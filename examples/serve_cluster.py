"""End-to-end fault-tolerant serving: the control plane recovers a cluster.

The paper runs MIG-serving as a Kubernetes controller that continuously
drives the cluster toward the optimizer's target state (§6-§7).  This
example drives that loop end to end through the declarative reconciler
(``repro.controlplane``) instead of mutating the cluster directly:

1. A seeded surge trace hits a 3-service synthetic-paper workload.
2. The closed-loop simulator serves it in ``control_plane=`` mode under
   the ``gpu_loss`` fault profile — one whole-GPU failure is injected
   mid-trace, killing its instances on the spot.
3. The control plane notices the observed/desired divergence, plans a
   repair through the §6 exchange-and-compact controller, re-creates the
   lost instances (paying their Figure-13c latencies), and sheds the
   over-capacity load honestly while degraded.
4. The recovery timeline is printed: fault -> detection -> repair
   transition -> SLO re-attainment.

  PYTHONPATH=src python examples/serve_cluster.py
"""

import numpy as np

from repro.core import SyntheticPaperProfiles, a100_rules
from repro.controlplane import FAULT_PROFILES
from repro.sim import ClusterSimulator, SimConfig
from repro.sim.traffic import correlated_surge_trace

SEED = 0
FAULT_PROFILE = "gpu_loss"


def main() -> None:
    prof = SyntheticPaperProfiles(n_models=3, seed=9)
    rng = np.random.default_rng((SEED, 3, 9))
    peaks = {m: float(rng.lognormal(7.0, 0.5)) for m in prof.services()}
    trace = correlated_surge_trace(
        {s: p / 4.0 for s, p in peaks.items()},
        duration_s=2 * 3600.0, bin_s=60.0,
        surge_mult=4.0, n_surges=2, surge_len_bins=15, ramp_bins=3,
        correlation=0.8, seed=SEED,
    )

    cfg = SimConfig(seed=SEED, fault_profile=FAULT_PROFILE)
    sim = ClusterSimulator(a100_rules(), prof, trace, cfg)
    profile = FAULT_PROFILES[FAULT_PROFILE]
    print(
        f"serving {len(trace.services)} services for {trace.duration_s:.0f}s "
        f"under fault profile '{FAULT_PROFILE}' "
        f"(gpu_failures={profile.gpu_failures}, "
        f"detection_delay={profile.detection_delay_s:.0f}s)\n"
    )
    rep = sim.run()

    print(rep.summary())

    print("\nrecovery timeline:")
    events = []
    for fault in rep.faults:
        events.append((
            fault.time_s,
            f"FAULT: {fault.kind} on "
            f"{'gpu' if fault.kind == 'gpu_failure' else 'machine'}"
            f"{fault.target} ({fault.fault_domain}) — "
            f"{fault.killed_instances} instances lost, "
            f"{sum(fault.lost_throughput.values()):.0f} req/s gone",
        ))
        events.append((
            fault.time_s + profile.detection_delay_s,
            "fault-detection deadline (a periodic observe may react first)",
        ))
    for t in rep.transitions:
        if t.reconcile is None:
            continue
        label = "repair" if t.trigger == "fault" else "demand transition"
        rec = t.reconcile
        events.append((
            t.start_s,
            f"{label}: {dict(sorted(t.action_counts.items()))} over "
            f"{t.parallel_seconds:.0f}s "
            f"(iterations={rec['iterations']}, retried={rec['retried']}, "
            f"converged={rec['converged']})",
        ))
    # per-fault re-attainment: the first bin at/after each fault where every
    # service meets its required rate again
    ok = np.ones(len(rep.times), dtype=bool)
    for tl in rep.timelines.values():
        ok &= tl.attainment >= 1.0 - 1e-9
    for fault in rep.faults:
        k = int(np.searchsorted(rep.times, fault.time_s - 1e-9))
        recovered = next((float(rep.times[j]) for j in range(k, len(ok)) if ok[j]), None)
        if recovered is not None:
            events.append((
                recovered,
                f"SLO re-attained ({recovered - fault.time_s:.0f}s after the"
                f" t={fault.time_s:.0f}s fault)",
            ))
    for ts, msg in sorted(events):
        print(f"  t={ts:7.0f}s  {msg}")
    print(
        f"\navailability={rep.availability():.4f}  "
        f"shed={rep.shed_total():.0f} requests  "
        f"final GPUs={rep.final_gpus}"
    )


if __name__ == "__main__":
    main()
