"""End-to-end serving driver (the paper's kind of workload).

1. Derives roofline profiles for three assigned architectures on TPU slices.
2. Optimizes a deployment (which slice sizes, which services, what batch).
3. Deploys it on the simulated cluster via the controller.
4. Brings up a REAL jit'd serving Engine (reduced config of the same
   architecture family) for every scheduled instance, load-balances a
   batched request stream across them with the weighted router, and reports
   per-service throughput counts.

  PYTHONPATH=src python examples/serve_cluster.py
"""

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.core import SLO, ConfigSpace, Controller, GreedyFast, SimulatedCluster, Workload
from repro.core.arch_bridge import tpu_arch_profiles
from repro.core.tpu_slice import pod_slice_rules, slice_mesh_shape
from repro.models import Model
from repro.serving import Engine, InstanceHandle, Request, WeightedRouter, run_closed_loop

ARCHS = ["qwen3-8b", "mamba2-370m", "zamba2-1.2b"]


def main() -> None:
    rules = pod_slice_rules()
    prof = tpu_arch_profiles(ARCHS)
    rng = np.random.default_rng(0)
    slos = {}
    for m in ARCHS:
        base = prof.throughput(m, prof.min_size(m), 50.0)
        slos[m] = SLO(base * float(rng.uniform(2.0, 5.0)), 50.0)
    wl = Workload.make(slos)

    dep = GreedyFast(ConfigSpace(rules, prof, wl)).solve()
    print(f"deployment uses {dep.num_gpus} pod-domains:")
    for i, cfg in enumerate(dep.configs):
        print(f"  domain{i}: partition={cfg.partition}")
        for a in cfg.assignments:
            if a.service:
                r, c = slice_mesh_shape(a.size)
                print(f"    {a.size:3d}-chip slice ({r}x{c} mesh) -> {a.service} "
                      f"batch={a.batch} {a.throughput:.0f} req/s")

    ctrl = Controller(rules, prof)
    cluster = SimulatedCluster(rules, dep.num_gpus)
    ctrl.deploy_fresh(cluster, dep)
    print(f"cluster: {cluster.gpus_in_use()} domains busy")

    # real engines for every instance of each service (reduced configs on CPU)
    print("\nserving real batched requests through scheduled instances:")
    for svc in ARCHS:
        handles, engines = [], {}
        iid = 0
        for cfg in dep.configs:
            for a in cfg.assignments:
                if a.service == svc:
                    handles.append(InstanceHandle(iid, a.size, a.throughput))
                    scfg = get_smoke_config(svc)
                    model = Model(scfg, remat=False)
                    params, _ = model.init(jax.random.PRNGKey(iid))
                    engines[iid] = Engine(model, params, batch=2, max_len=64)
                    iid += 1
        router = WeightedRouter(handles)
        reqs = {h.instance_id: [] for h in handles}
        for r in range(8):
            inst = router.pick()
            reqs[inst.instance_id].append(
                Request(rid=r, prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
            )
        served = 0
        for iid_, rs in reqs.items():
            if rs:
                served += run_closed_loop(engines[iid_], rs).served
        print(f"  {svc:14s} instances={len(handles)} dispatch={router.dispatch_counts()} served={served}/8")


if __name__ == "__main__":
    main()
