"""Train the ~100M-parameter example config for a few steps on CPU.

Thin wrapper over the real driver; the full run is
``python -m repro.launch.train --repro-100m --steps 300``.

  PYTHONPATH=src python examples/train_100m.py [steps]
"""

import sys

from repro.launch import train as train_mod


def main() -> None:
    steps = sys.argv[1] if len(sys.argv) > 1 else "5"
    sys.argv = [
        "train", "--repro-100m", "--steps", steps, "--batch", "4", "--seq", "64",
    ]
    train_mod.main()


if __name__ == "__main__":
    main()
