"""Quickstart: schedule DNN services onto reconfigurable accelerator slices.

Runs the whole MIG-Serving pipeline in miniature on the literal A100 rules:
profile → two-phase optimizer (greedy + GA/MCTS) → compare against static
baselines and the lower bound.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    SLO,
    SyntheticPaperProfiles,
    TwoPhaseOptimizer,
    Workload,
    a100_rules,
    baseline_homogeneous,
    baseline_static_mix,
    lower_bound_gpus,
)


def main() -> None:
    rules = a100_rules()
    prof = SyntheticPaperProfiles(n_models=12, seed=1)
    rng = np.random.default_rng(0)
    wl = Workload.make(
        {m: SLO(float(rng.lognormal(8.0, 0.7)), 100.0) for m in prof.services()}
    )

    print("model classification (paper §2.2):")
    for m in prof.services():
        print(f"  {m:16s} {prof.classify(m, 100.0)}")

    opt = TwoPhaseOptimizer(rules, prof, wl, ga_rounds=3, ga_population=4,
                            mcts_iterations=60, seed=0)
    rep = opt.run()

    print("\nGPUs used:")
    print(f"  A100-7/7 (no MIG)   : {baseline_homogeneous(rules, prof, wl, 7)}")
    print(f"  A100-MIX (static)   : {baseline_static_mix(rules, prof, wl)}")
    print(f"  greedy (fast algo)  : {rep.fast_deployment.num_gpus}  ({rep.fast_seconds:.2f}s)")
    print(f"  MIG-Serving (2-phase): {rep.best_deployment.num_gpus}  ({rep.total_seconds:.2f}s)")
    print(f"  lower bound         : {lower_bound_gpus(rules, prof, wl)}")
    print(f"\nGA history (best per round): {rep.ga_history}")
    ex = rep.best_deployment.configs[0]
    print(f"\nexample GPU config: partition={ex.partition}")
    for a in ex.assignments:
        print(f"  {a.size}/7 instance -> {a.service or '(idle)'}  batch={a.batch}  {a.throughput:.0f} req/s")


if __name__ == "__main__":
    main()
