"""Transparent deployment transition, closed-loop (paper §6 / §8.2).

Drives the cluster simulator (:mod:`repro.sim` — see the "Simulator"
section in ROADMAP.md) with a day->night->day arrival trace: traffic is
routed over the deployed MIG instances, the periodic re-optimizer detects
the demand shift, re-runs the optimizer pipeline, and executes
exchange-and-compact transitions whose Figure-13c action latencies are
charged to in-flight capacity.  The §6 transparency guarantee — during a
transition every service's throughput stays >= min(old, new) required —
is asserted at every trace point, and the run is fully seeded: the same
seed reproduces the report byte-for-byte.

  PYTHONPATH=src python examples/day_night_transition.py
"""

from repro.core import a100_rules
from repro.sim import ClusterSimulator, SimConfig

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from common import HEADROOM, day_night_trace, realworld_profile  # noqa: E402


def main() -> None:
    prof = realworld_profile()
    trace = day_night_trace(prof, headroom=HEADROOM)
    cfg = SimConfig(seed=0, reoptimize_every_s=1800.0, headroom=HEADROOM)
    rep = ClusterSimulator(a100_rules(), prof, trace, cfg).run()
    print(rep.summary())

    # §6 transparency at every trace point of every transition
    assert rep.transparent, "throughput dropped below min(old, new) required"
    print(
        "throughput never dropped below min(day, night) SLO: True "
        f"(worst margin {rep.transparency_margin():.1f} req/s)"
    )

    # the closed loop actually acted: at least one shrink + one grow
    acted = [t for t in rep.transitions if t.action_counts]
    assert len(acted) >= 2, "expected day->night and night->day transitions"

    # determinism: same seed, byte-identical report
    rep2 = ClusterSimulator(a100_rules(), prof, trace, cfg).run()
    assert rep.to_json() == rep2.to_json()
    print("same-seed re-run is byte-identical: True")


if __name__ == "__main__":
    main()
