"""Transparent deployment transition (paper §6 / §8.2).

Deploys the daytime workload, transitions to the night workload and back
with exchange-and-compact, and proves from the throughput trace that no
service ever dropped below min(day, night) required throughput.

  PYTHONPATH=src python examples/day_night_transition.py
"""

from repro.core import ConfigSpace, Controller, GreedyFast, SimulatedCluster, a100_rules

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
from common import day_night_workloads, realworld_profile  # noqa: E402


def main() -> None:
    rules = a100_rules()
    prof = realworld_profile()
    wl_day, wl_night = day_night_workloads(prof)
    dep_day = GreedyFast(ConfigSpace(rules, prof, wl_day)).solve()
    dep_night = GreedyFast(ConfigSpace(rules, prof, wl_night)).solve()
    print(f"day: {dep_day.num_gpus} GPUs   night: {dep_night.num_gpus} GPUs")

    ctrl = Controller(rules, prof)
    cluster = SimulatedCluster(rules, dep_day.num_gpus + 2)
    ctrl.deploy_fresh(cluster, dep_day)
    n0 = len(cluster.actions_applied)

    for label, target, wl_to in (
        ("day->night", dep_night, wl_night),
        ("night->day", dep_day, wl_day),
    ):
        rep = ctrl.transition(cluster, target)
        print(
            f"{label}: serial={rep.serial_seconds:.0f}s "
            f"parallel={rep.parallel_seconds:.0f}s actions={rep.action_counts} "
            f"busy={rep.final_gpus_busy} GPUs"
        )

    # transparency check over the full trace
    ok = True
    for _, tp in cluster.trace[n0:]:
        for svc in prof.services():
            lo = min(
                wl_day.services[wl_day.index(svc)].slo.throughput,
                wl_night.services[wl_night.index(svc)].slo.throughput,
            )
            if tp.get(svc, 0.0) < lo - 1e-6:
                ok = False
    print(f"throughput never dropped below min(day, night) SLO: {ok}")
    assert ok


if __name__ == "__main__":
    main()
