"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark), where
``derived`` is each benchmark's headline number, followed by the detailed
per-figure output blocks.

  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import time

from benchmarks import (
    fig01_10_cost,
    fig04_classification,
    fig09_gpu_savings,
    fig11_mps,
    fig12_slow_improvement,
    fig13_transition,
    fig14_slo_satisfaction,
    optimality_gap,
    roofline_table,
)

BENCHES = [
    ("fig01_10_cost", fig01_10_cost.main),
    ("fig04_classification", fig04_classification.main),
    ("fig09_gpu_savings", fig09_gpu_savings.main),
    ("fig11_mps", fig11_mps.main),
    ("fig12_slow_improvement", fig12_slow_improvement.main),
    ("fig13_transition", fig13_transition.main),
    ("fig14_slo_satisfaction", fig14_slo_satisfaction.main),
    ("optimality_gap", optimality_gap.main),
    ("roofline_table", roofline_table.main),
]


def _derived(report: str) -> str:
    """Last '#' comment line = the benchmark's headline."""
    heads = [l.strip("# ").strip() for l in report.splitlines() if l.startswith("#")]
    return (heads[-1] if heads else "").replace(",", ";")


def main() -> None:
    rows = []
    blocks = []
    for name, fn in BENCHES:
        t0 = time.monotonic()
        report = fn()
        us = (time.monotonic() - t0) * 1e6
        rows.append(f"{name},{us:.0f},{_derived(report)}")
        blocks.append(f"==== {name} ====\n{report}")
    print("name,us_per_call,derived")
    print("\n".join(rows))
    print()
    print("\n\n".join(blocks))


if __name__ == "__main__":
    main()
