"""Figure 14: throughput required by SLOs vs throughput provided by the
deployed instances, for the day and night workloads.

The paper measures >95% satisfaction, the <5% shortfall coming from
profiling-vs-serving variance.  We reproduce that by deploying the
optimizer's plan and re-evaluating each instance with a perturbed
"serving-framework" throughput (±4% noise, seeded) — satisfaction must stay
above 95% per service.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import ConfigSpace, GreedyFast, a100_rules

from benchmarks.common import day_night_workloads, realworld_profile


def run(noise: float = 0.04, seed: int = 0) -> Dict[str, Dict[str, float]]:
    rules = a100_rules()
    prof = realworld_profile()
    wl_day, wl_night = day_night_workloads(prof)
    rng = np.random.default_rng(seed)
    out = {}
    for label, wl in (("daytime", wl_day), ("night", wl_night)):
        dep = GreedyFast(ConfigSpace(rules, prof, wl)).solve()
        provided = {m: 0.0 for m in prof.services()}
        for cfg in dep.configs:
            for a in cfg.assignments:
                if a.service:
                    provided[a.service] += a.throughput * float(
                        rng.uniform(1 - noise, 1 + noise)
                    )
        sat = {}
        for svc in wl.services:
            sat[svc.name] = provided[svc.name] / svc.slo.throughput
        sat["all"] = sum(provided.values()) / sum(
            s.slo.throughput for s in wl.services
        )
        out[label] = sat
    return out


def main() -> str:
    res = run()
    lines = ["workload,service,satisfaction"]
    worst = 1e9
    for label, sat in res.items():
        for m, v in sat.items():
            lines.append(f"{label},{m},{v:.3f}")
            worst = min(worst, v)
    lines.append(f"# worst satisfaction: {worst:.1%} (paper: >95%)")
    assert worst > 0.95
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
