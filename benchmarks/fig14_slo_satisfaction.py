"""Figure 14: throughput required by SLOs vs throughput provided by the
deployed instances, for the day and night workloads.

The paper measures >95% satisfaction, the <5% shortfall coming from
profiling-vs-serving variance.  Reproduced on the closed-loop simulator
(:mod:`repro.sim`): the day->night->day trace is served live, each
instance's serving throughput is perturbed with seeded +/-4% noise against
its profile, and per-bin attainment (provided capacity / required) is
accounted per service — including through the mid-run transitions.
"""

from __future__ import annotations

from typing import Dict

from repro.core import a100_rules
from repro.sim import ClusterSimulator, SimConfig

from benchmarks.common import (
    HEADROOM,
    NIGHT_END_FRAC,
    NIGHT_START_FRAC,
    RAMP_DOWN_START_FRAC,
    day_night_trace,
    realworld_profile,
)


def run(noise: float = 0.04, seed: int = 0) -> Dict[str, Dict[str, float]]:
    rules = a100_rules()
    prof = realworld_profile()
    trace = day_night_trace(prof, headroom=HEADROOM)
    sim = ClusterSimulator(
        rules,
        prof,
        trace,
        SimConfig(
            seed=seed,
            reoptimize_every_s=1800.0,
            throughput_noise=noise,
            arrivals="poisson",
            headroom=HEADROOM,
        ),
    )
    rep = sim.run()
    # windows derived from the trace's phase fractions (0.02 guard margin
    # keeps ramp bins out of the night plateau window)
    n = len(rep.times)
    windows = {
        "daytime": slice(0, int(n * RAMP_DOWN_START_FRAC)),
        "night": slice(int(n * (NIGHT_START_FRAC + 0.02)), int(n * (NIGHT_END_FRAC - 0.02))),
    }
    out: Dict[str, Dict[str, float]] = {}
    for label, win in windows.items():
        sat: Dict[str, float] = {}
        prov_sum = req_sum = 0.0
        for svc in rep.services:
            tl = rep.timelines[svc]
            provided = float(tl.capacity[win].sum())
            required = float(tl.required[win].sum())
            sat[svc] = provided / required if required > 0 else 1.0
            prov_sum += provided
            req_sum += required
        sat["all"] = prov_sum / req_sum if req_sum > 0 else 1.0
        out[label] = sat
    # the falsifiable metrics: per-bin attainment (min(1, capacity/required),
    # dips when serving capacity lags the deployed requirement — e.g. during
    # transitions or broken in-flight accounting) and served arrivals
    out["attainment"] = {svc: rep.mean_attainment(svc) for svc in rep.services}
    out["served"] = {svc: rep.served_fraction(svc) for svc in rep.services}
    return out


def main() -> str:
    res = run()
    lines = ["workload,service,satisfaction"]
    for label in ("daytime", "night"):
        for m, v in res[label].items():
            lines.append(f"{label},{m},{v:.3f}")
    # the windowed capacity ratios above are reporting only — MIG instance
    # quantization over-provisions small services well past 100%; the
    # pass/fail criteria are attainment and served fraction, which track the
    # tightly provisioned path (and the +/-4% serving noise) bin by bin
    att_worst = min(res["attainment"].values())
    served_worst = min(res["served"].values())
    lines.append(f"# worst per-bin SLO attainment: {att_worst:.1%} (paper: >95%)")
    lines.append(f"# worst served-fraction of arrivals: {served_worst:.1%}")
    assert att_worst > 0.95
    assert served_worst > 0.95
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
