"""Figures 1 & 10: cost-per-request / workload cost across GPU setups.

Figure 1 compares per-request serving cost on V100, T4, A100-7/7 and
A100-7×1/7; Figure 10 compares whole-workload cost (T4 vs A100 baselines vs
MIG-Serving).  GPU relative performance is modeled as throughput scale
factors and priced with AWS on-demand rates (p3/g4dn/p4d, per paper refs
[3-5]).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import (
    SLO,
    SyntheticPaperProfiles,
    TwoPhaseOptimizer,
    Workload,
    a100_rules,
    baseline_homogeneous,
)

from benchmarks.common import simulation_profile, simulation_workload

# $/hr per GPU (AWS on-demand, 2021): p3.2xlarge=V100, g4dn.xlarge=T4,
# p4d.24xlarge/8=A100
PRICE = {"V100": 3.06, "T4": 0.526, "A100": 4.096}
# throughput of one whole GPU relative to an A100-7/7 (INT8 inference,
# batch 8 — NVIDIA data-center inference benchmarks put A100 at roughly
# 7-8x T4 and ~3x V100 on the paper's model set)
REL_TPUT = {"V100": 0.35, "T4": 0.13, "A100": 1.0}


def fig1_cost_per_request() -> Dict[str, Dict[str, float]]:
    # the paper's 8 hub models all fit a 1/7 instance; mirror that by
    # filtering to min_size == 1 models
    prof = SyntheticPaperProfiles(n_models=16, seed=0)
    out = {}
    for m in prof.services():
        if prof.min_size(m) != 1:
            continue
        a100_whole = prof.throughput(m, 7, 100.0)
        if a100_whole <= 0:
            continue
        # A100-7×1/7: seven independent 1/7 instances
        t_17 = prof.throughput(m, 1, 100.0) * 7
        costs = {
            "V100": PRICE["V100"] / (a100_whole * REL_TPUT["V100"]),
            "T4": PRICE["T4"] / (a100_whole * REL_TPUT["T4"]),
            "A100-7/7": PRICE["A100"] / a100_whole,
        }
        if t_17 > 0:
            costs["A100-7x1/7"] = PRICE["A100"] / t_17
        lo = min(costs.values())
        out[m] = {k: v / lo for k, v in costs.items()}  # normalized
    return out


def fig10_workload_cost() -> Dict[str, float]:
    rules = a100_rules()
    prof = simulation_profile()
    wl = simulation_workload("lognormal-1", prof)
    a100_77 = baseline_homogeneous(rules, prof, wl, 7)
    a100_17 = baseline_homogeneous(rules, prof, wl, 1)
    opt = TwoPhaseOptimizer(rules, prof, wl, ga_rounds=1, ga_population=3,
                            mcts_iterations=40, seed=0)
    mig = opt.run().best_deployment.num_gpus
    # T4 fleet able to provide the same aggregate throughput
    t4_count = 0
    for svc in wl.services:
        per_t4 = prof.throughput(svc.name, 7, svc.slo.latency_ms) * REL_TPUT["T4"]
        t4_count += int(np.ceil(svc.slo.throughput / max(per_t4, 1e-9)))
    costs = {
        "A100-7/7": a100_77 * PRICE["A100"],
        "T4": t4_count * PRICE["T4"],
        "MIG-Serving": mig * PRICE["A100"],
    }
    if a100_17 > 0:
        costs["A100-7x1/7"] = a100_17 * PRICE["A100"]
    lo = min(costs.values())
    return {k: v / lo for k, v in costs.items()}


def main() -> str:
    lines = []
    f1 = fig1_cost_per_request()
    prof = SyntheticPaperProfiles(n_models=16, seed=0)
    by_class: Dict[str, list] = {}
    for m, costs in f1.items():
        cls = prof.classify(m, 100.0)
        a100 = min(costs.get("A100-7x1/7", 9e9), costs["A100-7/7"])
        by_class.setdefault(cls, []).append(a100 <= min(costs.values()) * 1.02)
    per_class = {c: f"{sum(v)}/{len(v)}" for c, v in sorted(by_class.items())}
    sub_ok = by_class.get("sub-linear", [])
    lines.append(
        f"# Fig1: an A100 setup is cheapest (within 2%) per class: {per_class} "
        f"— A100-7x1/7 wins every sub-linear model "
        f"(the paper's hub models behave sub-linearly at its batch sizes)"
    )
    assert all(sub_ok), "MIG'd A100 must win the sub-linear class"
    f10 = fig10_workload_cost()
    lines.append("setup," + ",".join(f10.keys()))
    lines.append("normcost," + ",".join(f"{v:.3f}" for v in f10.values()))
    assert f10["MIG-Serving"] == min(f10.values())
    lines.append("# Fig10: MIG-Serving is the most cost-efficient (paper: same)")
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
