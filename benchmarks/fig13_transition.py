"""Figure 13: deployment transitions between the day and night real-world
workloads — end-to-end runtime (serial vs dependency-parallel), action
counts per transition, and per-action latencies (13c).

Runs on the closed-loop simulator (:mod:`repro.sim`): a day->night->day
arrival trace drives the cluster; the periodic re-optimizer detects the
demand shift and executes the exchange-and-compact transitions, whose
Figure-13c action latencies are charged to in-flight serving capacity.
The day->night (shrinking) and night->day (growing) transitions are read
off the simulation report.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core import a100_rules
from repro.core.cluster import ACTION_SECONDS
from repro.sim import ClusterSimulator, SimConfig

from benchmarks.common import HEADROOM, day_night_trace, realworld_profile


def run(seed: int = 0) -> Dict:
    rules = a100_rules()
    prof = realworld_profile()
    trace = day_night_trace(prof, headroom=HEADROOM)
    sim = ClusterSimulator(
        rules,
        prof,
        trace,
        SimConfig(
            seed=seed,
            reoptimize_every_s=1800.0,
            arrivals="poisson",
            headroom=HEADROOM,
        ),
    )
    rep = sim.run()

    def total(req: Dict[str, float]) -> float:
        return sum(req.values())

    day2night: Optional[Dict] = None
    night2day: Optional[Dict] = None
    for t in rep.transitions:
        if not t.action_counts:
            continue  # demand moved below threshold; no actions executed
        entry = {
            "t_s": t.start_s,
            "serial_s": t.serial_seconds,
            "parallel_s": t.parallel_seconds,
            "actions": dict(t.action_counts),
            "transparent": t.transparent,
        }
        if total(t.new_required) < total(t.old_required) and day2night is None:
            day2night = entry
        elif total(t.new_required) > total(t.old_required) and night2day is None:
            night2day = entry
    assert day2night and night2day, "trace must produce both transitions"

    gpus_by_phase = {
        "day": max(t.gpus_before for t in rep.transitions),
        "night": min(t.gpus_after for t in rep.transitions),
    }
    return {
        "gpus": gpus_by_phase,
        "day2night": day2night,
        "night2day": night2day,
        "transitions_total": len([t for t in rep.transitions if t.action_counts]),
        "transparent": rep.transparent,
        "action_seconds": dict(ACTION_SECONDS),
    }


def main() -> str:
    res = run()
    lines = [
        f"# day uses {res['gpus']['day']} GPUs, night uses {res['gpus']['night']}"
        f" (closed-loop sim, {res['transitions_total']} transitions)",
        "transition,serial_s,parallel_s,creates,deletes,migrates,repartitions",
    ]
    for t in ("day2night", "night2day"):
        a = res[t]["actions"]
        lines.append(
            f"{t},{res[t]['serial_s']:.0f},{res[t]['parallel_s']:.0f},"
            f"{a.get('create',0)},{a.get('delete',0)},{a.get('migrate',0)},{a.get('repartition',0)}"
        )
    for t in ("day2night", "night2day"):
        assert res[t]["parallel_s"] <= 1800, "transitions must finish within 30min (paper §8.2)"
        assert res[t]["transparent"], "§6 transparency must hold at every trace point"
    d2n, n2d = res["day2night"]["actions"], res["night2day"]["actions"]
    lines.append(
        f"# day2night deletes>={d2n.get('delete',0)}>= creates {d2n.get('create',0)}; "
        f"night2day creates {n2d.get('create',0)} >= deletes {n2d.get('delete',0)} (paper Fig13b)"
    )
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
