"""Figure 13: deployment transitions between the day and night real-world
workloads — end-to-end runtime (serial vs dependency-parallel), action
counts per transition, and per-action latencies (13c)."""

from __future__ import annotations

from typing import Dict

from repro.core import (
    ConfigSpace,
    Controller,
    GreedyFast,
    SimulatedCluster,
    a100_rules,
)
from repro.core.cluster import ACTION_SECONDS

from benchmarks.common import day_night_workloads, realworld_profile


def run() -> Dict:
    rules = a100_rules()
    prof = realworld_profile()
    wl_day, wl_night = day_night_workloads(prof)
    dep_day = GreedyFast(ConfigSpace(rules, prof, wl_day)).solve()
    dep_night = GreedyFast(ConfigSpace(rules, prof, wl_night)).solve()

    ctrl = Controller(rules, prof)
    cluster = SimulatedCluster(rules, dep_day.num_gpus + 2)
    ctrl.deploy_fresh(cluster, dep_day)

    day2night = ctrl.transition(cluster, dep_night)
    night2day = ctrl.transition(cluster, dep_day)
    return {
        "gpus": {"day": dep_day.num_gpus, "night": dep_night.num_gpus},
        "day2night": {
            "serial_s": day2night.serial_seconds,
            "parallel_s": day2night.parallel_seconds,
            "actions": day2night.action_counts,
        },
        "night2day": {
            "serial_s": night2day.serial_seconds,
            "parallel_s": night2day.parallel_seconds,
            "actions": night2day.action_counts,
        },
        "action_seconds": dict(ACTION_SECONDS),
    }


def main() -> str:
    res = run()
    lines = [
        f"# day uses {res['gpus']['day']} GPUs, night uses {res['gpus']['night']}",
        "transition,serial_s,parallel_s,creates,deletes,migrates,repartitions",
    ]
    for t in ("day2night", "night2day"):
        a = res[t]["actions"]
        lines.append(
            f"{t},{res[t]['serial_s']:.0f},{res[t]['parallel_s']:.0f},"
            f"{a.get('create',0)},{a.get('delete',0)},{a.get('migrate',0)},{a.get('repartition',0)}"
        )
    for t in ("day2night", "night2day"):
        assert res[t]["parallel_s"] <= 1800, "transitions must finish within 30min (paper §8.2)"
    d2n, n2d = res["day2night"]["actions"], res["night2day"]["actions"]
    lines.append(
        f"# day2night deletes>={d2n.get('delete',0)}>= creates {d2n.get('create',0)}; "
        f"night2day creates {n2d.get('create',0)} >= deletes {n2d.get('delete',0)} (paper Fig13b)"
    )
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
