"""Optimizer-core benchmark: the perf trajectory this repo tracks.

Times the hot paths that sit on the simulator's reoptimize loop — config
space construction, greedy ``produce``, GA rounds, MCTS iterations, and one
full simulator reoptimize cycle — at small/medium/large workloads (up to
~16 services x the full A100 partition space), and writes
``BENCH_optimizer.json`` at the repo root.

The JSON keeps two timing sections: ``baseline`` (recorded once, before the
array-native optimizer core landed) and ``current`` (refreshed every run),
plus the derived ``speedup`` ratios.  The performance contract (ROADMAP
"Performance contract") is that medium-workload ``greedy_produce_s`` and
``ga_round_s`` stay >= 5x faster than the recorded baseline, and that the
warm-start steady-state cycle (``warm_reoptimize_cycle_s``, incumbent
repair over a rebound ConfigSpace plus the delta-aware incremental
transition) stays >= 2x faster than the cold cycle
(``cold_reoptimize_cycle_s``) on the same medium 1.4x drift — a same-run
ratio recorded as ``speedup.medium.warm_vs_cold_reoptimize``.  A full run
**exits non-zero** when any floor is broken (``--smoke`` and
``--set-baseline`` skip the gate: smoke sizes have no recorded baseline and
a fresh baseline is 1.0x by construction).

Usage::

    PYTHONPATH=src python benchmarks/bench_optimizer.py            # refresh current
    PYTHONPATH=src python benchmarks/bench_optimizer.py --smoke    # CI: tiny sizes,
                                                                   # temp output, JSON sanity
    PYTHONPATH=src python benchmarks/bench_optimizer.py --set-baseline  # re-record baseline
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Callable, Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    SLO,
    ConfigSpace,
    Deployment,
    GeneticOptimizer,
    GreedyFast,
    MCTSSlow,
    SyntheticPaperProfiles,
    Workload,
    a100_rules,
)
from repro.core.cluster import SimulatedCluster
from repro.sim import ReoptimizeDriver

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_optimizer.json")

# ROADMAP "Performance contract": floors that a full (non-smoke) run must
# keep, per workload size and metric.  Most are speedups vs the recorded
# baseline; "warm_vs_cold_reoptimize" is a same-run ratio — the warm-start
# reoptimize cycle (incumbent repair over a rebound ConfigSpace) against the
# cold cycle on the same 1.4x drift.
SPEEDUP_FLOORS = {
    "medium": {
        "greedy_produce": 5.0,
        "ga_round": 5.0,
        "warm_vs_cold_reoptimize": 2.0,
    },
}

# (n_services, lognormal scale of SLO throughputs, MCTS iterations, GA population)
SIZES = {
    "small": dict(n=4, scale=7.6, mcts_iters=60, ga_pop=4),
    "medium": dict(n=12, scale=8.6, mcts_iters=60, ga_pop=4),
    "large": dict(n=16, scale=8.6, mcts_iters=60, ga_pop=4),
}
SMOKE_SIZES = {
    "smoke": dict(n=3, scale=7.0, mcts_iters=10, ga_pop=2),
}


def build_problem(n: int, scale: float, seed: int = 2):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(seed)
    slos = {m: SLO(float(rng.lognormal(scale, 0.7)), 100.0) for m in prof.services()}
    return prof, Workload.make(slos)


def best_of(fn: Callable[[], None], repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_size(name: str, spec: Dict, repeats: int) -> Dict[str, float]:
    prof, wl = build_problem(spec["n"], spec["scale"])
    rules = a100_rules()

    out: Dict[str, float] = {}
    out["space_build_s"] = best_of(lambda: ConfigSpace(rules, prof, wl), repeats)
    space = ConfigSpace(rules, prof, wl)
    out["num_configs"] = float(len(space))

    zeros = np.zeros(wl.n)
    out["greedy_produce_s"] = best_of(
        lambda: GreedyFast(space).produce(zeros), repeats
    )
    seed_dep = Deployment(GreedyFast(space).produce(zeros))
    out["num_gpus"] = float(seed_dep.num_gpus)

    out["mcts_produce_s"] = best_of(
        lambda: MCTSSlow(space, iterations=spec["mcts_iters"], seed=0).produce(zeros),
        repeats,
    )

    # GA-round timing: one §5.2 round (crossover + mutation + batched
    # fitness + elitist selection) with the registered greedy refill, so the
    # number tracks the GA machinery itself; the MCTS-refill variant rides
    # along as ga_round_mcts_s (it is dominated by the MCTS internals that
    # mcts_produce_s already tracks).
    def ga_round() -> None:
        ga = GeneticOptimizer(
            space, GreedyFast(space), population=spec["ga_pop"], rounds=1, seed=0
        )
        ga.run(seed_dep)

    out["ga_round_s"] = best_of(ga_round, repeats)

    def ga_round_mcts() -> None:
        ga = GeneticOptimizer(
            space,
            MCTSSlow(space, iterations=spec["mcts_iters"], seed=0),
            population=spec["ga_pop"],
            rounds=1,
            seed=0,
        )
        ga.run(seed_dep)

    out["ga_round_mcts_s"] = best_of(ga_round_mcts, repeats)

    optimize_share = {}

    def reoptimize_cycle() -> None:
        driver = ReoptimizeDriver(rules, prof, seed=0)
        cluster = SimulatedCluster(rules, 1)
        rates = {s.name: s.slo.throughput / driver.headroom for s in wl.services}
        driver.initial_deploy(cluster, rates)
        shifted = {svc: r * 1.4 for svc, r in rates.items()}
        driver.reoptimize(cluster, shifted, now=0.0)
        # the driver exposes the optimizer pipeline's wall clock (it cannot
        # go into the byte-pinned SimReport)
        optimize_share["s"] = driver.last_optimize_report.total_seconds

    out["reoptimize_cycle_s"] = best_of(reoptimize_cycle, max(1, repeats - 1))
    out["reoptimize_optimize_s"] = optimize_share["s"]

    # Warm vs cold steady-state cycle, apples-to-apples: setup (driver ctor
    # + initial_deploy) is untimed, the stopwatch covers exactly one
    # ``reoptimize`` on the same 1.4x drift.  The warm driver carries the
    # incumbent forward — the call rebinds the ConfigSpace and repairs the
    # delta instead of enumerating + packing from scratch, and the bounded
    # edit distance shrinks the §6 transition it must execute.
    def steady_cycle_once(warm: bool) -> float:
        driver = ReoptimizeDriver(rules, prof, seed=0, warm_start=warm)
        cluster = SimulatedCluster(rules, 1)
        rates = {s.name: s.slo.throughput / driver.headroom for s in wl.services}
        driver.initial_deploy(cluster, rates)
        shifted = {svc: r * 1.4 for svc, r in rates.items()}
        t0 = time.perf_counter()
        driver.reoptimize(cluster, shifted, now=0.0)
        return time.perf_counter() - t0

    inner_repeats = max(1, repeats - 1)
    out["cold_reoptimize_cycle_s"] = min(
        steady_cycle_once(False) for _ in range(inner_repeats)
    )
    out["warm_reoptimize_cycle_s"] = min(
        steady_cycle_once(True) for _ in range(inner_repeats)
    )
    return out


def git_rev() -> str:
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=REPO_ROOT,
                capture_output=True,
                text=True,
                check=True,
            ).stdout.strip()
            or "unknown"
        )
    except Exception:
        return "unknown"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny sizes, temp output")
    ap.add_argument("--set-baseline", action="store_true",
                    help="overwrite the recorded baseline with this run")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None, help="output path (default: repo BENCH_optimizer.json)")
    args = ap.parse_args()

    sizes = SMOKE_SIZES if args.smoke else SIZES
    repeats = 1 if args.smoke else args.repeats
    if args.out:
        out_path = args.out
    elif args.smoke:
        out_path = os.path.join(tempfile.gettempdir(), "BENCH_optimizer_smoke.json")
    else:
        out_path = DEFAULT_OUT
    if args.smoke and os.path.exists(out_path):
        # never let smoke-size timings clobber a recorded full-size baseline:
        # it was measured from the pre-change commit and cannot be reproduced
        # at HEAD.  (Re-overwriting a previous smoke artifact is fine.)
        try:
            with open(out_path) as f:
                existing = json.load(f)
        except (OSError, json.JSONDecodeError):
            existing = {}
        if "baseline" in existing and set(existing.get("workloads", {})) != set(
            SMOKE_SIZES
        ):
            ap.error(
                f"--smoke refuses to overwrite {out_path} (holds a full-size "
                "baseline); pick a fresh --out"
            )

    current: Dict[str, Dict[str, float]] = {}
    for name, spec in sizes.items():
        current[name] = bench_size(name, spec, repeats)
        timings = {k: round(v, 6) for k, v in current[name].items()}
        print(f"[{name}] {timings}")

    doc: Dict = {}
    if os.path.exists(out_path) and not args.smoke:
        try:
            with open(out_path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            doc = {}

    doc.setdefault("schema", 1)
    doc["units"] = "seconds (best-of repeats)"
    doc["workloads"] = {n: dict(s) for n, s in sizes.items()}
    if args.set_baseline or "baseline" not in doc:
        doc["baseline"] = current
        doc["baseline_git"] = git_rev()
    doc["current"] = current
    doc["current_git"] = git_rev()
    doc["speedup"] = {}
    for size, cur in current.items():
        base = doc["baseline"].get(size, {})
        doc["speedup"][size] = {
            key.removesuffix("_s"): round(base[key] / cur[key], 2)
            for key in cur
            if key.endswith("_s") and base.get(key, 0) > 0 and cur[key] > 0
        }
        # same-run ratio (not vs baseline): cold / warm steady-state
        # reoptimize on the identical drift — the warm-start win itself
        if cur.get("warm_reoptimize_cycle_s", 0) > 0 and cur.get(
            "cold_reoptimize_cycle_s", 0
        ) > 0:
            doc["speedup"][size]["warm_vs_cold_reoptimize"] = round(
                cur["cold_reoptimize_cycle_s"] / cur["warm_reoptimize_cycle_s"], 2
            )

    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")

    # validate: the file must round-trip as JSON with the expected sections
    with open(out_path) as f:
        loaded = json.load(f)
    assert "baseline" in loaded and "current" in loaded, "malformed bench output"
    print(f"wrote {out_path}")
    if doc["speedup"]:
        print("speedup vs baseline:", json.dumps(doc["speedup"], sort_keys=True))

    # gate the perf contract: a full run against a previously recorded
    # baseline must keep the ROADMAP floors, or the script fails the build
    if not args.smoke and not args.set_baseline:
        broken = []
        for size, floors in SPEEDUP_FLOORS.items():
            got = doc["speedup"].get(size, {})
            for metric, floor in floors.items():
                if metric not in got:
                    broken.append(f"{size}.{metric}: no speedup recorded")
                elif got[metric] < floor:
                    broken.append(
                        f"{size}.{metric}: {got[metric]:.2f}x < {floor:.1f}x floor"
                    )
        if broken:
            print(
                "PERF CONTRACT BROKEN (ROADMAP 'Performance contract'):\n  "
                + "\n  ".join(broken),
                file=sys.stderr,
            )
            return 1
        print("perf contract held:", ", ".join(
            f"{size}.{metric} >= {floor:.1f}x"
            for size, floors in SPEEDUP_FLOORS.items()
            for metric, floor in floors.items()
        ))
    return 0


if __name__ == "__main__":
    sys.exit(main())
