"""Regenerate the EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run
artifacts.  Run after any re-sweep:

  PYTHONPATH=src python -m benchmarks.gen_experiments > experiments/roofline_table.md
"""

from __future__ import annotations

from benchmarks.roofline_table import load_all


def fmt(v, scale=1.0, digits=3):
    return f"{v/scale:.{digits}g}"


def main() -> str:
    rows = load_all()
    base = [r for r in rows if "+" not in r["mesh"]]
    variants = [r for r in rows if "+" in r["mesh"]]
    base.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant "
        "| useful_flops | peak_GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in base:
        pk = (r.get("peak_memory_per_device") or 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3g} | {r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | {pk:.1f} |"
        )
    lines.append("")
    lines.append("### §Perf variants")
    lines.append(lines[0])
    lines.append(lines[1])
    for r in sorted(variants, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        pk = (r.get("peak_memory_per_device") or 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3g} | {r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | {pk:.1f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
