"""Beyond-paper: exact optimality gap on small instances.

The paper bounds MIG-Serving's quality only against a constraint-free LP
bound ("likely impossible to achieve").  On small instances we solve the
≤2-service config space exactly (branch-and-bound, repro.core.exact) and
combine the LP bound with the universal per-service bound — giving the
true gap of the fast greedy and the two-phase optimizer.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import (
    SLO,
    SyntheticPaperProfiles,
    TwoPhaseOptimizer,
    Workload,
    a100_rules,
    lower_bound_gpus,
)
from repro.core.exact import PairSpaceExact, per_service_lower_bound


def run(n_instances: int = 4) -> List[Dict]:
    out = []
    for seed in range(n_instances):
        prof = SyntheticPaperProfiles(n_models=4, seed=seed)
        rng = np.random.default_rng(seed)
        wl = Workload.make(
            {m: SLO(float(rng.lognormal(6.2, 0.5)), 100.0) for m in prof.services()}
        )
        opt = TwoPhaseOptimizer(
            a100_rules(), prof, wl, ga_rounds=6, ga_population=6,
            mcts_iterations=150, seed=0,
        )
        rep = opt.run()
        bb = PairSpaceExact(opt.space, node_limit=500_000)
        exact, done = bb.solve(rep.fast_deployment)
        out.append(
            {
                "seed": seed,
                "greedy": rep.fast_deployment.num_gpus,
                "two_phase": rep.best_deployment.num_gpus,
                "pair_exact": exact.num_gpus,
                "exact_complete": done,
                "lp_bound": lower_bound_gpus(a100_rules(), prof, wl),
                "per_service_bound": per_service_lower_bound(opt.space),
            }
        )
    return out


def main() -> str:
    rows = run()
    lines = ["seed,greedy,two_phase,pair_exact,complete,lp_bound,per_service_bound"]
    hits = 0
    for r in rows:
        lines.append(
            f"{r['seed']},{r['greedy']},{r['two_phase']},{r['pair_exact']},"
            f"{r['exact_complete']},{r['lp_bound']},{r['per_service_bound']}"
        )
        if r["two_phase"] <= r["pair_exact"]:
            hits += 1
    lines.append(
        f"# two-phase matches or beats the pair-space optimum on {hits}/{len(rows)} "
        f"small instances (packed >2-service configs escape the pair space)"
    )
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
