"""Shared workload builders for the paper-figure benchmarks (§8)."""

from __future__ import annotations

import numpy as np

from repro.core import SLO, SyntheticPaperProfiles, Workload

# The paper's four simulation workloads: 24 models, SLO throughputs drawn
# from normal / lognormal distributions, 100 ms latency SLO, sized to need
# hundreds of GPUs (§8).
SIM_WORKLOADS = {
    "normal-1": ("normal", 1),
    "normal-2": ("normal", 2),
    "lognormal-1": ("lognormal", 3),
    "lognormal-2": ("lognormal", 4),
}


def simulation_profile(seed: int = 1) -> SyntheticPaperProfiles:
    return SyntheticPaperProfiles(n_models=24, seed=seed)


def simulation_workload(name: str, prof: SyntheticPaperProfiles) -> Workload:
    dist, seed = SIM_WORKLOADS[name]
    rng = np.random.default_rng(seed)
    slos = {}
    for m in prof.services():
        if dist == "normal":
            tput = max(50.0, float(rng.normal(5000.0, 1500.0)))
        else:
            tput = float(rng.lognormal(8.3, 0.8))
        slos[m] = SLO(tput, 100.0)
    return Workload.make(slos)


def realworld_profile(seed: int = 9) -> SyntheticPaperProfiles:
    """Five services, as in the paper's real-world testbed workloads
    (roberta-large, bert-base-uncased, albert-large-v2, resnet101, resnet50)."""
    return SyntheticPaperProfiles(n_models=5, seed=seed)


def day_night_workloads(prof: SyntheticPaperProfiles):
    rng = np.random.default_rng(42)
    day = {m: SLO(float(rng.lognormal(7.0, 0.5)), 100.0) for m in prof.services()}
    night = {
        m: SLO(day[m].throughput * float(rng.uniform(0.2, 0.45)), 100.0)
        for m in prof.services()
    }
    return Workload.make(day), Workload.make(night)
