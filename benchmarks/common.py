"""Shared workload builders for the paper-figure benchmarks (§8)."""

from __future__ import annotations

import numpy as np

from repro.core import SLO, SyntheticPaperProfiles, Workload

# The paper's four simulation workloads: 24 models, SLO throughputs drawn
# from normal / lognormal distributions, 100 ms latency SLO, sized to need
# hundreds of GPUs (§8).
SIM_WORKLOADS = {
    "normal-1": ("normal", 1),
    "normal-2": ("normal", 2),
    "lognormal-1": ("lognormal", 3),
    "lognormal-2": ("lognormal", 4),
}


def simulation_profile(seed: int = 1) -> SyntheticPaperProfiles:
    return SyntheticPaperProfiles(n_models=24, seed=seed)


def simulation_workload(name: str, prof: SyntheticPaperProfiles) -> Workload:
    dist, seed = SIM_WORKLOADS[name]
    rng = np.random.default_rng(seed)
    slos = {}
    for m in prof.services():
        if dist == "normal":
            tput = max(50.0, float(rng.normal(5000.0, 1500.0)))
        else:
            tput = float(rng.lognormal(8.3, 0.8))
        slos[m] = SLO(tput, 100.0)
    return Workload.make(slos)


def realworld_profile(seed: int = 9) -> SyntheticPaperProfiles:
    """Five services, as in the paper's real-world testbed workloads
    (roberta-large, bert-base-uncased, albert-large-v2, resnet101, resnet50)."""
    return SyntheticPaperProfiles(n_models=5, seed=seed)


def day_night_workloads(prof: SyntheticPaperProfiles):
    rng = np.random.default_rng(42)
    day = {m: SLO(float(rng.lognormal(7.0, 0.5)), 100.0) for m in prof.services()}
    night = {
        m: SLO(day[m].throughput * float(rng.uniform(0.2, 0.45)), 100.0)
        for m in prof.services()
    }
    return Workload.make(day), Workload.make(night)


# one headroom for the trace builder AND SimConfig: day_night_trace divides
# SLO throughputs by it so the simulator's observed-rate x headroom
# requirement reproduces the paper's SLOs — the two must always match
HEADROOM = 1.1

# day->night->day phase boundaries as fractions of the trace duration; the
# fig13/fig14 analysis windows are derived from these, so retuning the ramp
# timing here keeps trace and analysis in lockstep
RAMP_DOWN_START_FRAC = 0.30
NIGHT_START_FRAC = 0.40
NIGHT_END_FRAC = 0.60
RAMP_UP_END_FRAC = 0.70


def day_night_trace(
    prof: SyntheticPaperProfiles,
    duration_s: float = 6 * 3600.0,
    bin_s: float = 60.0,
    headroom: float = HEADROOM,
):
    """Arrival trace realizing the day->night->day scenario (Figures 13-14):
    day rates, a smooth evening ramp down to each service's night rate, a
    night plateau, and a morning ramp back.  Rates are the day/night SLO
    throughputs divided by ``headroom`` so the closed-loop simulator's
    observed-rate x headroom requirement reproduces the paper's SLOs."""
    from repro.sim import replay_trace

    wl_day, wl_night = day_night_workloads(prof)
    n = int(round(duration_s / bin_s))
    t = (np.arange(n) + 0.5) / n
    # night weight: 0 during day, ramps down/up between the phase fractions
    down = (t - RAMP_DOWN_START_FRAC) / (NIGHT_START_FRAC - RAMP_DOWN_START_FRAC)
    up = (t - NIGHT_END_FRAC) / (RAMP_UP_END_FRAC - NIGHT_END_FRAC)
    w = np.clip(down, 0.0, 1.0) - np.clip(up, 0.0, 1.0)
    rates = {}
    for s_day, s_night in zip(wl_day.services, wl_night.services):
        hi = s_day.slo.throughput / headroom
        lo = s_night.slo.throughput / headroom
        rates[s_day.name] = hi * (1.0 - w) + lo * w
    return replay_trace(rates, bin_s)
