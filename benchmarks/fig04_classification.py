"""Figure 4 + Figures 16-19 (Appendix B): the model-performance study.

Classifies the synthetic 49-model population (sub-linear / linear /
super-linear) per the paper's §2.2 ratio test, at several batch sizes, and
emits the per-size throughput/latency table for two exemplar models (the
densenet121 / xlnet-large-cased analogues of Figure 3).
"""

from __future__ import annotations

from typing import Dict

from repro.core import SyntheticPaperProfiles


def classify_at_slo(prof: SyntheticPaperProfiles, slo_ms: float) -> Dict[str, int]:
    counts = {"sub-linear": 0, "linear": 0, "super-linear": 0}
    for m in prof.services():
        counts[prof.classify(m, slo_ms)] += 1
    return counts


def run() -> Dict:
    prof = SyntheticPaperProfiles(n_models=49, seed=0)
    by_slo = {slo: classify_at_slo(prof, slo) for slo in (30.0, 100.0, 1e9)}
    # Figure-3-style exemplars: most sub-linear and most super-linear model
    subs = [m for m in prof.services() if prof.classify(m) == "sub-linear"]
    sups = [m for m in prof.services() if prof.classify(m) == "super-linear"]
    exemplars = {}
    for m in (subs[:1] + sups[:1]):
        exemplars[m] = {
            s: {
                "throughput": round(prof.throughput(m, s, 100.0), 1),
                "latency_b8": round(prof.latency_ms(m, s, 8), 2)
                if prof.feasible(m, s) else None,
            }
            for s in prof.sizes()
        }
    return {"classification": by_slo, "exemplars": exemplars}


def main() -> str:
    res = run()
    lines = ["slo_ms,sub-linear,linear,super-linear"]
    for slo, c in res["classification"].items():
        lines.append(f"{slo},{c['sub-linear']},{c['linear']},{c['super-linear']}")
    nonlin = sum(
        v for k, v in res["classification"][100.0].items() if k != "linear"
    )
    lines.append(f"# non-linear models at 100ms SLO: {nonlin}/49 (paper: majority)")
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
