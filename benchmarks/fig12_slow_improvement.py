"""Figure 12: improvement of the slow algorithm (GA+MCTS) over the fast
greedy per GA round, on each simulation workload.  Paper: 1-3% GPUs saved,
monotone (elitism)."""

from __future__ import annotations

from typing import Dict, List

from repro.core import TwoPhaseOptimizer, a100_rules

from benchmarks.common import SIM_WORKLOADS, simulation_profile, simulation_workload


def run(rounds: int = 4) -> Dict[str, List[float]]:
    prof = simulation_profile()
    out = {}
    for name in SIM_WORKLOADS:
        wl = simulation_workload(name, prof)
        opt = TwoPhaseOptimizer(
            a100_rules(), prof, wl, ga_rounds=rounds, ga_population=4,
            mcts_iterations=50, seed=0,
        )
        rep = opt.run()
        base = rep.ga_history[0]
        out[name] = [h / base for h in rep.ga_history]
    return out


def main() -> str:
    res = run()
    lines = ["workload," + ",".join(f"round{i}" for i in range(max(len(v) for v in res.values())))]
    for name, hist in res.items():
        lines.append(name + "," + ",".join(f"{h:.4f}" for h in hist))
    final = {k: v[-1] for k, v in res.items()}
    best = 1.0 - min(final.values())
    lines.append(f"# max improvement over greedy: {best:.1%} (paper: 1-3%)")
    # monotone non-increasing per elitism
    for name, hist in res.items():
        assert all(a >= b for a, b in zip(hist, hist[1:])), name
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
