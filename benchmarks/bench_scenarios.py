"""Scenario-matrix benchmark: which scheduling policy wins under which load.

Runs the declarative scenario matrix (:mod:`repro.sim.scenarios` — trace
shape x scheduler x scale x SLO policy x fault profile x serving model)
through the closed-loop simulator and writes one comparable JSON report,
``BENCH_scenarios.json`` at the repo root: per-cell SLO attainment, GPUs
used (final/peak), in-loop reoptimize latency (mean transition makespan),
modeled power, the paper's headline "GPUs saved vs A100-as-is" (§8.1), on
fault-profile cells availability, recovery time to SLO re-attainment,
reconcile iterations/retries and shed requests, and on token-serving cells
TTFT/TPOT/queue-delay percentiles plus preemption/refusal counts.

The JSON is **seed-deterministic**: same seed => byte-identical file (the
property CI's smoke step and tests/test_scenarios.py pin).  Wall-clock
optimizer timings are printed to stdout only — they must never enter the
report bytes.

Usage::

    PYTHONPATH=src python benchmarks/bench_scenarios.py            # full matrix
    PYTHONPATH=src python benchmarks/bench_scenarios.py --smoke    # CI: tiny
                                                  # matrix, temp output
    PYTHONPATH=src python benchmarks/bench_scenarios.py --seed 7 --out /tmp/x.json
    PYTHONPATH=src python benchmarks/bench_scenarios.py --list     # enumerate
    PYTHONPATH=src python benchmarks/bench_scenarios.py \\
        --cell surge:greedy:small:uniform:gpu_loss  # one cell, no full matrix
    PYTHONPATH=src python benchmarks/bench_scenarios.py \\
        --cell flash:greedy:micro:uniform:none:token  # token serving model
    PYTHONPATH=src python benchmarks/bench_scenarios.py \\
        --cell flash:greedy:micro:uniform:instance_crash:token:mixed
                              # overload cell: priority classes + crash fault
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim.scenarios import (  # noqa: E402
    ScenarioCell,
    default_matrix,
    matrix_doc,
    run_cell,
    run_cell_obs,
    smoke_matrix,
)

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_scenarios.json")


def leaderboard(cells: Dict[str, Dict]) -> List[str]:
    """Per (trace, scale, slo, fault) group: schedulers ranked by peak GPUs,
    ties by mean attainment (higher better) then power (lower better)."""
    groups: Dict[str, List[Dict]] = {}
    for c in cells.values():
        key = "{trace}/{scale}/{slo}/{fault}".format(**c["cell"])
        if c["cell"].get("serving", "fluid") != "fluid":
            key += "/" + c["cell"]["serving"]
        if c["cell"].get("priority", "none") != "none":
            key += "/" + c["cell"]["priority"]
        groups.setdefault(key, []).append(c)
    lines = []
    for key in sorted(groups):
        ranked = sorted(
            groups[key],
            key=lambda c: (c["gpus_peak"], -c["mean_attainment"], c["power_w"]),
        )
        lines.append(
            f"{key}: "
            + "  ".join(
                f"{c['cell']['scheduler']}(gpus={c['gpus_peak']},"
                f" att={c['mean_attainment']:.3f}, saved={c['gpus_saved']})"
                for c in ranked
            )
        )
    return lines


def parse_cell(spec: str) -> ScenarioCell:
    """``trace:sched:scale:slo[:fault[:serving[:priority]]]`` -> a validated
    ScenarioCell."""
    from repro.sim.scenarios import (
        FAULT_PROFILES, PRIORITY_MIXES, SCALES, SCHEDULERS, SLO_POLICIES,
        TRACE_SHAPES,
    )

    parts = spec.split(":")
    if len(parts) not in (4, 5, 6, 7):
        raise SystemExit(
            f"--cell wants trace:sched:scale:slo[:fault[:serving[:priority]]],"
            f" got {spec!r}"
        )
    cell = ScenarioCell(*parts)
    for value, registry, axis in (
        (cell.trace, TRACE_SHAPES, "trace"),
        (cell.scheduler, SCHEDULERS, "scheduler"),
        (cell.scale, SCALES, "scale"),
        (cell.slo, SLO_POLICIES, "slo"),
        (cell.fault, FAULT_PROFILES, "fault"),
        (cell.serving, ("fluid", "token"), "serving"),
        (cell.priority, PRIORITY_MIXES, "priority"),
    ):
        if value not in registry:
            raise SystemExit(
                f"unknown {axis} {value!r}; known: {sorted(registry)}"
            )
    return cell


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny matrix, temp output (CI gate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="output path (default: repo BENCH_scenarios.json)")
    ap.add_argument("--list", action="store_true", dest="list_cells",
                    help="enumerate the default matrix's cells and exit")
    ap.add_argument("--cell", default=None, metavar="SPEC",
                    help="run one cell instead of the full matrix; SPEC is "
                         "trace:sched:scale:slo[:fault[:serving[:priority]]] "
                         "with the last three segments optional (defaults "
                         "none:fluid:none), e.g. "
                         "flash:greedy:micro:uniform:instance_crash:token:mixed"
                         "; an unknown axis value errors with that axis's "
                         "registered names; writes to --out when given, else "
                         "a temp file")
    ap.add_argument("--obs", action="store_true",
                    help="run cells with the flight recorder on "
                         "(SimConfig.observability): per-cell obs metrics in "
                         "each SimReport and a Chrome trace-event export per "
                         "cell via --trace-out.  Cell report SHAs then differ "
                         "from the observability-off baseline by design, so "
                         "never combine with the default BENCH_scenarios.json "
                         "output path")
    ap.add_argument("--trace-out", default=None, metavar="DIR",
                    help="with --obs: write each cell's Chrome trace-event "
                         "JSON (Perfetto-loadable) into DIR as "
                         "<cell name with / -> _>.trace.json")
    args = ap.parse_args()
    if args.trace_out is not None and not args.obs:
        ap.error("--trace-out requires --obs")
    if args.obs and args.out is None and args.cell is None and not args.smoke:
        ap.error("--obs would overwrite BENCH_scenarios.json with "
                 "obs-bearing SHAs; pass an explicit --out")

    if args.list_cells:
        try:
            for cell in default_matrix():
                print(cell.name)
        except BrokenPipeError:  # `--list | head` is fine
            os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0

    if args.cell is not None:
        cells = [parse_cell(args.cell)]
    else:
        cells = smoke_matrix() if args.smoke else default_matrix()
    if args.out:
        out_path = args.out
    elif args.smoke:
        out_path = os.path.join(tempfile.gettempdir(), "BENCH_scenarios_smoke.json")
    elif args.cell is not None:
        out_path = os.path.join(tempfile.gettempdir(), "BENCH_scenarios_cell.json")
    else:
        out_path = DEFAULT_OUT

    if args.trace_out:
        os.makedirs(args.trace_out, exist_ok=True)
    results: Dict[str, Dict] = {}
    for cell in cells:
        t0 = time.perf_counter()
        if args.obs:
            res, _rep, trace_json = run_cell_obs(cell, args.seed)
            if args.trace_out:
                trace_path = os.path.join(
                    args.trace_out,
                    cell.name.replace("/", "_") + ".trace.json",
                )
                with open(trace_path, "w") as f:
                    f.write(trace_json)
                    f.write("\n")
        else:
            res, _rep = run_cell(cell, args.seed)
        wall = time.perf_counter() - t0
        results[cell.name] = res.to_dict()
        # wall-clock goes to stdout only; the JSON stays seed-deterministic
        fault_bits = ""
        if cell.fault != "none":
            rec = (
                f"{res.recovery_time_s:.0f}s"
                if res.recovery_time_s is not None
                else "-"
            )
            fault_bits = (
                f" avail={res.availability:.3f} recovery={rec}"
                f" retried={res.actions_retried}"
                f" shed={res.shed_requests:.0f}"
            )
        token_bits = ""
        if res.token_serving is not None:
            tot = res.token_serving["_totals"]
            ttft_p95 = max(
                (
                    v["ttft_p95_s"]
                    for k, v in res.token_serving.items()
                    if k != "_totals"
                ),
                default=0.0,
            )
            token_bits = (
                f" ttft_p95={ttft_p95:.2f}s preempt={tot['preemptions']}"
                f" refuse={tot['refusals']}"
            )
        if res.priority is not None:
            token_bits += " " + " ".join(
                f"{cls}={v['goodput']}/{v['arrivals']}"
                for cls, v in res.priority.items()
            )
        print(
            f"[{cell.name}] gpus_peak={res.gpus_peak} asis={res.gpus_asis}"
            f" saved={res.gpus_saved} att={res.mean_attainment:.3f}"
            f" reopt_lat={res.reoptimize_latency_s:.0f}s"
            f" power={res.power_w:.0f}W transparent={res.transparent}"
            + fault_bits
            + token_bits
            + f" wall={wall:.2f}s"
        )

    doc = matrix_doc(cells, results, args.seed)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")

    # validate: round-trips as JSON, every cell carries the full schema
    with open(out_path) as f:
        loaded = json.load(f)
    assert loaded["cells"].keys() == results.keys(), "malformed scenario report"
    required = {
        "slo_satisfaction", "mean_attainment", "gpus_peak", "gpus_asis",
        "gpus_saved", "reoptimize_latency_s", "power_w", "report_sha256",
    }
    for name, c in loaded["cells"].items():
        missing = required - c.keys()
        assert not missing, f"cell {name} missing {sorted(missing)}"

    print(f"wrote {out_path} ({len(results)} cells)")
    for line in leaderboard(results):
        print(" ", line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
