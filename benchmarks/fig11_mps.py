"""Figure 11: combining MIG with MPS — up to N processes share one instance.

MPS multiplies instance throughput (imperfectly: shared SMs) at the cost of
isolation.  The paper's observation: MPS lifts the A100-7×1/7 baseline more
than it lifts MIG-Serving, so relative savings shrink (~10% at N=4) but stay
positive.  We model N-process MPS as a throughput multiplier
1 + 0.55·(N-1)^0.7 (saturating sharing efficiency) applied to every
instance's profile, and re-run the savings comparison at N ∈ {1, 2, 4}.
"""

from __future__ import annotations

import math
from typing import Dict, List

from repro.core import (
    ConfigSpace,
    GreedyFast,
    a100_rules,
    baseline_homogeneous,
)
from repro.core.profiles import PerfProfile, SyntheticPaperProfiles

from benchmarks.common import SIM_WORKLOADS, simulation_profile, simulation_workload


class MPSProfile(PerfProfile):
    """Wraps a profile with an N-process MPS throughput multiplier."""

    def __init__(self, base: PerfProfile, n_proc: int):
        self.base = base
        self.mult = 1.0 + 0.55 * (n_proc - 1) ** 0.7 if n_proc > 1 else 1.0

    def services(self):
        return self.base.services()

    def sizes(self):
        return self.base.sizes()

    def latency_ms(self, model, size, batch):
        # N processes split the batch; effective per-request service rate
        # rises by the MPS multiplier
        lat = self.base.latency_ms(model, size, batch)
        return lat / self.mult


def run() -> Dict[str, Dict[int, float]]:
    rules = a100_rules()
    base = simulation_profile()
    out: Dict[str, Dict[int, float]] = {}
    for name in list(SIM_WORKLOADS)[:2]:  # two workloads keep runtime sane
        wl = simulation_workload(name, base)
        out[name] = {}
        for n_proc in (1, 2, 4):
            prof = MPSProfile(base, n_proc)
            mig = GreedyFast(ConfigSpace(rules, prof, wl)).solve().num_gpus
            b17 = baseline_homogeneous(rules, prof, wl, 1)
            b77 = baseline_homogeneous(rules, prof, wl, 7)
            ref = b17 if b17 > 0 else b77
            out[name][n_proc] = 1.0 - mig / ref
    return out


def main() -> str:
    res = run()
    lines = ["workload,mps1_savings,mps2_savings,mps4_savings"]
    for name, row in res.items():
        lines.append(f"{name},{row[1]:.3f},{row[2]:.3f},{row[4]:.3f}")
        # savings shrink as MPS lifts the baseline, but stay positive
        assert row[4] <= row[1] + 0.02
        assert row[4] > 0.0
    lines.append("# savings shrink with more MPS processes (paper Fig 11: ~10% at N=4)")
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
