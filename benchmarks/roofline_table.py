"""§Roofline benchmark: aggregate the dry-run artifacts into the per-(arch ×
shape × mesh) roofline table (compute/memory/collective terms, dominant
bottleneck, MODEL_FLOPS ratio).  Source data: experiments/dryrun/*.json
written by repro.launch.dryrun."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_all(pattern: str = "*.json") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful_flops | peak_mem/dev |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in rows:
        pk = r.get("peak_memory_per_device")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {pk/1e9:.1f} GB |" if pk else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} | - |"
        )
    return "\n".join(lines)


def main() -> str:
    rows = [r for r in load_all() if "__16x16.json" not in "" ]
    single = [r for r in rows if r["mesh"] == "16x16"]
    n_total = len(single)
    dominant = {}
    for r in single:
        dominant[r["dominant"]] = dominant.get(r["dominant"], 0) + 1
    lines = [f"# {len(rows)} dry-run artifacts, {n_total} single-pod baselines"]
    lines.append("dominant_term," + ",".join(f"{k}:{v}" for k, v in sorted(dominant.items())))
    worst = sorted(single, key=lambda r: r["useful_flops_ratio"])[:3]
    for r in worst:
        lines.append(
            f"# worst useful-flops: {r['arch']} {r['shape']} ratio={r['useful_flops_ratio']:.2f}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
