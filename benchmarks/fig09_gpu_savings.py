"""Figure 9: number of GPUs used by each algorithm on the four simulation
workloads — baselines (A100-7/7, A100-7×1/7, A100-MIX), the fast greedy,
MIG-Serving's two-phase algorithm, and the constraint-free lower bound.

Paper claims reproduced: MIG-Serving saves up to ~40% GPUs vs A100-7/7 and
lands within a few % of the lower bound (§8.1).
"""

from __future__ import annotations

from typing import Dict

from repro.core import (
    TwoPhaseOptimizer,
    a100_rules,
    baseline_homogeneous,
    baseline_static_mix,
    lower_bound_gpus,
)

from benchmarks.common import SIM_WORKLOADS, simulation_profile, simulation_workload


def run(ga_rounds: int = 3, mcts_iterations: int = 60) -> Dict[str, Dict[str, float]]:
    rules = a100_rules()
    prof = simulation_profile()
    out: Dict[str, Dict[str, float]] = {}
    for name in SIM_WORKLOADS:
        wl = simulation_workload(name, prof)
        opt = TwoPhaseOptimizer(
            rules, prof, wl, ga_rounds=ga_rounds,
            ga_population=4, mcts_iterations=mcts_iterations, seed=0,
        )
        rep = opt.run()
        row = {
            "A100-7/7": baseline_homogeneous(rules, prof, wl, 7),
            "A100-7x1/7": baseline_homogeneous(rules, prof, wl, 1),
            "A100-MIX": baseline_static_mix(rules, prof, wl),
            "greedy": rep.fast_deployment.num_gpus,
            "MIG-Serving": rep.best_deployment.num_gpus,
            "lower-bound": lower_bound_gpus(rules, prof, wl),
        }
        row["savings_vs_7/7"] = 1.0 - row["MIG-Serving"] / row["A100-7/7"]
        row["gap_to_lower_bound"] = row["MIG-Serving"] / row["lower-bound"] - 1.0
        out[name] = row
    return out


def main() -> str:
    res = run()
    lines = ["workload," + ",".join(next(iter(res.values())).keys())]
    for name, row in res.items():
        lines.append(
            name + "," + ",".join(
                f"{v:.3f}" if isinstance(v, float) else str(v) for v in row.values()
            )
        )
    best = max(r["savings_vs_7/7"] for r in res.values())
    lines.append(f"# max savings vs A100-7/7: {best:.1%} (paper: up to 40%)")
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
