"""Golden regression tests pinning seeded optimizer outputs.

The array-native optimizer core (indexed deployments, batched fitness,
vectorized greedy/MCTS paths) must not change what the algorithms *decide*:
same seed => the same configs in the same order.  These tests compare the
seeded outputs of ``GreedyFast``, ``MCTSSlow`` and ``GeneticOptimizer`` —
plus a SHA-256 of a full closed-loop ``SimReport.to_json()`` (the repo's
determinism contract) — against ``tests/golden/optimizer_golden.json``.

Greedy, GA, and the simulator hash are bit-identical to the
pre-vectorization implementation.  The standalone MCTS entries were
re-recorded once when top-K cuts moved from ``np.argsort`` to
``np.argpartition``: configs with *exactly* equal scores are now ordered by
ascending config index (well-defined, numpy-version-stable) instead of
quicksort's unspecified tie order; solution sizes are unchanged.

Regenerate (only when behavior is *intentionally* changed) with::

    PYTHONPATH=src python tests/test_optimizer_golden.py --regen
"""

import hashlib
import json
import os
import sys

import numpy as np

if __name__ == "__main__":  # regen mode runs without pytest/conftest
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (
    SLO,
    ConfigSpace,
    Deployment,
    GeneticOptimizer,
    GreedyFast,
    MCTSSlow,
    SyntheticPaperProfiles,
    Workload,
    a100_rules,
    tpu_slice_rules,
)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "optimizer_golden.json")

# (name, n_models, profile seed, slo lognormal scale, rules factory)
PROBLEMS = [
    ("a100_n6", 6, 3, 7.4, a100_rules),
    ("a100_n10", 10, 5, 8.2, a100_rules),
]


def _problem(n, seed, scale, rules_factory):
    sizes = (1, 2, 4, 8, 16) if rules_factory is tpu_slice_rules else (1, 2, 3, 4, 7)
    prof = SyntheticPaperProfiles(n_models=n, seed=seed, sizes=sizes)
    rng = np.random.default_rng(seed)
    slos = {m: SLO(float(rng.lognormal(scale, 0.7)), 100.0) for m in prof.services()}
    wl = Workload.make(slos)
    return prof, wl, ConfigSpace(rules_factory(), prof, wl)


def _canon(cfg):
    """JSON-able canonical form of one GPU config."""
    return [[int(s), svc, int(b)] for (s, svc, b) in cfg.canonical()]


def _deployment_record(configs, wl):
    dep = Deployment(list(configs))
    return {
        "configs": [_canon(c) for c in configs],  # order preserved
        "num_gpus": dep.num_gpus,
        "completion": [float(x) for x in dep.completion_rates(wl)],
    }


def compute_golden():
    golden = {"schema": 1, "problems": {}}
    for name, n, seed, scale, rules_factory in PROBLEMS:
        prof, wl, space = _problem(n, seed, scale, rules_factory)
        entry = {}

        entry["greedy"] = _deployment_record(
            GreedyFast(space).produce(np.zeros(wl.n)), wl
        )
        entry["greedy_partial"] = _deployment_record(
            GreedyFast(space).produce(np.full(wl.n, 0.55)), wl
        )

        for mseed in (0, 7):
            cfgs = MCTSSlow(space, iterations=80, seed=mseed).produce(np.zeros(wl.n))
            entry[f"mcts_seed{mseed}"] = _deployment_record(cfgs, wl)

        seed_dep = Deployment(GreedyFast(space).produce(np.zeros(wl.n)))
        for slow_name in ("greedy", "mcts"):
            slow = (
                GreedyFast(space)
                if slow_name == "greedy"
                else MCTSSlow(space, iterations=40, seed=0)
            )
            res = GeneticOptimizer(
                space, slow, population=4, rounds=3, seed=0
            ).run(seed_dep)
            entry[f"ga_{slow_name}"] = {
                "best": sorted(_canon(c) for c in res.best.configs),
                "num_gpus": res.best.num_gpus,
                "history": list(res.history),
            }
        golden["problems"][name] = entry

    # TPU rule-set greedy (different partition universe)
    prof, wl, space = _problem(5, 3, 7.0, tpu_slice_rules)
    golden["problems"]["tpu_n5"] = {
        "greedy": _deployment_record(GreedyFast(space).produce(np.zeros(wl.n)), wl)
    }

    # closed-loop simulator: the determinism contract, hashed
    from repro.sim import ClusterSimulator, SimConfig, diurnal_trace

    sprof = SyntheticPaperProfiles(n_models=5, seed=9)
    rng = np.random.default_rng(42)
    peaks = {m: float(rng.lognormal(7.0, 0.5)) for m in sprof.services()}
    trace = diurnal_trace(peaks, duration_s=2 * 3600.0, bin_s=60.0,
                          night_frac=0.25, seed=0)
    rep = ClusterSimulator(
        a100_rules(), sprof, trace, SimConfig(seed=0, reoptimize_every_s=1800.0)
    ).run()
    blob = rep.to_json()
    golden["sim"] = {
        "sha256": hashlib.sha256(blob.encode()).hexdigest(),
        "bytes": len(blob),
        "transitions": len(rep.transitions),
        "final_gpus": rep.final_gpus,
    }
    return golden


def _load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def test_golden_file_exists():
    assert os.path.exists(GOLDEN_PATH), (
        "golden file missing — regenerate with "
        "`PYTHONPATH=src python tests/test_optimizer_golden.py --regen`"
    )


def test_seeded_outputs_match_golden():
    got = compute_golden()
    want = _load_golden()
    # compare piecewise for readable failures
    assert sorted(got["problems"]) == sorted(want["problems"])
    for name, entry in want["problems"].items():
        for key, val in entry.items():
            assert got["problems"][name][key] == val, (
                f"{name}/{key} diverged from the recorded seed behavior"
            )
    assert got["sim"] == want["sim"], (
        "SimReport.to_json() is no longer byte-identical to the recorded run"
    )


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        data = compute_golden()
        with open(GOLDEN_PATH, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH} ({os.path.getsize(GOLDEN_PATH)} bytes)")
    else:
        print(__doc__)
