"""Ragged continuous-batching oracle + engine admission-control behavior.

The pin for PR 5's rebuilt engine: a multi-slot engine with staggered
admissions must produce *byte-identical* ``out_tokens`` to decoding each
request alone in a batch-1 engine — on both the flat and the paged KV
backend.  Any cross-slot KV corruption, shared decode position, or bad
page-table wiring breaks token equality immediately.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import Engine, OutOfPages, Request, run_closed_loop

MAX_LEN = 64
NEW_TOKENS = 6

_CACHE = {}


def model_and_params(arch):
    if arch not in _CACHE:
        cfg = get_smoke_config(arch)
        m = Model(cfg, remat=False)
        params, _ = m.init(jax.random.PRNGKey(0))
        _CACHE[arch] = (m, params)
    return _CACHE[arch]


def make_prompts(cfg, lengths, seed=7):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(1, cfg.vocab_size, size=L).astype(np.int32) for L in lengths
    ]


def solo_tokens(m, params, prompt, new_tokens=NEW_TOKENS):
    """The oracle: the request decoded alone in a batch-1 flat engine."""
    eng = Engine(m, params, batch=1, max_len=MAX_LEN, kv_backend="flat")
    req = Request(rid=0, prompt=prompt, max_new_tokens=new_tokens)
    run_closed_loop(eng, [req])
    return list(req.out_tokens)


@pytest.mark.parametrize("backend", ["flat", "paged"])
@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-370m"])
def test_ragged_oracle_staggered_admits(arch, backend):
    """≥3 requests of different prompt lengths, admitted at staggered steps:
    every request's out_tokens is byte-identical to its solo decode."""
    m, params = model_and_params(arch)
    prompts = make_prompts(m.cfg, (3, 5, 9))
    solo = [solo_tokens(m, params, p) for p in prompts]
    eng = Engine(m, params, batch=3, max_len=MAX_LEN, kv_backend=backend)
    reqs = [
        Request(rid=i, prompt=p, max_new_tokens=NEW_TOKENS)
        for i, p in enumerate(prompts)
    ]
    eng.admit(reqs[0])
    eng.step()
    eng.step()
    eng.admit(reqs[1])
    eng.step()
    eng.admit(reqs[2])
    while eng.num_live:
        eng.step()
    for req, want in zip(reqs, solo):
        assert req.out_tokens == want, (req.rid, req.out_tokens, want)


@pytest.mark.parametrize("backend", ["flat", "paged"])
def test_ragged_oracle_slot_reuse(backend):
    """More requests than slots through run_closed_loop: freed slots are
    re-admitted at new offsets and the oracle still holds for every request."""
    m, params = model_and_params("qwen3-8b")
    prompts = make_prompts(m.cfg, (4, 7, 3, 6, 5), seed=11)
    solo = [solo_tokens(m, params, p) for p in prompts]
    eng = Engine(m, params, batch=2, max_len=MAX_LEN, kv_backend=backend)
    reqs = [
        Request(rid=i, prompt=p, max_new_tokens=NEW_TOKENS)
        for i, p in enumerate(prompts)
    ]
    stats = run_closed_loop(eng, reqs)
    assert stats.served == len(reqs)
    for req, want in zip(reqs, solo):
        assert req.out_tokens == want, (req.rid, req.out_tokens, want)


def test_admission_refused_on_pool_exhaustion_then_recovers():
    """A pool too small for the full batch refuses admission (OutOfPages, no
    silent clamp); run_closed_loop completes everything once slots free up,
    and all pages return to the pool."""
    m, params = model_and_params("qwen3-8b")
    eng = Engine(
        m, params, batch=3, max_len=MAX_LEN,
        kv_backend="paged", page_size=4, num_pages=6,
    )
    reqs = [
        Request(rid=i, prompt=np.arange(1, 8, dtype=np.int32), max_new_tokens=2)
        for i in range(4)
    ]
    # 7-token context + 1 decode slot = 2 pages each; 3 concurrent exhaust
    # the pool, so the 4th admission must be refused (and retried later) —
    # never silently clamped into another slot's pages.
    with pytest.raises(OutOfPages):
        e2 = Engine(m, params, batch=3, max_len=MAX_LEN,
                    kv_backend="paged", page_size=4, num_pages=1)
        e2.admit(Request(rid=99, prompt=np.arange(1, 8, dtype=np.int32),
                         max_new_tokens=2))
    stats = run_closed_loop(eng, reqs)
    assert stats.served == 4
    assert stats.preempted == 0  # refusal path only: nobody grows past 2 pages
    assert all(r.done for r in reqs)
    assert eng.pool.free_pages == eng.pool.num_pages


def test_mid_decode_exhaustion_preempts_and_completes():
    """When a request cannot grow mid-decode it is preempted (pages released,
    restarted later with its generated tokens folded into the prompt) and
    still finishes with the full token budget."""
    m, params = model_and_params("qwen3-8b")
    eng = Engine(
        m, params, batch=3, max_len=MAX_LEN,
        kv_backend="paged", page_size=4, num_pages=5,
    )
    reqs = [
        Request(rid=i, prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=8)
        for i in range(4)
    ]
    stats = run_closed_loop(eng, reqs)
    assert stats.served == 4
    assert stats.preempted > 0
    assert all(len(r.out_tokens) == 8 for r in reqs)
    assert eng.pool.free_pages == eng.pool.num_pages


def test_preempt_at_context_cap_finishes_truncated():
    """A request preempted with no room left to resume (context cap) is
    finished truncated — like the non-preempted max_len path — instead of
    crashing re-admission."""
    m, params = model_and_params("qwen3-8b")
    eng = Engine(m, params, batch=2, max_len=13,
                 kv_backend="paged", page_size=4, num_pages=4)
    reqs = [
        Request(rid=0, prompt=np.arange(1, 12, dtype=np.int32), max_new_tokens=8),
        Request(rid=1, prompt=np.arange(1, 4, dtype=np.int32), max_new_tokens=8),
    ]
    stats = run_closed_loop(eng, reqs)
    assert stats.served == 2
    assert reqs[1].done  # small request gets its full budget
    assert 0 < len(reqs[0].out_tokens) <= 8  # truncated at the context cap
    assert eng.pool.free_pages == eng.pool.num_pages


def test_unservable_request_does_not_block_later_requests():
    """First-fit admission: a request the pool can never hold must not
    head-of-line block admittable requests behind it; the loop serves them,
    then raises honestly for the stuck one."""
    m, params = model_and_params("qwen3-8b")
    eng = Engine(m, params, batch=2, max_len=20,
                 kv_backend="paged", page_size=4, num_pages=4)
    big = Request(rid=0, prompt=np.arange(1, 12, dtype=np.int32),
                  max_new_tokens=12)  # grows past the whole pool
    small = Request(rid=1, prompt=np.arange(1, 8, dtype=np.int32),
                    max_new_tokens=4)
    with pytest.raises(RuntimeError):
        run_closed_loop(eng, [big, small])
    assert small.done
    assert not big.done


def test_failed_prefill_releases_pool_reservation():
    """If prefill (or scatter) raises after the pages were reserved, the
    reservation is rolled back: the free list is byte-identical and a retry
    of the same rid succeeds instead of tripping the pool's rid assert."""
    m, params = model_and_params("qwen3-8b")
    eng = Engine(m, params, batch=2, max_len=MAX_LEN,
                 kv_backend="paged", page_size=4, num_pages=8)
    free_before = list(eng.pool._free)
    req = Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                  max_new_tokens=2)
    good_prefill = eng._prefill

    def boom(*a, **k):
        raise RuntimeError("injected prefill failure")

    eng._prefill = boom
    with pytest.raises(RuntimeError, match="injected"):
        eng.admit(req)
    assert eng.pool._free == free_before  # byte-identical pool
    assert eng.num_live == 0 and eng.slots == [None, None]
    assert req.out_tokens == []
    eng._prefill = good_prefill
    eng.admit(req)  # same rid re-admits cleanly
    while eng.num_live:
        eng.step()
    assert req.done
    assert eng.pool.free_pages == eng.pool.num_pages


def test_top_k_ties_sample_exactly_k():
    """top_k=k with tied logits must sample from exactly k candidates
    (deterministic lowest-index tie order), never from every tied logit."""
    m, params = model_and_params("qwen3-8b")
    eng = Engine(m, params, batch=1, max_len=MAX_LEN,
                 temperature=1.0, top_k=2)
    logits = np.zeros(8, np.float32)
    logits[[1, 3, 6]] = 5.0  # three-way tie for the top-2 cut
    rng = np.random.default_rng(0)
    drawn = {eng._sample(logits, rng) for _ in range(200)}
    assert drawn == {1, 3}  # stable order keeps the lowest tied indices


def test_equal_rid_requests_are_identity_compared():
    """Two distinct requests sharing a rid (and prompt bytes) must not make
    run_closed_loop's pending.remove() raise on numpy array equality."""
    m, params = model_and_params("qwen3-8b")
    eng = Engine(m, params, batch=1, max_len=MAX_LEN, kv_backend="flat")
    prompt = np.arange(1, 5, dtype=np.int32)
    r1 = Request(rid=7, prompt=prompt.copy(), max_new_tokens=2)
    r2 = Request(rid=7, prompt=prompt.copy(), max_new_tokens=2)
    assert r1 != r2  # identity, not field, comparison
    stats = run_closed_loop(eng, [r1, r2])
    assert stats.served == 2
    assert r1.done and r2.done
    assert r1.out_tokens == r2.out_tokens  # same prompt => same argmax tokens
    # the run_closed_loop calibration hooks observed per-request latencies
    assert len(stats.ttft_s) == 2 and all(t >= 0.0 for t in stats.ttft_s)
    assert len(stats.tpot_s) == 2


def test_admit_rejects_context_longer_than_max_len():
    m, params = model_and_params("qwen3-8b")
    eng = Engine(m, params, batch=1, max_len=8)
    with pytest.raises(ValueError):
        eng.admit(Request(rid=0, prompt=np.arange(1, 10, dtype=np.int32),
                          max_new_tokens=2))


def test_seeded_sampling_reproducible_and_argmax_at_zero():
    """temperature=0 is argmax (the deterministic default); temperature>0
    draws from the seeded rng and reproduces exactly for the same seed."""
    m, params = model_and_params("qwen3-8b")
    prompts = make_prompts(m.cfg, (4, 4, 4), seed=3)

    def run(temp, seed):
        eng = Engine(m, params, batch=2, max_len=MAX_LEN,
                     temperature=temp, top_k=8)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        run_closed_loop(eng, reqs, seed=seed)
        return [list(r.out_tokens) for r in reqs]

    assert run(0.0, 0) == run(0.0, 1)  # argmax ignores the rng
    assert run(0.8, 5) == run(0.8, 5)
    assert run(0.8, 5) != run(0.8, 6)


def test_measured_profile_feedback_loop():
    """§8.3: run_closed_loop feeds measured throughput into a
    MeasuredProfile, which the optimizer-side latency query then reflects."""
    from repro.core.arch_bridge import tpu_arch_profiles
    from repro.core.online_profiles import MeasuredProfile

    m, params = model_and_params("qwen3-8b")
    measured = MeasuredProfile(tpu_arch_profiles(["qwen3-8b"]))
    eng = Engine(m, params, batch=2, max_len=MAX_LEN)
    reqs = [
        Request(rid=i, prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=3)
        for i in range(4)
    ]
    run_closed_loop(eng, reqs, measured=measured, service="qwen3-8b", size=16)
    corr = measured.correction("qwen3-8b", 16)
    assert corr != 1.0
    base = measured.base.latency_ms("qwen3-8b", 16, 8)
    assert measured.latency_ms("qwen3-8b", 16, 8) == pytest.approx(base / corr)
