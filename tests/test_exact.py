"""Exact pair-space solver + universal lower bound (beyond-paper)."""

import numpy as np
import pytest

from repro.core import (
    SLO,
    ConfigSpace,
    GreedyFast,
    SyntheticPaperProfiles,
    Workload,
    a100_rules,
    lower_bound_gpus,
)
from repro.core.exact import PairSpaceExact, per_service_lower_bound


def small(seed, n=3, scale=6.0):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(seed)
    wl = Workload.make(
        {m: SLO(float(rng.lognormal(scale, 0.5)), 100.0) for m in prof.services()}
    )
    return prof, wl


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_exact_not_worse_than_greedy_and_bounded(seed):
    prof, wl = small(seed)
    space = ConfigSpace(a100_rules(), prof, wl)
    greedy = GreedyFast(space).solve()
    exact, done = PairSpaceExact(space, node_limit=300_000).solve(greedy)
    assert exact.is_valid(wl)
    assert exact.num_gpus <= greedy.num_gpus
    lb = max(lower_bound_gpus(a100_rules(), prof, wl), per_service_lower_bound(space))
    assert exact.num_gpus >= lb
    if done:
        # certified optimum over the pair space
        assert exact.num_gpus <= greedy.num_gpus


def test_per_service_bound_is_valid():
    """The universal per-service bound never exceeds the certified optimum
    (it complements the LP bound; for balanced workloads LP dominates)."""
    for seed in range(4):
        prof, wl = small(seed)
        space = ConfigSpace(a100_rules(), prof, wl)
        ps = per_service_lower_bound(space)
        assert ps >= 1
        greedy = GreedyFast(space).solve()
        exact, done = PairSpaceExact(space, node_limit=200_000).solve(greedy)
        assert ps <= exact.num_gpus
