"""Hypothesis, or a seeded stand-in when the library is absent.

The tier-1 suite must collect and run in environments without
``hypothesis`` (the CI job matrix pins both cases).  Test modules import
the property-testing surface from here::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

When the real library is installed these are simply re-exports.  Otherwise
a minimal fallback provides the same decorator API driving a *fixed seeded
sample*: each ``@given`` test runs ``max_examples`` deterministic examples
drawn from a PRNG seeded by the test's qualified name — no shrinking, no
database, but the same strategies vocabulary and reproducible inputs.

Only the strategy combinators this repo uses are implemented
(``integers``, ``sampled_from``, ``lists``, ``booleans``, ``floats``,
``tuples``, ``just``); extend the fallback when a test needs more.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    from hypothesis import assume, HealthCheck  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # -- seeded fallback ------------------------------------
    import functools
    import random

    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 100

    class _Strategy:
        """A draw function over a ``random.Random``."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: "random.Random"):
            return self._draw(rng)

    class _strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: min_value + (max_value - min_value) * rng.random()
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strategies)
            )

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

    st = _strategies()

    class HealthCheck:
        all = staticmethod(lambda: [])
        too_slow = data_too_large = filter_too_much = None

    def assume(condition) -> bool:
        """Fallback semantics: a failed assumption just skips the example
        by raising, caught in the runner below."""
        if not condition:
            raise _AssumptionFailed()
        return True

    class _AssumptionFailed(Exception):
        pass

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Records ``max_examples`` for ``given`` (deadline etc. ignored)."""

        def decorate(func):
            func._compat_max_examples = max_examples
            return func

        return decorate

    def given(*pos_strategies, **kw_strategies):
        """Run the test over a fixed seeded sample of strategy draws.

        Mirrors hypothesis' calling convention: positional strategies fill
        the test's trailing positional parameters, keyword strategies its
        named parameters.  The PRNG seed is the test's qualified name, so
        inputs are stable across runs and processes.
        """

        def decorate(func):
            @functools.wraps(func)
            def wrapper(*args, **kwargs):
                # read at call time: @settings may sit either above or below
                # @given (both orders are valid in real hypothesis)
                max_examples = getattr(
                    wrapper,
                    "_compat_max_examples",
                    getattr(func, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES),
                )
                rng = random.Random(f"{func.__module__}.{func.__qualname__}")
                ran = 0
                attempts = 0
                while ran < max_examples and attempts < max_examples * 10:
                    attempts += 1
                    gen_pos = tuple(s.example(rng) for s in pos_strategies)
                    gen_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                    try:
                        func(*args, *gen_pos, **gen_kw, **kwargs)
                    except _AssumptionFailed:
                        continue
                    ran += 1
                if max_examples > 0 and ran == 0:
                    # mirror hypothesis' Unsatisfiable: a test that ran zero
                    # examples must not silently pass
                    raise RuntimeError(
                        f"{func.__qualname__}: assume() rejected all "
                        f"{attempts} generated examples"
                    )

            # pytest must not introspect the wrapped signature for fixtures
            # (the strategy parameters are not fixtures)
            del wrapper.__wrapped__
            wrapper.hypothesis_compat_fallback = True
            return wrapper

        return decorate
