"""Scheduler-zoo tests: golden pins + properties for the two new policies.

The fragmentation-aware packer and the energy-aware repartitioner
(:mod:`repro.core.zoo`) are deterministic, so their outputs on seeded
problems are pinned byte-for-byte in ``tests/golden/scheduler_zoo_golden.json``
— the same contract the optimizer goldens enforce.  Regenerate (only on
intentional behavior changes) with::

    PYTHONPATH=src python tests/test_scheduler_zoo.py --regen

Property coverage: validity of produced deployments from arbitrary starting
completions, produce/produce_indexed agreement, the fragmentation and power
models themselves, and registry integration through ``TwoPhaseOptimizer``
and the closed-loop driver.
"""

import json
import os
import sys

import numpy as np
import pytest

if __name__ == "__main__":  # regen mode runs without pytest/conftest
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_compat import given, settings, st

from repro.core import (
    SLO,
    ConfigSpace,
    Deployment,
    EnergyAwareRepartitioner,
    FragAwarePacker,
    GPUConfig,
    InstanceAssignment,
    PowerModel,
    SyntheticPaperProfiles,
    TwoPhaseOptimizer,
    Workload,
    a100_rules,
    deployment_power,
    stranded_slices_of,
)
from repro.core.optimizer import FAST_ALGORITHMS, SLOW_ALGORITHMS

GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden", "scheduler_zoo_golden.json"
)

# (name, n_models, profile seed, slo lognormal scale) — mirrors the
# optimizer-golden problems so zoo behavior is pinned on the same terrain
PROBLEMS = [
    ("a100_n6", 6, 3, 7.4),
    ("a100_n10", 10, 5, 8.2),
]

ZOO = {
    "frag": lambda s: FragAwarePacker(s),
    "energy": lambda s: EnergyAwareRepartitioner(s),
}


def _problem(n, seed, scale):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(seed)
    slos = {m: SLO(float(rng.lognormal(scale, 0.7)), 100.0) for m in prof.services()}
    wl = Workload.make(slos)
    return prof, wl, ConfigSpace(a100_rules(), prof, wl)


def _canon(cfg):
    return [[int(s), svc, int(b)] for (s, svc, b) in cfg.canonical()]


def compute_golden():
    golden = {"schema": 1, "problems": {}}
    for name, n, seed, scale in PROBLEMS:
        prof, wl, space = _problem(n, seed, scale)
        entry = {}
        for zoo_name, make in ZOO.items():
            algo = make(space)
            for tag, completion in (
                ("", np.zeros(wl.n)),
                ("_partial", np.full(wl.n, 0.55)),
            ):
                cfgs = algo.produce(completion)
                dep = Deployment(list(cfgs))
                entry[zoo_name + tag] = {
                    "configs": [_canon(c) for c in cfgs],  # order preserved
                    "num_gpus": dep.num_gpus,
                    "power_w": deployment_power(cfgs),
                }
        golden["problems"][name] = entry
    return golden


def _load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


# -- golden pins -----------------------------------------------------------------


def test_zoo_golden_file_exists():
    assert os.path.exists(GOLDEN_PATH), (
        "golden file missing — regenerate with "
        "`PYTHONPATH=src python tests/test_scheduler_zoo.py --regen`"
    )


def test_zoo_seeded_outputs_match_golden():
    got = compute_golden()
    want = _load_golden()
    assert sorted(got["problems"]) == sorted(want["problems"])
    for name, entry in want["problems"].items():
        for key, val in entry.items():
            assert got["problems"][name][key] == val, (
                f"{name}/{key} diverged from the recorded zoo behavior"
            )


# -- validity / indexed agreement -------------------------------------------------


@given(seed=st.integers(0, 40))
@settings(max_examples=8, deadline=None)
def test_zoo_produce_covers_need_and_matches_indexed(seed):
    _, wl, space = _problem(6, 3, 7.4)
    rng = np.random.default_rng(seed)
    start = rng.uniform(0.0, 0.95, size=wl.n)
    for make in ZOO.values():
        algo = make(space)
        cfgs = algo.produce(start)
        total = start.copy()
        for c in cfgs:
            total = total + c.utility(wl)
        assert bool(np.all(total >= 1.0 - 1e-9))
        idep = make(space).produce_indexed(start)
        assert idep.num_gpus == len(cfgs)
        assert not idep.extras  # zoo picks stay inside the enumerated space
        assert sorted(c.canonical() for c in idep.to_deployment().configs) == sorted(
            c.canonical() for c in cfgs
        )


def test_zoo_is_deterministic_across_runs():
    _, wl, space = _problem(6, 3, 7.4)
    z = np.zeros(wl.n)
    for make in ZOO.values():
        a = [c.canonical() for c in make(space).produce(z)]
        b = [c.canonical() for c in make(space).produce(z)]
        assert a == b


# -- fragmentation model ----------------------------------------------------------


def test_stranded_slices_zero_for_fully_busy_and_positive_for_idle():
    rules = a100_rules()
    busy = GPUConfig(
        (3, 4),
        (
            InstanceAssignment(3, "a", 8, 100.0),
            InstanceAssignment(4, "a", 8, 150.0),
        ),
    )
    assert stranded_slices_of(busy, rules) == 0.0
    idle = GPUConfig(
        (3, 4),
        (
            InstanceAssignment(3, "a", 8, 100.0),
            InstanceAssignment(4, None),
        ),
    )
    # free=4, largest reusable chunk covers all of it -> half-cost residual
    assert stranded_slices_of(idle, rules) == pytest.approx(2.0)
    # fragmented free: two 1-slice holes reuse worse than one 2-slice hole
    frag2 = GPUConfig(
        (1, 1, 1, 4),
        (
            InstanceAssignment(1, None),
            InstanceAssignment(1, None),
            InstanceAssignment(1, "a", 4, 30.0),
            InstanceAssignment(4, "a", 8, 150.0),
        ),
    )
    assert stranded_slices_of(frag2, rules) > stranded_slices_of(idle, rules) - 2.0
    assert stranded_slices_of(frag2, rules) == pytest.approx(1.5)  # 2 - 1 + 0.5


def test_frag_packer_prefers_unfragmented_config_at_equal_base_score():
    """The packer's score hook must rank a full device above a config that
    strands slices when both offer the same need-weighted utility."""
    _, wl, space = _problem(6, 3, 7.4)
    packer = FragAwarePacker(space)
    need = np.ones(wl.n)
    scores = packer._scores(need)
    base = need[space.ia] * space.ua + need[space.ib] * space.ub
    # discounting never raises a score, and strictly lowers stranded configs
    assert np.all(scores <= base + 1e-12)
    stranded = packer.static_frag > 0
    if stranded.any():
        assert np.all(scores[stranded] < base[stranded])


# -- power model ------------------------------------------------------------------


def test_power_model_prefers_fewer_larger_instances():
    pm = PowerModel()
    one_big = GPUConfig((7,), (InstanceAssignment(7, "a", 8, 700.0),))
    many_small = GPUConfig(
        (1,) * 7, tuple(InstanceAssignment(1, "a", 1, 100.0) for _ in range(7))
    )
    assert pm.config_power(one_big) < pm.config_power(many_small)
    # equal busy slices: the difference is exactly the instance overhead
    assert pm.config_power(many_small) - pm.config_power(one_big) == pytest.approx(
        6 * pm.instance_w
    )
    # instances_power mirrors config_power for a one-GPU instance set
    assert pm.instances_power([("a", 7, 700.0)], gpus_in_use=1) == pytest.approx(
        pm.config_power(one_big)
    )


def test_energy_weights_monotone_in_power():
    _, wl, space = _problem(6, 3, 7.4)
    algo = EnergyAwareRepartitioner(space)
    order = np.argsort(algo.power)
    w = algo.weights[order]
    assert np.all(np.diff(w) <= 1e-12)  # heavier configs never weigh more


# -- registry / closed-loop integration -------------------------------------------


def test_zoo_registered_in_both_registries():
    for name in ("frag", "energy"):
        assert name in FAST_ALGORITHMS and name in SLOW_ALGORITHMS


@pytest.mark.parametrize("fast", ["frag", "energy"])
def test_two_phase_with_zoo_fast_algorithm(fast):
    prof, wl, space = _problem(5, 3, 7.2)
    opt = TwoPhaseOptimizer(
        a100_rules(), prof, wl, fast=fast, ga_rounds=2, ga_population=3, space=space
    )
    rep = opt.run()
    assert rep.fast_deployment.is_valid(wl)
    assert rep.best_deployment.is_valid(wl)
    assert rep.best_deployment.num_gpus <= rep.fast_deployment.num_gpus


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        data = compute_golden()
        with open(GOLDEN_PATH, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH} ({os.path.getsize(GOLDEN_PATH)} bytes)")
    else:
        print(__doc__)
