"""Property tests pinning the array-native optimizer core to the scalar
reference semantics.

The vectorized paths (count-vector completions, batched GA fitness, the
packed-candidate scan, the dense utility matrix) must reproduce the legacy
per-config Python loops *float-for-float* — that equality is what lets the
refactor keep seeded greedy/GA outputs and `SimReport.to_json()` bytes
unchanged.  Each test states the exact reference loop it checks against.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    SLO,
    ConfigSpace,
    Deployment,
    GreedyFast,
    IndexedDeployment,
    SyntheticPaperProfiles,
    Workload,
    a100_rules,
    fitness_batch,
    mutate_swap,
)
from repro.core.ga import _fitness
from repro.core.mcts import MCTSSlow, _bucket_signature, _top_k_desc


def make_problem(n=6, seed=3, scale=7.4):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(seed)
    slos = {m: SLO(float(rng.lognormal(scale, 0.7)), 100.0) for m in prof.services()}
    wl = Workload.make(slos)
    return prof, wl, ConfigSpace(a100_rules(), prof, wl)


def random_deployment(space, rng):
    """A deployment mixing enumerated configs (some repeated) and a mutant."""
    k = int(rng.integers(3, 12))
    idx = rng.integers(0, len(space), size=k)
    dep = Deployment([space.configs[int(i)] for i in idx])
    return mutate_swap(dep, rng, swaps=3)


# -- Workload --------------------------------------------------------------------


def test_workload_index_matches_linear_scan():
    _, wl, _ = make_problem()
    for svc in wl.services:
        scanned = next(s.index for s in wl.services if s.name == svc.name)
        assert wl.index(svc.name) == scanned
    with pytest.raises(KeyError):
        wl.index("no-such-service")


# -- count-vector completions ----------------------------------------------------


@given(seed=st.integers(0, 40))
@settings(max_examples=10, deadline=None)
def test_completion_of_counts_exactly_matches_scalar_loop(seed):
    """Reference: two index-order accumulation loops (a-side then b-side),
    summed — precisely what the two np.bincount gathers compute."""
    _, wl, space = make_problem(seed=3)
    rng = np.random.default_rng(seed)
    counts = np.zeros(len(space), dtype=np.int64)
    hot = rng.integers(0, len(space), size=int(rng.integers(1, 30)))
    for i in hot:
        counts[int(i)] += 1

    acc_a = np.zeros(wl.n)
    acc_b = np.zeros(wl.n)
    for i in np.flatnonzero(counts):
        w = float(counts[i])
        acc_a[space.ia[i]] += w * space.ua[i]
        acc_b[space.ib[i]] += w * space.ub[i]
    ref = acc_a + acc_b

    got = space.completion_of_counts(counts)
    assert np.array_equal(got, ref)  # exact float equality


def test_util_matrix_rows_equal_utility_of():
    _, _, space = make_problem()
    for i in range(0, len(space), max(1, len(space) // 60)):
        assert np.array_equal(space.util_matrix[i], space.utility_of(i))


def test_count_matrix_completion_matches_single_rows():
    # the batched matmul path is the throughput-oriented API: BLAS blocking
    # differs between the 2D and per-row kernels, so its contract is
    # numerical (1e-9), not bitwise like the bincount path above
    _, wl, space = make_problem()
    rng = np.random.default_rng(0)
    counts = rng.integers(0, 3, size=(5, len(space)))
    batch = space.completion_of_count_matrix(counts.astype(np.float64))
    for p in range(counts.shape[0]):
        ref = space.completion_of_counts(counts[p])
        np.testing.assert_allclose(batch[p], ref, rtol=1e-9, atol=1e-12)


# -- IndexedDeployment -----------------------------------------------------------


@given(seed=st.integers(0, 40))
@settings(max_examples=10, deadline=None)
def test_indexed_deployment_round_trip_and_completion(seed):
    _, wl, space = make_problem(seed=3)
    rng = np.random.default_rng(seed)
    dep = random_deployment(space, rng)
    idep = IndexedDeployment.from_deployment(space, dep)
    assert idep.num_gpus == dep.num_gpus
    back = idep.to_deployment()
    assert sorted(c.canonical() for c in back.configs) == sorted(
        c.canonical() for c in dep.configs
    )
    np.testing.assert_allclose(
        idep.completion_rates(), dep.completion_rates(wl), rtol=1e-9, atol=1e-12
    )


def test_greedy_produce_indexed_consistent_with_produce():
    _, wl, space = make_problem(n=7, seed=5, scale=7.8)
    configs = GreedyFast(space).produce(np.zeros(wl.n))
    idep = GreedyFast(space).produce_indexed(np.zeros(wl.n))
    assert idep.num_gpus == len(configs)
    assert sorted(c.canonical() for c in idep.to_deployment().configs) == sorted(
        c.canonical() for c in configs
    )
    assert idep.is_valid()
    # the generic OptimizerProcedure.solve_indexed round-trips the same way
    sdep = GreedyFast(space).solve_indexed()
    assert sdep.num_gpus == len(configs) and sdep.is_valid()


def test_two_phase_space_reuse_and_best_indexed():
    prof, wl, space = make_problem(n=5, seed=5, scale=7.2)
    from repro.core import TwoPhaseOptimizer, tpu_slice_rules

    opt = TwoPhaseOptimizer(space.rules, prof, wl, space=space)
    assert opt.space is space
    rep = opt.run(skip_phase2=True)
    idep = rep.best_indexed(space)
    assert idep.num_gpus == rep.best_deployment.num_gpus
    assert idep.is_valid()
    with pytest.raises(ValueError):
        TwoPhaseOptimizer(tpu_slice_rules(), prof, wl, space=space)


# -- batched GA fitness ----------------------------------------------------------


@given(seed=st.integers(0, 40))
@settings(max_examples=8, deadline=None)
def test_fitness_batch_bit_identical_to_legacy_fitness(seed):
    """Reference: the scalar `_fitness` (completion via config-by-config
    `GPUConfig.utility` accumulation).  Bit-identical slack is what keeps
    the GA's selection order — hence its seeded output — unchanged."""
    _, wl, space = make_problem(seed=3)
    rng = np.random.default_rng(seed)
    deps = [random_deployment(space, rng) for _ in range(5)]
    batch = fitness_batch(deps, space)
    legacy = [_fitness(d, space) for d in deps]
    assert batch == legacy  # exact tuple equality, including float slack


# -- packed candidate scan -------------------------------------------------------


@given(seed=st.integers(0, 60))
@settings(max_examples=12, deadline=None)
def test_packed_scan_matches_scalar_packed_candidate(seed):
    """Reference: `_packed_candidate`, the per-partition/per-service scalar
    loop from the seed implementation (kept precisely for this test)."""
    _, wl, space = make_problem(seed=3)
    rng = np.random.default_rng(seed)
    completion = rng.uniform(0.0, 1.2, size=wl.n)
    greedy = GreedyFast(space)
    ref = greedy._packed_candidate(completion)
    need = np.clip(1.0 - completion, 0.0, None)
    got = greedy._packed_scan(need)
    if ref is None:
        assert got is None
        return
    assert got is not None
    pu, row, choices = got
    cfg = greedy._build_packed(row, choices)
    assert cfg.canonical() == ref.canonical()
    assert np.array_equal(pu, ref.utility(wl))  # exact float equality


# -- greedy incremental score/completion maintenance -----------------------------


@given(seed=st.integers(0, 40))
@settings(max_examples=8, deadline=None)
def test_greedy_matches_rescoring_reference(seed):
    """The incremental path must match a from-scratch rescoring loop (the
    seed implementation's structure) on arbitrary starting completions."""
    _, wl, space = make_problem(seed=3)
    rng = np.random.default_rng(seed)
    start = rng.uniform(0.0, 0.9, size=wl.n)
    configs = GreedyFast(space).produce(start)

    # scalar reference: recompute scores from scratch every round
    c = start.astype(np.float64).copy()
    ref = []
    greedy = GreedyFast(space)
    while np.any(c < 1.0 - 1e-9):
        scores = space.score_all(c)
        idx = int(np.argmax(scores))
        best_score = float(scores[idx])
        chosen, chosen_u = space.configs[idx], space.utility_of(idx)
        packed = greedy._packed_candidate(c)
        if packed is not None:
            pu = packed.utility(wl)
            need = np.clip(1.0 - c, 0.0, None)
            ps = float(np.sum(need * pu))
            if ps > best_score:
                chosen, chosen_u = packed, pu
        ref.append(chosen)
        c = c + chosen_u

    assert [cf.canonical() for cf in configs] == [cf.canonical() for cf in ref]


# -- MCTS building blocks --------------------------------------------------------


def test_top_k_desc_is_k_largest_in_deterministic_order():
    rng = np.random.default_rng(0)
    for _ in range(20):
        scores = np.round(rng.uniform(0, 1, size=200), 2)  # force ties
        k = int(rng.integers(1, 20))
        got = _top_k_desc(scores, k)
        assert len(got) == min(k, len(scores))
        # descending scores, ties broken by ascending index
        pairs = [(-float(scores[i]), int(i)) for i in got]
        assert pairs == sorted(pairs)
        # the k-th kept score is >= every dropped score
        kept_min = min(float(scores[i]) for i in got)
        dropped = np.delete(scores, got)
        if len(dropped):
            assert kept_min >= float(dropped.max()) - 1e-12


def test_bucket_signature_distinguishes_met_from_nearly_met():
    n = 4
    met = np.ones(n)
    nearly = np.ones(n)
    nearly[2] = 1.0 - 1e-6
    assert _bucket_signature(met) != _bucket_signature(nearly)
    assert _bucket_signature(met) == _bucket_signature(np.full(n, 1.5))


def test_mcts_edges_only_touch_sampled_or_scored_configs():
    _, wl, space = make_problem(n=6, seed=3)
    m = MCTSSlow(space, iterations=10, seed=0)
    edges = m._edges(np.zeros(wl.n))
    assert 0 < len(edges) <= m.top_k
    scores = space.score_all(np.zeros(wl.n))
    for e in edges:
        assert scores[e] > 0.0


# -- no jax in the numpy-only core ----------------------------------------------


def test_core_and_sim_stay_jax_free():
    """The performance contract: repro.core, repro.sim, the control plane
    (repro.controlplane) and the flight recorder (repro.obs) import no
    jax.

    Two complementary checks.  The runtime pin (subprocess below) proves
    the modules it imports are clean as executed; the static pin walks the
    whole transitive import graph — including modules this test does not
    import and function-local lazy imports the runtime check can never
    see (that is how it caught the ``arch_bridge -> configs -> models ->
    transformer -> jax`` leak the subprocess missed for nine PRs)."""
    import subprocess
    import sys

    # -- static: the import-boundary rule over the full graph ------------------
    root = __file__.rsplit("/tests/", 1)[0]
    sys.path.insert(0, root + "/tools")
    try:
        from contracts import load_project
        from contracts.rules import ImportBoundaryRule
    finally:
        sys.path.pop(0)
    from pathlib import Path

    findings = ImportBoundaryRule().check(load_project(Path(root) / "src"))
    assert not findings, "\n".join(str(f) for f in findings)

    # -- runtime: the executed-module pin --------------------------------------

    code = (
        "import sys; import repro.core, repro.sim, repro.controlplane; "
        "import repro.core.zoo, repro.sim.scenarios; "  # the scheduler zoo + matrix
        "import repro.sim.servemodel; "  # the token-level serving model
        "import repro.controlplane.reconciler, repro.controlplane.faults; "
        "import repro.obs, repro.obs.trace, repro.obs.metrics, repro.obs.flight; "
        "bad = [m for m in sys.modules if m == 'jax' or m.startswith('jax.')]; "
        "assert not bad, f'jax leaked into the numpy-only core: {bad}'; "
        "print('clean')"
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert out.returncode == 0, out.stderr
    assert "clean" in out.stdout
