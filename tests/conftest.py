import os
import sys

# Tests run on the single real CPU device (the dry-run's 512-device flag is
# set only inside repro.launch.dryrun).  Keep jax quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make tests/_hypothesis_compat.py importable from every test module
sys.path.insert(0, os.path.dirname(__file__))
