"""Optimizer tests: config space, greedy, MCTS, GA, two-phase (§5)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    SLO,
    BeamGreedy,
    ConfigSpace,
    Deployment,
    GreedyFast,
    MCTSSlow,
    SyntheticPaperProfiles,
    TwoPhaseOptimizer,
    Workload,
    a100_rules,
    baseline_homogeneous,
    lower_bound_gpus,
    mutate_swap,
    tpu_slice_rules,
)


def small_problem(n=8, seed=2, scale=7.2):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(seed)
    slos = {m: SLO(float(rng.lognormal(scale, 0.7)), 100.0) for m in prof.services()}
    return prof, Workload.make(slos)


class TestConfigSpace:
    def test_utilities_touch_at_most_two_services(self):
        prof, wl = small_problem()
        space = ConfigSpace(a100_rules(), prof, wl)
        assert len(space) > 0
        for i in range(0, len(space), max(1, len(space) // 50)):
            u = space.utility_of(i)
            assert np.count_nonzero(u) <= 2
            assert np.all(u >= 0)

    def test_scores_match_definition(self):
        prof, wl = small_problem()
        space = ConfigSpace(a100_rules(), prof, wl)
        c = np.linspace(0, 1.2, wl.n)
        scores = space.score_all(c)
        need = np.clip(1 - c, 0, None)
        for i in range(0, len(space), max(1, len(space) // 25)):
            expect = float(np.sum(need * space.utility_of(i)))
            assert scores[i] == pytest.approx(expect, rel=1e-9)

    def test_batch_respects_latency_slo(self):
        prof, wl = small_problem()
        space = ConfigSpace(a100_rules(), prof, wl)
        for cfg in space.configs[:: max(1, len(space) // 40)]:
            for a in cfg.assignments:
                if a.service is None:
                    continue
                slo = wl.services[wl.index(a.service)].slo
                assert prof.latency_ms(a.service, a.size, a.batch) <= slo.latency_ms


class TestGreedy:
    def test_produces_valid_deployment(self):
        prof, wl = small_problem()
        space = ConfigSpace(a100_rules(), prof, wl)
        dep = GreedyFast(space).solve()
        assert dep.is_valid(wl)

    def test_beats_or_matches_static_baselines(self):
        prof, wl = small_problem(n=12, scale=8.0)
        space = ConfigSpace(a100_rules(), prof, wl)
        dep = GreedyFast(space).solve()
        b_whole = baseline_homogeneous(a100_rules(), prof, wl, 7)
        assert dep.num_gpus <= b_whole

    def test_bounded_below_by_lower_bound(self):
        prof, wl = small_problem(n=10, scale=8.0)
        space = ConfigSpace(a100_rules(), prof, wl)
        dep = GreedyFast(space).solve()
        lb = lower_bound_gpus(a100_rules(), prof, wl)
        assert dep.num_gpus >= lb

    def test_tpu_rules_work_too(self):
        prof = SyntheticPaperProfiles(n_models=6, seed=3, sizes=(1, 2, 4, 8, 16))
        rng = np.random.default_rng(0)
        slos = {m: SLO(float(rng.lognormal(7.0, 0.6)), 100.0) for m in prof.services()}
        wl = Workload.make(slos)
        space = ConfigSpace(tpu_slice_rules(), prof, wl)
        dep = GreedyFast(space).solve()
        assert dep.is_valid(wl)


class TestMCTS:
    def test_valid_and_not_worse_than_greedy_much(self):
        prof, wl = small_problem(n=8, scale=7.5)
        space = ConfigSpace(a100_rules(), prof, wl)
        greedy = GreedyFast(space).solve()
        dep = Deployment(MCTSSlow(space, iterations=120, seed=0).produce(
            np.zeros(wl.n)))
        assert dep.is_valid(wl)
        assert dep.num_gpus <= greedy.num_gpus + 2

    def test_refill_from_partial_completion(self):
        prof, wl = small_problem()
        space = ConfigSpace(a100_rules(), prof, wl)
        c = np.full(wl.n, 0.6)
        configs = MCTSSlow(space, iterations=50, seed=1).produce(c)
        total = c + sum(cfg.utility(wl) for cfg in configs)
        assert np.all(total >= 1.0 - 1e-9)


class TestGA:
    def test_two_phase_never_worse_than_fast(self):
        prof, wl = small_problem(n=10, scale=8.0)
        opt = TwoPhaseOptimizer(
            a100_rules(), prof, wl, ga_rounds=2, ga_population=3,
            mcts_iterations=40, seed=0,
        )
        rep = opt.run()
        assert rep.best_deployment.is_valid(wl)
        assert rep.best_deployment.num_gpus <= rep.fast_deployment.num_gpus
        # history is monotonically non-increasing (elitism, §5.2)
        assert all(a >= b for a, b in zip(rep.ga_history, rep.ga_history[1:]))

    def test_mutation_preserves_completion(self):
        prof, wl = small_problem()
        space = ConfigSpace(a100_rules(), prof, wl)
        dep = GreedyFast(space).solve()
        mut = mutate_swap(dep, np.random.default_rng(0), swaps=6)
        np.testing.assert_allclose(
            mut.completion_rates(wl), dep.completion_rates(wl), rtol=1e-9
        )
        assert mut.num_gpus == dep.num_gpus


class TestBeamGreedy:
    def test_valid_and_at_least_as_good(self):
        prof, wl = small_problem(n=8, scale=7.5)
        space = ConfigSpace(a100_rules(), prof, wl)
        g = GreedyFast(space).solve()
        b = Deployment(BeamGreedy(space, beam=3, branch=3).produce(np.zeros(wl.n)))
        assert b.is_valid(wl)
        assert b.num_gpus <= g.num_gpus


@given(seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_greedy_valid_property(seed):
    """Property: for any synthetic workload, greedy terminates with a valid
    deployment whose count is >= the constraint-free lower bound."""
    prof, wl = small_problem(n=6, seed=seed, scale=7.0)
    space = ConfigSpace(a100_rules(), prof, wl)
    dep = GreedyFast(space).solve()
    assert dep.is_valid(wl)
    assert dep.num_gpus >= lower_bound_gpus(a100_rules(), prof, wl)
