"""Overload-resilience tests (ISSUE 7): priority classes, deadlines,
retry-with-backoff, and the serving-path crash fault.

Pins the resilience layer spanning traffic -> admission -> token serving
-> control plane -> reporting:

* golden pin — the curated overload cell (adversarial flash burst x mixed
  priority classes x seeded ``instance_crash`` fault on the token model)
  records its seeded report SHA plus the full per-class
  goodput/SLO-attainment/drop/retry block in
  ``tests/golden/resilience_golden.json``.  Regenerate (only on
  intentional behavior changes) with::

      PYTHONPATH=src python tests/test_resilience.py --regen

* conservation — per priority class, over arbitrary seeds and under the
  chaos of crashes + shedding, every arrival is accounted for exactly:
  ``arrivals == completed + deadline_dropped + retry_dropped + shed +
  in_system``.
* byte-identity — a priority mix is opt-in: without one, reports carry
  none of the new keys (the historical golden suites pin the bytes).
* unit coverage of the mechanisms: class-major admission order, deadline
  drops for goodput, capped exponential backoff under a retry budget,
  lowest-class-first victim eviction, crash semantics (KV + sampled
  tokens lost, cold page pool), and fail-fast config validation.
"""

import json
import math
import os
import sys

import numpy as np
import pytest

if __name__ == "__main__":  # regen mode runs without pytest/conftest
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_compat import given, settings, st

from repro.core import SyntheticPaperProfiles
from repro.sim import (
    PRIORITY_CLASSES,
    PriorityMix,
    ScenarioCell,
    SimConfig,
    TokenKnobs,
    TokenRequest,
    TokenServingState,
    build_cell,
    run_cell,
)
from repro.sim.servemodel import InstanceModel, TokenMetrics
from repro.sim.traffic import STANDARD_CLASS

GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden", "resilience_golden.json"
)

# the curated overload cell: adversarial burst x priority mix x crash fault
# (also in smoke_matrix, so both CI jobs execute it)
OVERLOAD_CELL = ScenarioCell(
    "flash", "greedy", "micro", "uniform", "instance_crash",
    serving="token", priority="mixed",
)


def compute_golden():
    res, rep = run_cell(OVERLOAD_CELL, seed=0)
    return {
        "schema": 1,
        "overload_cells": {
            f"{OVERLOAD_CELL.name}@seed0": {
                "report_sha256": res.report_sha256,
                "priority": rep.priority,
                "faults": [
                    {"kind": f.kind, "spilled": f.spilled}
                    for f in rep.faults
                ],
            }
        },
    }


def _load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


# -- golden pin -------------------------------------------------------------------


def test_resilience_golden_file_exists():
    assert os.path.exists(GOLDEN_PATH), (
        "golden file missing — regenerate with "
        "`PYTHONPATH=src python tests/test_resilience.py --regen`"
    )


def test_overload_cell_matches_golden():
    got = compute_golden()
    want = _load_golden()
    assert got == want, (
        "the overload cell's seeded behavior diverged from the recorded "
        "per-class goodput/retry block or report SHA"
    )


def test_overload_cell_exercises_every_mechanism():
    """The curated cell is only a meaningful pin if the resilience
    machinery actually fires in it."""
    _, rep = run_cell(OVERLOAD_CELL, seed=0)
    p = rep.priority
    assert set(p) == set(PRIORITY_CLASSES)
    assert sum(v["retries"] for v in p.values()) > 0
    assert sum(v["deadline_dropped"] + v["retry_dropped"] for v in p.values()) > 0
    assert any(f.kind == "instance_crash" and f.spilled > 0 for f in rep.faults)
    # crashes are process deaths, not capacity faults: no fault-triggered
    # reconcile pass fires (demand-triggered reoptimizes may still run)
    assert all(t.trigger != "fault" for t in rep.transitions)


# -- per-class conservation ------------------------------------------------------


@given(seed=st.integers(0, 30))
@settings(max_examples=3, deadline=None)
def test_per_class_conservation_under_chaos(seed):
    """Requests cannot leak across the crash/shed/retry paths: per class,
    arrivals == completed + deadline_dropped + retry_dropped + shed +
    in_system, exactly."""
    _, rep = run_cell(OVERLOAD_CELL, seed=seed)
    for cls, v in rep.priority.items():
        assert v["arrivals"] == (
            v["completed"] + v["deadline_dropped"] + v["retry_dropped"]
            + v["shed"] + v["in_system"]
        ), (cls, v)
        assert v["goodput"] <= v["completed"] <= v["arrivals"]
        assert 0.0 <= v["slo_attainment"] <= 1.0


def test_overload_cell_is_seed_deterministic():
    r1 = run_cell(OVERLOAD_CELL, seed=3)[1].to_json()
    r2 = run_cell(OVERLOAD_CELL, seed=3)[1].to_json()
    assert r1 == r2
    assert r1 != run_cell(OVERLOAD_CELL, seed=4)[1].to_json()


# -- byte-identity: the mix is opt-in --------------------------------------------


def test_priority_keys_absent_without_a_mix():
    """No mix -> none of the new report keys exist: historical token and
    fluid reports keep their exact byte layout."""
    plain = ScenarioCell(
        "flash", "greedy", "micro", "uniform", serving="token"
    )
    d = run_cell(plain, seed=0)[1].to_dict()
    assert "priority" not in d
    for tl in d["timelines"].values():
        assert "deadline_dropped" not in tl and "retry_dropped" not in tl
    mixed = run_cell(OVERLOAD_CELL, seed=0)[1].to_dict()
    assert set(mixed["priority"]) == set(PRIORITY_CLASSES)
    for tl in mixed["timelines"].values():
        assert "deadline_dropped" in tl and "retry_dropped" in tl


# -- PriorityMix ------------------------------------------------------------------


class TestPriorityMix:
    def test_rejects_malformed_mixes(self):
        with pytest.raises(ValueError):
            PriorityMix(weights=(1.0, 1.0))  # wrong arity
        with pytest.raises(ValueError):
            PriorityMix(weights=(-1.0, 1.0, 1.0))  # negative weight
        with pytest.raises(ValueError):
            PriorityMix(weights=(0.0, 0.0, 0.0))  # nothing to draw
        with pytest.raises(ValueError):
            PriorityMix(deadline_s=(0.0, 1.0, 2.0))  # non-positive deadline
        with pytest.raises(ValueError):
            PriorityMix(per_service={"svc": "premium"})  # unknown class name

    def test_pinned_service_consumes_no_randomness(self):
        mix = PriorityMix(per_service={"svc-a": "critical"})
        r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
        assert mix.class_of("svc-a", r1) == PRIORITY_CLASSES.index("critical")
        assert r1.random() == r2.random()  # rng untouched by the pin
        # unpinned services draw exactly one uniform
        mix.class_of("svc-b", r1)
        r2.random()
        assert r1.random() == r2.random()

    def test_weighted_draw_matches_weights(self):
        mix = PriorityMix(weights=(0.5, 0.5, 0.0))
        rng = np.random.default_rng(0)
        draws = [mix.class_of("s", rng) for _ in range(500)]
        assert set(draws) == {0, 1}  # zero-weight class never drawn
        assert 150 < draws.count(0) < 350  # roughly half each


# -- instance-level mechanisms ----------------------------------------------------


def _small_knobs(**over):
    kw = dict(
        prompt_tokens=8, decode_tokens=4, max_len=16, page_size=4,
        hbm_gb_per_unit=1e-12,  # floor-limited pool: max_pages_per_req pages
        prefill_chunk=4,
    )
    kw.update(over)
    return TokenKnobs(**kw)


def _instance(knobs, slots=4, svc="svc", resilience=True):
    return InstanceModel(
        0, svc, 1, slots=slots, knobs=knobs,
        step_time_s=lambda b: 0.01, now=0.0, resilience=resilience,
    )


def _req(rid, priority, prompt=4, decode=2, arrival=0.0, deadline=math.inf):
    r = TokenRequest(rid, "svc", arrival, prompt, decode)
    r.priority = priority
    r.deadline_s = deadline
    return r


def test_admission_is_class_major_fifo_within_class():
    """A critical request enqueued *after* two batch requests is still
    admitted first; within a class the order stays FIFO."""
    knobs = _small_knobs(hbm_gb_per_unit=1.0)
    inst = _instance(knobs, slots=1)
    metrics = TokenMetrics(["svc"])
    b0, b1 = _req(0, 2), _req(1, 2)
    crit = _req(2, 0)
    for r in (b0, b1, crit):
        inst.enqueue(r)
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at["svc"]) == 3
    assert crit.admit_s < b0.admit_s < b1.admit_s


def test_legacy_queue_view_is_the_standard_class_fifo():
    inst = _instance(_small_knobs(), resilience=False)
    r = TokenRequest(0, "svc", 0.0, 4, 2)
    inst.queue.append(r)  # historical tests drive the model this way
    assert inst.queues[STANDARD_CLASS] == [r]
    assert inst.in_system == 1


def test_expired_deadline_is_dropped_not_served():
    knobs = _small_knobs(hbm_gb_per_unit=1.0)
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    dead = _req(0, 1, deadline=-1.0)  # already past its SLO at admission
    ok = _req(1, 1)
    inst.enqueue(dead)
    inst.enqueue(ok)
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at["svc"]) == 1  # only `ok` ran
    assert dead.finish_s < 0.0
    assert metrics.deadline_dropped["svc"] == 1
    assert metrics.class_deadline_dropped[1] == 1
    assert metrics.class_goodput[1] == 1


def test_refusal_backs_off_with_capped_exponential_delay():
    knobs = _small_knobs()
    assert knobs.retry_backoff_s(1) == knobs.retry_base_s
    assert knobs.retry_backoff_s(2) == knobs.retry_base_s * knobs.retry_mult
    assert knobs.retry_backoff_s(50) == knobs.retry_cap_s  # capped
    # one-request pool: the second long prompt is refused and parks in the
    # backoff heap instead of spinning at the queue head
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    hog = _req(0, 1, prompt=10, decode=5)
    late = _req(1, 1, prompt=10, decode=2)
    inst.enqueue(hog)
    inst.enqueue(late)
    inst.run_until(0.05, metrics)
    assert len(inst.live) == 1 and late.retries >= 1
    assert inst.backoff and inst.backoff[0][2] is late
    assert late.next_try_s > inst.clock - 0.05  # scheduled in the future
    inst.run_until(1e9, metrics)  # backoff expires, retry succeeds
    assert len(metrics.completed_at["svc"]) == 2
    assert metrics.retry_dropped["svc"] == 0


def test_retry_budget_exhaustion_drops_the_request():
    knobs = _small_knobs(retry_budget=0)  # first refusal already exceeds it
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    inst.enqueue(_req(0, 2, prompt=10, decode=5))
    doomed = _req(1, 2, prompt=10, decode=2)
    inst.enqueue(doomed)
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at["svc"]) == 1
    assert metrics.retry_dropped["svc"] == 1
    assert metrics.class_retry_dropped[2] == 1
    assert doomed.finish_s < 0.0 and inst.in_system == 0


def test_eviction_prefers_lowest_class_victim():
    """When a critical request must grow its KV pages, the batch-class
    neighbor is evicted — the critical request itself keeps running."""
    knobs = _small_knobs()  # 5-page pool
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    crit = _req(0, 0, prompt=10, decode=4)  # 3 pages, grows past 12 tokens
    batch = _req(1, 2, prompt=6, decode=8)  # 2 pages
    inst.enqueue(crit)
    inst.enqueue(batch)
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at["svc"]) == 2  # batch resumed and finished
    assert crit.preemptions == 0
    assert batch.preemptions >= 1
    assert metrics.preemptions["svc"] == crit.preemptions + batch.preemptions


def test_eviction_never_sacrifices_a_higher_class():
    """The mirror image: when the *batch* request needs pages, it preempts
    itself rather than evicting the critical neighbor."""
    knobs = _small_knobs()
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    batch = _req(0, 2, prompt=10, decode=4)
    crit = _req(1, 0, prompt=6, decode=8)
    inst.enqueue(batch)
    inst.enqueue(crit)
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at["svc"]) == 2
    assert crit.preemptions == 0
    assert batch.preemptions >= 1


def test_crash_loses_kv_and_generated_tokens():
    """A crash is harsher than a drain: in-flight requests restart from the
    prompt (their sampled tokens lived in the dead process) and the
    replacement pool is cold."""
    knobs = _small_knobs(hbm_gb_per_unit=1.0)
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    a = _req(0, 1, prompt=4, decode=8)
    b = _req(1, 1, prompt=4, decode=8, arrival=50.0)  # not yet arrived
    inst.enqueue(a)
    inst.enqueue(b)
    inst.run_until(0.05, metrics)  # mid-decode: ~4 of 8 tokens sampled
    assert a.generated > 0 and len(inst.live) == 1
    inflight, queued = inst.crash(inst.clock, metrics)
    assert inflight == [a] and queued == [b]
    assert a.generated == 0 and a.preemptions == 1  # restart from prompt
    assert b.generated == 0 and b.preemptions == 0  # queued spill intact
    assert inst.in_system == 0
    assert len(inst.pool._free) == knobs.num_pages(1)  # cold pool
    assert metrics.preemptions["svc"] == 1
    # the spilled request re-admits elsewhere and still completes fully
    inst2 = _instance(knobs, slots=2)
    inst2.enqueue(a)
    inst2.run_until(1e9, metrics)
    assert a.finish_s > 0.0 and a.generated == 8


def test_crash_instance_charges_the_retry_budget():
    prof = SyntheticPaperProfiles(n_models=2, seed=2)
    svc = sorted(prof.services())[0]
    mix = PriorityMix(per_service={svc: "standard"})
    state = TokenServingState(
        [svc], prof, lambda s: 100.0,
        _small_knobs(hbm_gb_per_unit=1.0, retry_budget=0), mix=mix,
    )
    state.sync_instances({7: (svc, 1, 50.0)}, lambda uid: 1.0, 0.0)
    inst = state.instances[7]
    # pin the twin's shape so exactly one request is mid-decode at crash
    # time (the profile-derived slots/step-time vary across services)
    inst.slots = 1
    inst.step_time_s = lambda b: 0.01
    rng = np.random.default_rng(0)
    for _ in range(2):
        r = state.make_request(svc, 0.0, rng)  # charges class_arrivals
        r.prompt_tokens, r.decode_tokens = 4, 8
        inst.enqueue(r)
    inst.run_until(0.05, state.metrics)
    assert len(inst.live) == 1
    spilled = state.crash_instance(7, inst.clock)
    assert spilled == 1
    # retry_budget=0: the in-flight spill is dropped, the queued one survives
    assert state.metrics.class_retry_dropped[STANDARD_CLASS] == 1
    assert len(state.spill[svc]) == 1
    counts = state.priority_summary()["standard"]
    assert counts["arrivals"] == counts["completed"] + counts[
        "deadline_dropped"] + counts["retry_dropped"] + counts[
        "shed"] + counts["in_system"]


# -- fail-fast config validation ---------------------------------------------------


class TestConfigValidation:
    def test_unknown_axis_values_raise_with_valid_names(self):
        with pytest.raises(ValueError, match="poisson"):
            SimConfig(arrivals="bogus")
        with pytest.raises(ValueError, match="gpu_loss"):
            SimConfig(fault_profile="bogus")
        with pytest.raises(ValueError, match="token"):
            SimConfig(serving_model="bogus")
        with pytest.raises(ValueError, match="poisson"):
            SimConfig(serving_model="token", arrivals="fluid")

    def test_priority_mix_requires_the_token_model(self):
        with pytest.raises(ValueError, match="token"):
            SimConfig(priority_mix=PriorityMix())
        SimConfig(serving_model="token", priority_mix=PriorityMix())  # ok

    def test_build_cell_rejects_unknown_axes(self):
        for bad in (
            ScenarioCell("nope", "greedy", "micro", "uniform"),
            ScenarioCell("flash", "nope", "micro", "uniform"),
            ScenarioCell("flash", "greedy", "nope", "uniform"),
            ScenarioCell("flash", "greedy", "micro", "nope"),
            ScenarioCell("flash", "greedy", "micro", "uniform", "nope"),
            ScenarioCell(
                "flash", "greedy", "micro", "uniform", serving="nope"
            ),
            ScenarioCell(
                "flash", "greedy", "micro", "uniform", priority="nope"
            ),
        ):
            with pytest.raises(ValueError, match="valid"):
                build_cell(bad, seed=0)


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        data = compute_golden()
        with open(GOLDEN_PATH, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    else:
        print("run under pytest, or with --regen to rewrite the golden file")
