"""Rule-set tests: A100 MIG legality (§2.1 / Figure 2) and TPU slices."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mig import a100_rules
from repro.core.rms import validate_partition_universe
from repro.core.tpu_slice import SLICE_SHAPES, tpu_slice_rules


class TestA100Rules:
    def setup_method(self):
        self.r = a100_rules()

    def test_universe_valid(self):
        validate_partition_universe(self.r)

    def test_paper_examples(self):
        r = self.r
        # §2.1: "an A100 cannot allocate a 3/7 instance when having a running
        # 4/7 instance" — the hard-coded no 4+3 rule
        assert not r.is_legal_partition((3, 4))
        # "3/7 + 3/7 is possible but not shown in the figure"
        assert r.is_legal_partition((3, 3))
        # the shaded Figure-2 example: 4/7 + 2/7 + 1/7
        assert r.is_legal_partition((1, 2, 4))
        # 5/7 and 6/7 instances do not exist
        assert 5 not in r.instance_sizes and 6 not in r.instance_sizes

    def test_free_slices_do_not_imply_allocatable(self):
        r = self.r
        # two 3/7 instances leave one free slice, but 2/7 needs an aligned pair
        assert not r.is_legal_partition((2, 3, 3))
        # ... while a 1/7 fits
        assert r.is_legal_partition((1, 3, 3))

    def test_full_partition_count(self):
        # 11 maximal multisets (NVIDIA's "18 combinations" counts
        # placement-distinct variants; the scheduler works on multisets)
        assert len(self.r.full_partitions()) == 11

    def test_seven_is_exclusive(self):
        assert self.r.is_legal_partition((7,))
        assert not self.r.is_legal_partition((1, 7))

    def test_rule_reconf_merge_and_split(self):
        r = self.r
        # merge two 1/7 into a 2/7 without touching the rest
        assert r.rule_reconf((1, 1), (2,), (1, 1, 1, 1, 1, 1, 1))
        # splitting a 4/7 into 4 × 1/7
        assert r.rule_reconf((4,), (1, 1, 1, 1), (1, 2, 4))
        # illegal: result contains 4+3
        assert not r.rule_reconf((1, 2), (3,), (1, 2, 4))
        # removing something not present
        assert not r.rule_reconf((3,), (1, 1, 1), (1, 2, 4))

    @given(st.lists(st.sampled_from([1, 2, 3, 4, 7]), min_size=1, max_size=7))
    @settings(max_examples=200, deadline=None)
    def test_legality_is_order_invariant_and_downward_closed(self, sizes):
        r = self.r
        part = tuple(sorted(sizes))
        legal = r.is_legal_partition(part)
        if legal:
            # any sub-multiset of a legal partition is legal
            for i in range(len(part)):
                sub = part[:i] + part[i + 1 :]
                assert r.is_legal_partition(sub), (part, sub)


class TestTpuSliceRules:
    def setup_method(self):
        self.r = tpu_slice_rules()

    def test_universe_valid(self):
        validate_partition_universe(self.r)

    def test_alignment_is_the_mig_analogue(self):
        r = self.r
        # 16 chips fully tileable by four 4-chip slices
        assert r.is_legal_partition((4, 4, 4, 4))
        # 8+4+4 legal; but three 8s never fit
        assert r.is_legal_partition((4, 4, 8))
        assert not r.is_legal_partition((8, 8, 8))
        # "free chips != allocatable slice": 4+2+2... leaves 8 free chips but
        # an aligned 2x4 8-slice may be blocked by placement
        assert sum((2, 2, 4)) + 8 <= 16
        # power-of-two only (the 5/7-6/7 analogue)
        assert set(r.instance_sizes) == set(SLICE_SHAPES)

    @given(st.lists(st.sampled_from([1, 2, 4, 8, 16]), min_size=1, max_size=16))
    @settings(max_examples=150, deadline=None)
    def test_downward_closed(self, sizes):
        r = self.r
        part = tuple(sorted(sizes))
        if r.is_legal_partition(part):
            for i in range(len(part)):
                assert r.is_legal_partition(part[:i] + part[i + 1 :])

    def test_mesh_shapes(self):
        from repro.core.tpu_slice import slice_mesh_shape

        for s, (h, w) in SLICE_SHAPES.items():
            assert h * w == s
            assert slice_mesh_shape(s) == (h, w)


# -- regression: validate_partition_universe raises typed errors ----------------


class _BrokenRules(tpu_slice_rules().__class__):
    """Stub rule-set whose oracles can be bent one failure mode at a time."""

    def __init__(self, partitions):
        self._partitions = partitions

    def legal_partitions(self):
        return self._partitions

    def is_legal_partition(self, partition):
        return partition in self._partitions


class TestValidatePartitionUniverse:
    """PR 10 converted the validator's bare asserts (stripped under
    ``python -O``) to ValueError with messages naming the offender."""

    def test_empty_universe(self):
        with pytest.raises(ValueError, match="no legal partitions"):
            validate_partition_universe(_BrokenRules([]))

    def test_unsorted_partition(self):
        with pytest.raises(ValueError, match="not sorted"):
            validate_partition_universe(_BrokenRules([(2, 1)]))

    def test_oversubscribed_partition(self):
        with pytest.raises(ValueError, match="oversubscribed"):
            validate_partition_universe(_BrokenRules([(16, 16)]))

    def test_size_outside_menu(self):
        with pytest.raises(ValueError, match="size outside"):
            validate_partition_universe(_BrokenRules([(3,)]))

    def test_disagreeing_oracles(self):
        class _Disagree(_BrokenRules):
            def is_legal_partition(self, partition):
                return False

        with pytest.raises(ValueError, match="oracles disagree"):
            validate_partition_universe(_Disagree([(4, 4)]))

    def test_real_rule_sets_still_pass(self):
        validate_partition_universe(a100_rules())
        validate_partition_universe(tpu_slice_rules())
