"""Unit tests for the MoE dispatch and the chunked SSD scan."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.models.common import ParamFactory
from repro.models.moe import capacity, moe_forward, moe_init
from repro.models.ssm import ssd_chunked, ssd_step
from repro.kernels.ref import ssm_scan_ref


def make_moe(cfg_overrides=None, seed=0):
    cfg = get_smoke_config("deepseek-v3-671b")
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    f = ParamFactory(jax.random.PRNGKey(seed), jnp.float32)
    moe_init(f, cfg)
    return cfg, f.params


class TestMoE:
    def test_output_shape_and_aux_range(self):
        cfg, params = make_moe()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        out, aux = moe_forward(params, cfg, x)
        assert out.shape == x.shape
        # Switch aux is ~1 for a balanced router, >=1-ish in general
        assert 0.5 < float(aux) < float(cfg.num_experts)

    def test_capacity_rounding(self):
        cfg, _ = make_moe()
        c = capacity(1024, cfg)
        assert c % 8 == 0
        assert c >= 1024 * cfg.experts_per_token / cfg.num_experts

    def test_token_dropping_at_tiny_capacity(self):
        """With capacity_factor → 0 most tokens drop and output shrinks, but
        shared experts keep it nonzero."""
        cfg, params = make_moe({"capacity_factor": 1e-6})
        cfg_big, params_big = make_moe({"capacity_factor": 8.0})
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
        out_small, _ = moe_forward(params, cfg, x)
        out_big, _ = moe_forward(params_big, cfg_big, x)
        assert float(jnp.mean(jnp.abs(out_small))) < float(jnp.mean(jnp.abs(out_big)))

    def test_generous_capacity_matches_exact_routing(self):
        """With capacity >= T·k no token drops: the scatter/gather dispatch
        must equal the dense per-token expert evaluation."""
        cfg, params = make_moe({"capacity_factor": 64.0, "num_shared_experts": 0})
        B, S = 1, 8
        x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model))
        out, _ = moe_forward(params, cfg, x)
        # dense reference
        xf = x.reshape(-1, cfg.d_model)
        logits = xf @ params["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        ref = jnp.zeros_like(xf)
        for t in range(xf.shape[0]):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(cfg.experts_per_token):
                e = int(idx[t, j])
                h = jax.nn.silu(xf[t] @ params["we_gate"][e]) * (
                    xf[t] @ params["we_up"][e]
                )
                acc = acc + w[t, j] * (h @ params["we_down"][e])
            ref = ref.at[t].set(acc)
        np.testing.assert_allclose(
            np.asarray(out.reshape(-1, cfg.d_model)), np.asarray(ref),
            rtol=2e-4, atol=2e-4,
        )


class TestSSD:
    @given(chunk=st.sampled_from([8, 16, 32]), seed=st.integers(0, 20))
    @settings(max_examples=12, deadline=None)
    def test_chunked_matches_sequential(self, chunk, seed):
        """Property: the chunked SSD equals the sequential recurrence for
        any chunking."""
        key = jax.random.PRNGKey(seed)
        B, S, H, P, N = 2, 64, 2, 8, 4
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        y1, f1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        y2, f2 = ssm_scan_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=2e-3, atol=2e-3)

    def test_step_continues_prefill_state(self):
        """ssd_step applied after ssd_chunked's final state must equal the
        full-sequence result at the next position."""
        key = jax.random.PRNGKey(7)
        B, S, H, P, N = 1, 32, 2, 8, 4
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, S + 1, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S + 1, N))
        Cm = jax.random.normal(ks[4], (B, S + 1, N))
        _, state = ssd_chunked(x[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S], 16)
        y_step, _ = ssd_step(state, x[:, S], dt[:, S], A, Bm[:, S], Cm[:, S])
        y_full, _ = ssd_chunked(x, dt, A, Bm, Cm, 11 if (S + 1) % 11 == 0 else 33)
        np.testing.assert_allclose(
            np.asarray(y_step), np.asarray(y_full[:, S]), rtol=2e-3, atol=2e-3
        )
