"""Profile generators + roofline parsing tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.arch_bridge import tpu_arch_profiles
from repro.core.profiles import SyntheticPaperProfiles
from repro.core.tpu_slice import pod_slice_rules
from repro.roofline.analysis import _shape_bytes, collective_bytes, hlo_cost


class TestSyntheticProfiles:
    def test_classification_mix_matches_paper(self):
        """§2.2/Fig.4: non-linear models are prevalent."""
        prof = SyntheticPaperProfiles(n_models=49, seed=0)
        classes = [prof.classify(m) for m in prof.services()]
        nonlinear = sum(c != "linear" for c in classes)
        assert nonlinear > len(classes) / 2
        assert {"sub-linear", "super-linear"} <= set(classes)

    def test_latency_monotone_in_batch(self):
        prof = SyntheticPaperProfiles(n_models=5, seed=1)
        for m in prof.services():
            for s in prof.sizes():
                if not prof.feasible(m, s):
                    continue
                lats = [prof.latency_ms(m, s, b) for b in (1, 2, 4, 8)]
                assert all(a < b for a, b in zip(lats, lats[1:]))

    def test_throughput_zero_when_slo_unattainable(self):
        prof = SyntheticPaperProfiles(n_models=5, seed=1)
        m = prof.services()[0]
        assert prof.throughput(m, 1, 1e-6) == 0.0

    @given(seed=st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_throughput_monotone_in_size_under_loose_slo(self, seed):
        prof = SyntheticPaperProfiles(n_models=4, seed=seed)
        for m in prof.services():
            ts = [prof.throughput(m, s, 1e9) for s in sorted(prof.sizes())]
            ts = [t for t in ts if t > 0]
            assert all(a <= b * 1.001 for a, b in zip(ts, ts[1:]))


class TestRooflineProfiles:
    def test_big_models_need_big_slices(self):
        prof = tpu_arch_profiles()
        rules = pod_slice_rules()
        small = prof.min_size("qwen3-8b")
        big = prof.min_size("deepseek-v3-671b")
        assert big > small
        assert big >= 128  # 1.34 TB of bf16 weights

    def test_kv_heavy_models_scale_sublinearly(self):
        prof = tpu_arch_profiles()
        assert prof.classify("mamba2-370m", 50.0) == "sub-linear"


class TestHLOParsing:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[128,4096]{1,0}") == 128 * 4096 * 2
        assert _shape_bytes("f32[16]") == 64
        assert _shape_bytes("(bf16[8,8]{1,0}, f32[4])") == 128 + 16
        assert _shape_bytes("token[]") == 0

    def test_collective_parse(self):
        hlo = """
ENTRY %main.1_spmd (p: f32[8,32]) -> f32[8,32] {
  %add.1 = bf16[1024]{0} add(x, y)
  %all-reduce.5 = bf16[4096,128]{1,0} all-reduce(bf16[4096,128]{1,0} %add.1), replica_groups={}
  %ag = f32[64,32]{1,0} all-gather(f32[8,32]{1,0} %p), dimensions={0}
  %rs.2 = f32[8,32]{1,0} reduce-scatter(f32[64,32]{1,0} %ag), dimensions={0}
  %a2a = bf16[16,16]{1,0} all-to-all(bf16[16,16]{1,0} %x)
  %cp-start = bf16[2,2]{1,0} collective-permute-start(bf16[2,2]{1,0} %y)
}
        """
        got = collective_bytes(hlo)
        assert got["all-reduce"] == 4096 * 128 * 2
        assert got["all-gather"] == 64 * 32 * 4
        assert got["reduce-scatter"] == 8 * 32 * 4
        assert got["all-to-all"] == 16 * 16 * 2
        assert got["collective-permute"] == 2 * 2 * 2

    def test_collective_parse_while_trip_count(self):
        """Collectives in a scan body count once per layer, not once."""
        hlo = """
%region_0.1_spmd (param: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
  %all-reduce.9 = f32[4,16]{1,0} all-reduce(f32[4,16]{1,0} %x), replica_groups={}
}

%region_1.2_spmd (param.1: (s32[], f32[4,16])) -> pred[] {
  %lt = pred[] compare(%a, %b)
}

ENTRY %main.3_spmd (param.2: f32[4,16]) -> f32[4,16] {
  %all-gather.1 = f32[8,16]{1,0} all-gather(f32[4,16]{1,0} %param.2), dimensions={0}
  %while.3 = (s32[], f32[4,16]{1,0}) while(%tuple.7), condition=%region_1.2_spmd, body=%region_0.1_spmd, backend_config={"known_trip_count":{"n":"6"}}
}
        """
        got = collective_bytes(hlo)
        assert got["all-reduce"] == 6 * 4 * 16 * 4
        assert got["all-gather"] == 8 * 16 * 4


class TestHloCost:
    def test_scan_flops_match_unrolled(self):
        """The parser multiplies while bodies by trip count — the exact
        behavior cost_analysis() lacks."""
        import jax
        import jax.numpy as jnp

        def scanned(w, x):
            def body(x, wl):
                return jnp.tanh(x @ wl), None
            x, _ = jax.lax.scan(body, x, w)
            return x

        def unrolled(w, x):
            for i in range(6):
                x = jnp.tanh(x @ w[i])
            return x

        w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        cs = jax.jit(scanned).lower(w, x).compile()
        cu = jax.jit(unrolled).lower(w, x).compile()
        expected = 6 * 2 * 8 * 64 * 64
        assert hlo_cost(cs.as_text())["flops"] == expected
        assert hlo_cost(cu.as_text())["flops"] == expected
        # and cost_analysis really does undercount the scan (the bug we fix);
        # older jax returns a list of per-module dicts, newer a single dict
        ca = cs.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        assert ca["flops"] < expected

    def test_dot_flops_with_batch_dims(self):
        import jax
        import jax.numpy as jnp

        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
        c = jax.jit(f).lower(a, b).compile()
        got = hlo_cost(c.as_text())["flops"]
        assert got == 2 * 4 * 8 * 32 * 16
