"""Per-architecture smoke tests (reduced same-family configs, CPU) and
decode/forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, long_context_variant
from repro.models import Model
from repro.training import adamw, data, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg, remat=False)
    params, specs = m.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(specs)
    B, S = 2, 32
    if cfg.modality == "text":
        logits, aux = m.forward(
            params, tokens=jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        )
    else:
        logits, aux = m.forward(
            params, embeds=jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
        )
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg, remat=False)
    params, _ = m.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, adamw.AdamWConfig(lr=1e-3)))
    ostate = adamw.init(params)
    batch = data.synthetic_batch(cfg, data.DataConfig(batch=2, seq_len=32), 0)
    params2, ostate2, metrics = step(params, ostate, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-8b", "granite-20b", "mamba2-370m",
                                  "zamba2-1.2b", "deepseek-v2-236b", "musicgen-large"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg, remat=False)
    params, _ = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    if cfg.modality == "text":
        full, _ = m.forward(params, tokens=toks)
    else:
        full, _ = m.forward(params, embeds=jnp.take(params["embed"], toks, axis=0))
    cache = m.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(params, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1).astype(jnp.float32)
    full = full.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(dec - full))) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 0.05, rel


@pytest.mark.parametrize("arch", ["qwen3-8b", "zamba2-1.2b", "deepseek-v3-671b"])
def test_prefill_matches_forward_and_seeds_decode(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg, remat=False)
    params, _ = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full, _ = m.forward(params, tokens=toks)
    lgp, cache = m.prefill(params, tokens=toks)
    rel = float(jnp.max(jnp.abs(lgp[:, 0].astype(jnp.float32) - full[:, -1].astype(jnp.float32))))
    assert rel < 1e-3
    # prefill cache sizes equal prompt length; decoding continues with pos=S...
    # grow a fresh cache instead (ring semantics differ); here we check the
    # prefill cache layer-stacks exist with the right leading dim
    n_layers = {
        "dense": cfg.num_layers, "vlm": cfg.num_layers, "audio": cfg.num_layers,
        "moe": cfg.num_layers - cfg.first_dense_layers,
        "ssm": cfg.num_layers,
        "hybrid": cfg.num_layers // max(1, cfg.shared_attn_every),
    }[cfg.arch_type]
    lead = jax.tree.leaves(cache["layers"])[0].shape[0]
    assert lead == n_layers


def test_sliding_window_variant_limits_cache():
    cfg = long_context_variant(get_smoke_config("qwen3-8b"), window=8)
    m = Model(cfg, remat=False)
    cache = m.init_cache(2, max_len=64)
    k = cache["layers"]["k"]
    assert k.shape[2] == 8  # (L, B, W, KV, hd) ring buffer
    # ring decode still matches full attention within the window ... smoke:
    params, _ = m.init(jax.random.PRNGKey(0))
    lg, cache = m.decode_step(params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(20))
    assert not bool(jnp.any(jnp.isnan(lg.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """The exact numbers from the assignment block."""
    expect = {
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32000, ssm_state=64),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=12288, vocab_size=151936),
        "mamba2-370m": dict(num_layers=48, d_model=1024, d_ff=0, vocab_size=50280, ssm_state=128),
        "internvl2-1b": dict(num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151655),
        "phi4-mini-3.8b": dict(num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8, d_ff=8192, vocab_size=200064),
        "musicgen-large": dict(num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=2048),
        "deepseek-v2-236b": dict(num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128, vocab_size=102400, num_experts=160, experts_per_token=6, kv_lora_rank=512, moe_d_ff=1536),
        "granite-20b": dict(num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128, vocab_size=129280, num_experts=256, experts_per_token=8, moe_d_ff=2048, mtp=True),
        "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8, d_ff=53248, vocab_size=128256),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
        assert cfg.citation


def test_param_counts_plausible():
    """Sanity: derived parameter counts are in the advertised ballpark."""
    approx = {
        "qwen3-8b": (8e9, 0.35),
        "llama3-405b": (405e9, 0.15),
        "mamba2-370m": (370e6, 0.35),
        "deepseek-v2-236b": (236e9, 0.25),
        "deepseek-v3-671b": (671e9, 0.25),
        "granite-20b": (20e9, 0.35),
    }
    for arch, (n, tol) in approx.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < tol, (arch, got, n)


@pytest.mark.parametrize("arch", ["qwen3-8b", "musicgen-large"])
def test_use_kernels_matches_jnp_path(arch):
    """End-to-end: the Pallas-kernel attention path (interpret mode) agrees
    with the pure-jnp model forward."""
    cfg = get_smoke_config(arch)
    mk = Model(cfg, remat=False, use_kernels=True)
    mj = Model(cfg, remat=False, use_kernels=False)
    params, _ = mj.init(jax.random.PRNGKey(0))
    B, S = 2, 128  # tile-aligned so the kernel path engages
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    if cfg.modality == "text":
        lk, _ = mk.forward(params, tokens=toks)
        lj, _ = mj.forward(params, tokens=toks)
    else:
        emb = jnp.take(params["embed"], toks, axis=0)
        lk, _ = mk.forward(params, embeds=emb)
        lj, _ = mj.forward(params, embeds=emb)
    err = float(jnp.max(jnp.abs(lk.astype(jnp.float32) - lj.astype(jnp.float32))))
    assert err < 0.15  # bf16 accumulation-order differences only


# -- regression: ModelConfig validation raises typed errors ---------------------


class TestModelConfigValidation:
    """PR 10 converted ``ModelConfig.__post_init__``'s bare asserts
    (stripped under ``python -O``) to ValueError with messages."""

    @staticmethod
    def _cfg(**overrides):
        from repro.models import ModelConfig

        kw = dict(
            name="tiny",
            arch_type="dense",
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=2,
            d_ff=128,
            vocab_size=256,
        )
        kw.update(overrides)
        return ModelConfig(**kw)

    def test_unknown_arch_type(self):
        with pytest.raises(ValueError, match="unknown arch_type"):
            self._cfg(arch_type="quantum")

    def test_ssm_requires_no_attention(self):
        with pytest.raises(ValueError, match="attention_kind='none'"):
            self._cfg(arch_type="ssm", attention_kind="gqa", ssm_state=16)

    def test_mla_requires_kv_lora_rank(self):
        with pytest.raises(ValueError, match="kv_lora_rank"):
            self._cfg(attention_kind="mla", kv_lora_rank=0)

    def test_valid_config_unaffected(self):
        cfg = self._cfg()
        assert cfg.head_dim == 16  # derived d_model // num_heads

    def test_modelconfig_importable_without_jax(self):
        """``repro.models`` now exports ModelConfig eagerly and Model
        lazily (PEP 562): importing the package must not pull in jax —
        that is the import-boundary leak PR 10's checker caught."""
        import subprocess
        import sys

        code = (
            "import sys\n"
            "from repro.models import ModelConfig\n"
            "import repro.core.arch_bridge\n"
            "assert not any(m == 'jax' or m.startswith('jax.') "
            "for m in sys.modules), 'jax leaked'\n"
            "from repro.models import Model\n"
            "assert 'jax' in sys.modules\n"
        )
        root = __file__.rsplit("/tests/", 1)[0]
        proc = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True,
            text=True,
            cwd=root,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        )
        assert proc.returncode == 0, proc.stderr
