"""Scenario-matrix harness tests (repro.sim.scenarios).

Pins the properties ISSUE 3 names: the declarative matrix runs every cell
through the closed loop, cell metrics carry the comparable schema
(attainment, GPUs used, reoptimize latency, GPUs saved vs A100-as-is), the
same seed yields byte-identical documents *through the scenario runner*
(SimReport bytes included), the correlated-surge trace really correlates,
and per-service latency targets flow into the optimizer's workloads.
"""

import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import SyntheticPaperProfiles, a100_rules
from repro.sim import (
    FAULT_PROFILES,
    FLUID_SCHEDULERS,
    SCALES,
    SCHEDULERS,
    SLO_POLICIES,
    TRACE_SHAPES,
    ReoptimizeDriver,
    ScenarioCell,
    correlated_surge_trace,
    default_matrix,
    run_cell,
    run_matrix,
    smoke_matrix,
)


# -- matrix definitions ----------------------------------------------------------


def test_default_matrix_covers_the_required_axes():
    """Acceptance floor: >= 2 trace shapes x >= 4 schedulers (incl. both new
    zoo policies) x >= 2 scales, plus the curated fault slice covering
    every registered fault profile and the curated token-serving slice."""
    cells = default_matrix()
    fluid_cells = [
        c
        for c in cells
        if c.fault == "none"
        and c.serving == "fluid"
        and c.scheduler in FLUID_SCHEDULERS
    ]
    fault_cells = [c for c in cells if c.fault != "none"]
    token_cells = [c for c in cells if c.serving == "token"]
    warm_cells = [c for c in cells if c.scheduler == "greedy_warm"]
    traces = {c.trace for c in fluid_cells}
    scheds = {c.scheduler for c in fluid_cells}
    scales = {c.scale for c in fluid_cells}
    assert len(traces) >= 2
    assert len(scheds) >= 4 and {"frag", "energy"} <= scheds
    assert len(scales) >= 2
    assert len(fluid_cells) == (
        len(traces) * len(scheds) * len(scales) * len(SLO_POLICIES)
    )
    # the warm-start slice: greedy_warm cells exist and each has a "greedy"
    # twin in the fluid product to read against
    assert warm_cells
    fluid_points = {(c.trace, c.scale, c.slo) for c in fluid_cells}
    assert all(
        (c.trace, c.scale, c.slo) in fluid_points for c in warm_cells
    )
    # the fifth axis: every non-none fault profile appears in the slice
    assert {c.fault for c in fault_cells} == set(FAULT_PROFILES) - {"none"}
    # the sixth axis: the token slice runs flash + surge at micro scale
    assert {c.trace for c in token_cells} == {"flash", "surge"}
    assert all(c.scale == "micro" for c in token_cells)
    assert len(set(c.name for c in cells)) == len(cells)  # names are unique


def test_smoke_matrix_exercises_both_new_schedulers():
    scheds = {c.scheduler for c in smoke_matrix()}
    assert {"frag", "energy"} <= scheds
    fluid = [c for c in smoke_matrix() if c.serving == "fluid"]
    assert all(c.scale == "small" for c in fluid)
    # one token-serving cell keeps the discrete model in every CI run
    assert any(c.serving == "token" for c in smoke_matrix())


def test_registries_are_consistent():
    for cell in default_matrix():
        assert cell.trace in TRACE_SHAPES
        assert cell.scheduler in SCHEDULERS
        assert cell.scale in SCALES
        assert cell.slo in SLO_POLICIES
        assert cell.fault in FAULT_PROFILES
        assert cell.serving in ("fluid", "token")


def test_token_cell_name_is_suffixed_and_fluid_names_unchanged():
    """Fluid cells keep their exact historical names (report documents are
    keyed by them); token cells append the serving segment."""
    fluid = ScenarioCell("surge", "greedy", "small", "uniform")
    assert fluid.name == "surge/greedy/small/uniform/none"
    token = ScenarioCell("flash", "greedy", "micro", "uniform", serving="token")
    assert token.name == "flash/greedy/micro/uniform/none/token"


# -- cell execution and schema ---------------------------------------------------


def test_run_cell_produces_comparable_metrics():
    res, rep = run_cell(ScenarioCell("surge", "frag", "small", "uniform"), seed=0)
    d = res.to_dict()
    assert set(d["slo_satisfaction"]) == set(rep.services)
    assert 0.0 <= d["mean_attainment"] <= 1.0
    assert d["gpus_peak"] >= d["gpus_final"] >= 1
    assert d["gpus_asis"] >= 1
    assert d["gpus_saved"] == d["gpus_asis"] - d["gpus_peak"]
    assert d["reoptimize_latency_s"] >= 0.0
    assert d["power_w"] > 0.0
    assert len(d["report_sha256"]) == 64
    # the headline: MIG serving beats whole-GPU serving of the same demand
    assert d["gpus_saved"] >= 0


# -- determinism through the runner ----------------------------------------------


@given(seed=st.integers(0, 10))
@settings(max_examples=3, deadline=None)
def test_same_seed_byte_identical_through_scenario_runner(seed):
    cell = ScenarioCell("surge", "energy", "small", "tiered")
    res1, rep1 = run_cell(cell, seed)
    res2, rep2 = run_cell(cell, seed)
    assert rep1.to_json() == rep2.to_json()  # SimReport byte-identity
    assert res1.report_sha256 == res2.report_sha256
    assert res1.to_dict() == res2.to_dict()


def test_run_matrix_document_is_byte_identical():
    cells = smoke_matrix()
    b1 = json.dumps(run_matrix(cells, seed=3), sort_keys=True, separators=(",", ":"))
    b2 = json.dumps(run_matrix(cells, seed=3), sort_keys=True, separators=(",", ":"))
    assert b1 == b2
    b3 = json.dumps(run_matrix(cells, seed=4), sort_keys=True, separators=(",", ":"))
    assert b1 != b3  # the seed actually flows through


def test_schedulers_differentiate_somewhere():
    """The harness exists to compare policies: on the surge trace at small
    scale, at least one zoo policy must decide differently from greedy."""
    sha = {}
    for sched in ("greedy", "frag", "energy"):
        res, _ = run_cell(ScenarioCell("surge", sched, "small", "uniform"), seed=0)
        sha[sched] = res.report_sha256
    assert sha["frag"] != sha["greedy"] or sha["energy"] != sha["greedy"]


# -- correlated surge trace ------------------------------------------------------


class TestCorrelatedSurge:
    def test_seeded_and_reproducible(self):
        kw = dict(duration_s=7200, bin_s=60, surge_mult=4.0, n_surges=2,
                  surge_len_bins=10, correlation=0.8)
        t1 = correlated_surge_trace({"a": 10.0, "b": 20.0}, seed=5, **kw)
        t2 = correlated_surge_trace({"a": 10.0, "b": 20.0}, seed=5, **kw)
        t3 = correlated_surge_trace({"a": 10.0, "b": 20.0}, seed=6, **kw)
        for svc in ("a", "b"):
            np.testing.assert_array_equal(t1.rates[svc], t2.rates[svc])
        assert any(
            not np.array_equal(t1.rates[s], t3.rates[s]) for s in ("a", "b")
        )

    def test_services_surge_in_the_same_bins(self):
        tr = correlated_surge_trace(
            {"a": 10.0, "b": 100.0, "c": 55.0}, duration_s=7200, bin_s=60,
            surge_mult=4.0, n_surges=1, surge_len_bins=10, ramp_bins=2,
            correlation=0.9, seed=3,
        )
        elevated = {
            svc: set(np.flatnonzero(r > r.min() * 1.01).tolist())
            for svc, r in tr.rates.items()
        }
        # correlated: every service is elevated in exactly the same bins
        vals = list(elevated.values())
        assert vals[0] and all(v == vals[0] for v in vals)

    def test_surge_amplitude_respects_coupling_floor(self):
        tr = correlated_surge_trace(
            {"a": 10.0}, duration_s=3600, bin_s=60, surge_mult=5.0,
            n_surges=1, surge_len_bins=8, ramp_bins=1, correlation=0.5, seed=0,
        )
        peak = tr.rates["a"].max() / 10.0
        # coupling k in [0.5, 1]: peak in [1 + 4*0.5, 1 + 4*1]
        assert 3.0 - 1e-9 <= peak <= 5.0 + 1e-9


# -- per-service latency targets -------------------------------------------------


class TestLatencyTargets:
    def test_workload_for_applies_targets(self):
        prof = SyntheticPaperProfiles(n_models=3, seed=9)
        svcs = sorted(prof.services())
        targets = {svcs[0]: 50.0, svcs[1]: 200.0}
        driver = ReoptimizeDriver(
            a100_rules(), prof, latency_slo_ms=100.0, latency_targets=targets
        )
        wl = driver.workload_for({s: 100.0 for s in svcs})
        by_name = {s.name: s.slo.latency_ms for s in wl.services}
        assert by_name[svcs[0]] == 50.0
        assert by_name[svcs[1]] == 200.0
        assert by_name[svcs[2]] == 100.0  # fallback to the uniform SLO

    def test_tiered_policy_changes_the_run(self):
        cell_u = ScenarioCell("diurnal", "greedy", "small", "uniform")
        cell_t = ScenarioCell("diurnal", "greedy", "small", "tiered")
        res_u, _ = run_cell(cell_u, seed=0)
        res_t, _ = run_cell(cell_t, seed=0)
        assert res_u.report_sha256 != res_t.report_sha256

    def test_tiered_policy_maps_alternating_targets(self):
        default_lat, targets = SLO_POLICIES["tiered"](["a", "b", "c"])
        assert default_lat == 100.0
        assert targets == {"a": 50.0, "b": 200.0, "c": 50.0}
