"""Warm-start incremental reoptimization tests (ISSUE 8).

Contracts pinned here:

* **Rebind bit-identity** — ``ConfigSpace.rebind`` to a rate-drifted workload
  produces exactly the arrays a cold ``ConfigSpace`` build would (same IEEE
  divisions), so incumbent count vectors carry over index-for-index.
* **Warm determinism** — same seed + same incumbent => byte-identical
  deployment out of ``TwoPhaseOptimizer``.
* **Cold-solve fallbacks** — workload divergence beyond the threshold, or an
  add phase that blows the edit budget, falls back to a deployment equal to
  the cold solve *exactly* (same configs, same order).
* **Warm-start off is the default everywhere** and reproduces the recorded
  ``tests/golden/optimizer_golden.json`` behavior bit-for-bit.
* **warm_repair** trims over-provisioned capacity on downward drift while
  keeping every service complete.
* **transition_incremental** reaches the target content with creates
  strictly before deletes (the §6 transparency order).
* **Sim-level** — the ``greedy_warm`` scenario cell is seed-deterministic
  and its transitions stay transparent.
"""

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
import test_optimizer_golden as tg  # noqa: E402  (shared problem builders)

from repro.core import (  # noqa: E402
    ConfigSpace,
    Deployment,
    GeneticOptimizer,
    GreedyFast,
    SLO,
    TwoPhaseOptimizer,
    Workload,
    a100_rules,
)
from repro.core.cluster import SimulatedCluster  # noqa: E402
from repro.core.controller import (  # noqa: E402
    _config_content,
    _gpu_content,
)
from repro.core.deployment import IndexedDeployment  # noqa: E402
from repro.core.greedy import warm_repair  # noqa: E402
from repro.sim import ReoptimizeDriver, ScenarioCell, SimConfig, run_cell  # noqa: E402


def _problem():
    return tg._problem(6, 3, 7.4, a100_rules)


def _drift(wl: Workload, mult: float) -> Workload:
    return Workload.make(
        {s.name: SLO(s.slo.throughput * mult, s.slo.latency_ms) for s in wl.services}
    )


def _dep_bytes(dep: Deployment) -> bytes:
    return json.dumps([tg._canon(c) for c in dep.configs]).encode()


def _incumbent(space: ConfigSpace) -> IndexedDeployment:
    dep = Deployment(GreedyFast(space).produce(np.zeros(space.workload.n)))
    return IndexedDeployment.from_deployment(space, dep)


# -- rebind ---------------------------------------------------------------------


class TestRebind:
    def test_rebound_arrays_match_a_cold_build_bit_for_bit(self):
        prof, wl, space = _problem()
        wl2 = _drift(wl, 1.37)
        warm = space.rebind(wl2)
        cold = ConfigSpace(space.rules, prof, wl2)
        assert np.array_equal(warm.ua, cold.ua)
        assert np.array_equal(warm.ub, cold.ub)
        assert np.array_equal(warm.req, cold.req)
        assert warm.configs is space.configs  # enumeration is shared, not copied

    def test_rebind_refuses_incompatible_workloads(self):
        import pytest

        _, wl, space = _problem()
        changed_latency = Workload.make(
            {s.name: SLO(s.slo.throughput, 55.0) for s in wl.services}
        )
        assert not space.compatible(changed_latency)
        with pytest.raises(ValueError):
            space.rebind(changed_latency)


# -- optimizer warm path ---------------------------------------------------------


class TestWarmOptimizer:
    def test_same_seed_same_incumbent_byte_identical(self):
        prof, wl, space = _problem()
        inc = _incumbent(space)
        wl2 = _drift(wl, 1.3)

        def solve():
            sp = space.rebind(wl2)
            opt = TwoPhaseOptimizer(
                space.rules, prof, wl2, slow="greedy", ga_rounds=3,
                ga_population=4, seed=0, space=sp,
                incumbent=IndexedDeployment(sp, inc.counts.copy(), list(inc.extras)),
                incumbent_workload=wl,
                warm_divergence=4.0, warm_edit_frac=1.0,
            )
            return opt.run()

        r1, r2 = solve(), solve()
        assert r1.warm and r2.warm
        assert r1.warm_edits == r2.warm_edits
        assert _dep_bytes(r1.best_deployment) == _dep_bytes(r2.best_deployment)

    def test_large_divergence_falls_back_to_the_cold_solve_exactly(self):
        prof, wl, space = _problem()
        inc = _incumbent(space)
        wl2 = _drift(wl, 3.0)  # 200% drift >> 0.5 threshold
        sp = space.rebind(wl2)
        warm = TwoPhaseOptimizer(
            space.rules, prof, wl2, slow="greedy", ga_rounds=3, ga_population=4,
            seed=0, space=sp,
            incumbent=IndexedDeployment(sp, inc.counts.copy(), list(inc.extras)),
            incumbent_workload=wl, warm_divergence=0.5,
        ).run()
        cold = TwoPhaseOptimizer(
            space.rules, prof, wl2, slow="greedy", ga_rounds=3, ga_population=4,
            seed=0,
        ).run()
        assert not warm.warm
        assert warm.warm_fallback == "divergence"
        assert _dep_bytes(warm.best_deployment) == _dep_bytes(cold.best_deployment)

    def test_blown_edit_budget_falls_back_to_the_cold_solve_exactly(self):
        prof, wl, space = _problem()
        inc = _incumbent(space)
        wl2 = _drift(wl, 1.4)  # needs many adds, budget floor is 2
        sp = space.rebind(wl2)
        warm = TwoPhaseOptimizer(
            space.rules, prof, wl2, slow="greedy", ga_rounds=3, ga_population=4,
            seed=0, space=sp,
            incumbent=IndexedDeployment(sp, inc.counts.copy(), list(inc.extras)),
            incumbent_workload=wl, warm_divergence=4.0, warm_edit_frac=0.0,
        ).run()
        cold = TwoPhaseOptimizer(
            space.rules, prof, wl2, slow="greedy", ga_rounds=3, ga_population=4,
            seed=0,
        ).run()
        assert not warm.warm
        assert warm.warm_fallback == "edit_budget"
        assert _dep_bytes(warm.best_deployment) == _dep_bytes(cold.best_deployment)

    def test_warm_solution_is_valid_and_edit_bounded(self):
        prof, wl, space = _problem()
        inc = _incumbent(space)
        wl2 = _drift(wl, 1.3)
        sp = space.rebind(wl2)
        rep = TwoPhaseOptimizer(
            space.rules, prof, wl2, slow="greedy", ga_rounds=3, ga_population=4,
            seed=0, space=sp,
            incumbent=IndexedDeployment(sp, inc.counts.copy(), list(inc.extras)),
            incumbent_workload=wl, warm_divergence=4.0, warm_edit_frac=1.0,
        ).run()
        assert rep.warm
        assert rep.best_deployment.is_valid(wl2)
        from repro.core.ga import deployment_edit_distance

        budget = max(2, int(np.ceil(1.0 * inc.num_gpus)))
        assert (
            deployment_edit_distance(rep.best_deployment, inc.to_deployment())
            <= budget
        )


# -- greedy warm repair ----------------------------------------------------------


class TestWarmRepair:
    def test_downward_drift_trims_capacity(self):
        _, wl, space = _problem()
        inc = _incumbent(space)
        sp = space.rebind(_drift(wl, 0.6))
        inc2 = IndexedDeployment(sp, inc.counts.copy(), list(inc.extras))
        repaired, edits = warm_repair(sp, GreedyFast(sp), inc2)
        assert edits > 0
        assert repaired.num_gpus < inc.num_gpus
        assert repaired.to_deployment().is_valid(sp.workload)

    def test_repair_is_idempotent(self):
        """With no drift, a second repair finds nothing left to do: the trim
        phase is a fixpoint (it may trim greedy overshoot once, never twice).
        """
        _, wl, space = _problem()
        inc = _incumbent(space)
        once, edits1 = warm_repair(space, GreedyFast(space), inc)
        assert once.num_gpus + edits1 >= inc.num_gpus  # only trims, no adds
        twice, edits2 = warm_repair(space, GreedyFast(space), once)
        assert edits2 == 0
        assert np.array_equal(twice.counts, once.counts)


# -- GA incumbent bounding -------------------------------------------------------


class TestGABounding:
    def test_unbounded_incumbent_leaves_the_rng_stream_untouched(self):
        """Filtering happens after children are built, so a huge edit budget
        must reproduce the incumbent-free run exactly."""
        _, wl, space = _problem()
        seed_dep = Deployment(GreedyFast(space).produce(np.zeros(wl.n)))

        def run(**kw):
            ga = GeneticOptimizer(
                space, GreedyFast(space), population=4, rounds=3, seed=0
            )
            return ga.run(seed_dep, **kw)

        plain = run()
        bounded = run(incumbent=seed_dep, edit_budget=10**9)
        assert _dep_bytes(plain.best) == _dep_bytes(bounded.best)
        assert plain.history == bounded.history


# -- incremental transition ------------------------------------------------------


class TestTransitionIncremental:
    def _driver_cycle(self, mult):
        from repro.core import SyntheticPaperProfiles

        prof = SyntheticPaperProfiles(n_models=6, seed=3)
        rng = np.random.default_rng(3)
        rates = {m: float(rng.lognormal(7.4, 0.7)) for m in prof.services()}
        driver = ReoptimizeDriver(
            a100_rules(), prof, seed=0, warm_start=True,
            warm_divergence=4.0, warm_edit_frac=1.0,
        )
        cluster = SimulatedCluster(a100_rules(), 1)
        driver.initial_deploy(cluster, rates)
        n0 = len(cluster.actions_applied)
        driver.reoptimize(
            cluster, {s: r * mult for s, r in rates.items()}, now=0.0
        )
        return driver, cluster, cluster.actions_applied[n0:]

    def test_reaches_target_content_with_creates_before_deletes(self):
        driver, cluster, actions = self._driver_cycle(1.3)
        assert driver.last_optimize_report.warm
        kinds = [a.kind for a in actions]
        if "create" in kinds and "delete" in kinds:
            assert max(i for i, k in enumerate(kinds) if k == "create") < min(
                i for i, k in enumerate(kinds) if k == "delete"
            )
        target = sum(
            (_config_content(c) for c in driver._incumbent.to_deployment().configs),
            start=__import__("collections").Counter(),
        )
        got = sum(
            (_gpu_content(g) for g in cluster.gpus.values()),
            start=__import__("collections").Counter(),
        )
        assert got == target
        # surplus devices drained all the way to empty (reusable next cycle)
        assert all(
            not g.instances or g.busy() for g in cluster.gpus.values()
        )


# -- defaults and sim-level ------------------------------------------------------


class TestWarmOffDefaults:
    def test_warm_start_is_off_by_default_at_every_layer(self):
        from repro.core import SyntheticPaperProfiles

        assert SimConfig().warm_start is False
        prof = SyntheticPaperProfiles(n_models=3, seed=0)
        driver = ReoptimizeDriver(a100_rules(), prof)
        assert driver.warm_start is False
        _, wl, space = _problem()
        assert TwoPhaseOptimizer(
            space.rules, space.profile, wl, space=space
        ).incumbent is None

    def test_warm_off_reproduces_the_recorded_golden_greedy_entry(self):
        """The optimizer entry point without an incumbent must still emit the
        exact configs ``tests/golden/optimizer_golden.json`` records."""
        with open(tg.GOLDEN_PATH) as f:
            golden = json.load(f)
        for name, n, seed, scale, rules_factory in tg.PROBLEMS:
            prof, wl, space = tg._problem(n, seed, scale, rules_factory)
            rep = TwoPhaseOptimizer(
                space.rules, prof, wl, space=space, seed=0
            ).run(skip_phase2=True)
            assert not rep.warm
            want = golden["problems"][name]["greedy"]["configs"]
            assert [tg._canon(c) for c in rep.fast_deployment.configs] == want


class TestWarmScenarioCell:
    def test_cell_is_deterministic_and_transparent(self):
        cell = ScenarioCell("surge", "greedy_warm", "small", "uniform")
        r1, rep1 = run_cell(cell, seed=0)
        r2, rep2 = run_cell(cell, seed=0)
        assert rep1.to_json() == rep2.to_json()
        assert r1.report_sha256 == r2.report_sha256
        assert r1.transparent
