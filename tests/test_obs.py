"""Flight-recorder observability tests (repro.obs + the instrumented sim).

The contracts ISSUE 9 pins:

* byte identity — observability is strictly additive: for every curated
  cell class (fluid pin, token, fault, overload/priority, warm-start), a
  run with ``SimConfig.observability=True`` whose ``obs`` block is stripped
  re-serializes to *exactly* the pinned observability-off SHA from the
  existing golden files.  This is stronger than re-running with the flag
  off: it proves the instrumentation perturbs nothing it watches.
* golden pin — the curated obs cell's seeded report SHA, Perfetto trace
  SHA, span summary, flight-recorder accounting, and final counters live in
  ``tests/golden/obs_golden.json`` (plus the warm cell's baseline SHA,
  which no other golden records).  Regenerate intentionally with::

      PYTHONPATH=src python tests/test_obs.py --regen

* determinism — same seed, byte-identical obs-bearing report *and*
  byte-identical Chrome trace-event export.
* trace validity — the export is well-formed trace-event JSON (phases,
  non-negative durations, thread-name metadata per track), and the
  tracer's nesting discipline holds under arbitrary well-formed call
  sequences (property test) while malformed sequences raise.
* no wall clock — nothing under ``src/repro/obs/`` imports :mod:`time` or
  :mod:`datetime` (grep-proof over the sources), so the obs block cannot
  smuggle nondeterminism into the report bytes.
* the leaderboard report (``tools/report_scenarios.py``) renders the repo
  benchmark document byte-identically across runs.
* the real engine's ``ServeStats.summary()`` speaks the same metrics
  schema as the simulator's obs block (``serving.*`` counters, shared
  percentile keys), so ``launch/serve.py --stats-json`` output reads side
  by side with simulated cells.
"""

import hashlib
import json
import os
import re
import sys

import pytest

if __name__ == "__main__":  # regen mode runs without pytest/conftest
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_compat import given, settings, st

from repro.obs import FlightRecorder, MetricsRegistry, NullRegistry, Observability
from repro.obs.metrics import Histogram, percentile_summary
from repro.obs.trace import NullTracer, SpanTracer
from repro.sim import ScenarioCell, SimConfig, run_cell, run_cell_obs

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "obs_golden.json")

# the curated obs cell: token serving, so all three layers (tracer, metrics,
# flight recorder) are exercised — also the smoke/CI token cell
OBS_CELL = ScenarioCell("flash", "greedy", "micro", "uniform", serving="token")
# the warm-start cell has no golden of its own; obs_golden pins its
# observability-off baseline SHA so the byte-identity sweep covers it
WARM_CELL = ScenarioCell("surge", "greedy_warm", "small", "uniform")
# a fault cell: transitions with real §6 actions plus an inject->detect arc
FAULT_CELL = ScenarioCell("surge", "greedy", "small", "uniform", fault="gpu_loss")

# the byte-identity sweep: one cell per curated class, each mapped to the
# golden file + key path holding its pinned observability-off report SHA
IDENTITY_CELLS = [
    (
        ScenarioCell("diurnal", "greedy", "small", "uniform"),
        "servemodel_golden.json",
        ("fluid_pin", "report_sha256"),
    ),
    (
        OBS_CELL,
        "servemodel_golden.json",
        ("token_cells", "flash/greedy/micro/uniform/none/token@seed0",
         "report_sha256"),
    ),
    (
        FAULT_CELL,
        "controlplane_golden.json",
        ("cells", "surge/greedy/small/uniform/gpu_loss", "report_sha256"),
    ),
    (
        ScenarioCell("flash", "greedy", "micro", "uniform",
                     fault="instance_crash", serving="token",
                     priority="mixed"),
        "resilience_golden.json",
        ("overload_cells",
         "flash/greedy/micro/uniform/instance_crash/token/mixed@seed0",
         "report_sha256"),
    ),
    (
        WARM_CELL,
        "obs_golden.json",
        ("baseline_pins", "surge/greedy_warm/small/uniform/none@seed0",
         "report_sha256"),
    ),
]


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def _stripped_sha(rep) -> str:
    """SHA of the report with its ``obs`` key removed — must equal the
    observability-off SHA if the instrumentation is strictly additive."""
    d = rep.to_dict()
    assert "obs" in d, "observability was on; the obs block must serialize"
    d.pop("obs")
    return _sha(json.dumps(d, sort_keys=True, separators=(",", ":")))


def _pinned_sha(golden_file, key_path) -> str:
    with open(os.path.join(GOLDEN_DIR, golden_file)) as f:
        node = json.load(f)
    for k in key_path:
        node = node[k]
    return node


# one obs run of the curated cell is shared by several tests (sim runs are
# the expensive part; everything below reads the same artifacts)
_RUNS = {}


def _obs_run(cell, seed=0):
    key = (cell.name, seed)
    if key not in _RUNS:
        _RUNS[key] = run_cell_obs(cell, seed)
    return _RUNS[key]


def compute_golden():
    res, rep, trace_json = run_cell_obs(OBS_CELL, seed=0)
    obs = rep.obs
    warm_res, _ = run_cell(WARM_CELL, seed=0)  # observability OFF: the baseline
    return {
        "schema": 1,
        "obs_cell": {
            "cell": OBS_CELL.name,
            "seed": 0,
            "report_sha256": res.report_sha256,
            "trace_sha256": _sha(trace_json),
            "span_summary": obs["spans"],
            "flight": {
                k: obs["flight"][k]
                for k in ("record_limit", "tracked", "truncated")
            },
            "counters": obs["metrics"]["counters"],
        },
        "baseline_pins": {
            f"{WARM_CELL.name}@seed0": {
                "cell": WARM_CELL.name,
                "seed": 0,
                "report_sha256": warm_res.report_sha256,
            },
        },
    }


def _load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


# -- golden pin ------------------------------------------------------------------


def test_obs_golden_file_exists():
    assert os.path.exists(GOLDEN_PATH), (
        "golden file missing — regenerate with "
        "`PYTHONPATH=src python tests/test_obs.py --regen`"
    )


def test_obs_cell_matches_golden():
    res, rep, trace_json = _obs_run(OBS_CELL)
    want = _load_golden()["obs_cell"]
    obs = rep.obs
    got = {
        "cell": OBS_CELL.name,
        "seed": 0,
        "report_sha256": res.report_sha256,
        "trace_sha256": _sha(trace_json),
        "span_summary": obs["spans"],
        "flight": {
            k: obs["flight"][k] for k in ("record_limit", "tracked", "truncated")
        },
        "counters": obs["metrics"]["counters"],
    }
    assert got == want, (
        "seeded obs output diverged from the recorded behavior — if the "
        "drift is intentional, regen with "
        "`PYTHONPATH=src python tests/test_obs.py --regen`"
    )


# -- byte identity: obs is strictly additive -------------------------------------


@pytest.mark.parametrize(
    "cell,golden_file,key_path",
    IDENTITY_CELLS,
    ids=[c.name for c, _f, _k in IDENTITY_CELLS],
)
def test_stripping_obs_recovers_pinned_bytes(cell, golden_file, key_path):
    """obs-on report minus its obs key == the pinned observability-off SHA.

    Stronger than re-running with the flag off: proves the instrumented
    code paths (simulator bins, reoptimize driver, token serving model,
    fault arcs) compute exactly what they computed before the flag existed.
    """
    _res, rep, _trace = _obs_run(cell)
    assert _stripped_sha(rep) == _pinned_sha(golden_file, key_path), (
        f"{cell.name}: enabling observability changed the underlying "
        "report bytes — the flag must be strictly additive"
    )


# -- determinism -----------------------------------------------------------------


def test_same_seed_byte_identical_report_and_trace():
    _res1, rep1, trace1 = _obs_run(OBS_CELL)
    _res2, rep2, trace2 = run_cell_obs(OBS_CELL, seed=0)
    assert rep1.to_json() == rep2.to_json()
    assert trace1 == trace2


# -- Chrome trace-event validity -------------------------------------------------


def test_trace_export_is_valid_chrome_trace_json():
    _res, _rep, trace_json = _obs_run(OBS_CELL)
    doc = json.loads(trace_json)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events, "the obs cell must record spans"
    meta_tids, used_tids = set(), set()
    for ev in events:
        assert ev["ph"] in ("M", "X", "i"), ev
        assert ev["pid"] == 0
        if ev["ph"] == "M":
            assert ev["name"] == "thread_name" and ev["args"]["name"]
            meta_tids.add(ev["tid"])
            continue
        used_tids.add(ev["tid"])
        assert ev["ts"] >= 0.0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
        else:  # instants are thread-scoped markers
            assert ev["s"] == "t"
    # every track used by an event is named by thread metadata (Perfetto
    # renders the row labels from these)
    assert used_tids <= meta_tids
    # the token cell puts serving bins and the reoptimize cycle on the
    # timeline (its one transition is a no-op plan, so no actions track)
    names = {ev["args"]["name"] for ev in events if ev["ph"] == "M"}
    assert {"reoptimize", "serving"} <= names


def test_fault_cell_traces_actions_and_fault_arc():
    """The gpu_loss cell exercises the §6 action spans and the fault
    inject->detect instrumentation the token cell's no-op transition
    cannot."""
    _res, rep, trace_json = _obs_run(FAULT_CELL)
    doc = json.loads(trace_json)
    by_track = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] != "M":
            by_track.setdefault(ev["cat"], []).append(ev)
    assert {"reoptimize", "actions", "faults"} <= set(by_track)
    # per-action spans carry the action kind and land inside some
    # transition's execute window
    executes = [
        (e["ts"], e["ts"] + e["dur"])
        for e in by_track["reoptimize"]
        if e["name"] == "execute"
    ]
    assert executes
    for ev in by_track["actions"]:
        assert ev["name"] in ("create", "destroy", "migrate", "repartition")
        assert any(
            t0 - 1e-3 <= ev["ts"] and ev["ts"] + ev["dur"] <= t1 + 1e-3
            for t0, t1 in executes
        ), f"action span outside every execute window: {ev}"
    fault_names = {e["name"] for e in by_track["faults"]}
    assert any(n.startswith("inject:") for n in fault_names)
    assert any(n.startswith("detect:") for n in fault_names)
    counters = rep.obs["metrics"]["counters"]
    assert counters["faults.injected"] >= 1.0
    assert counters["transitions"] >= 1.0
    assert counters["admission.shed"] > 0.0  # degraded-mode shedding fired


def test_span_summary_counts_match_trace_export():
    _res, rep, trace_json = _obs_run(OBS_CELL)
    doc = json.loads(trace_json)
    non_meta = [ev for ev in doc["traceEvents"] if ev["ph"] != "M"]
    assert rep.obs["spans"]["events"] == len(non_meta)
    assert sum(rep.obs["spans"]["tracks"].values()) == len(non_meta)


# -- tracer unit + property coverage ---------------------------------------------


class TestSpanTracer:
    def test_span_rejects_negative_duration(self):
        tr = SpanTracer()
        with pytest.raises(ValueError, match="ends before it starts"):
            tr.span("t", "bad", 5.0, 4.0)
        tr.span("t", "tick", 5.0, 5.0)  # zero-duration is fine

    def test_end_without_begin_raises(self):
        tr = SpanTracer()
        with pytest.raises(RuntimeError, match="without begin"):
            tr.end("t", 1.0)

    def test_leaked_begin_fails_well_formedness_and_export(self):
        tr = SpanTracer()
        tr.begin("t", "open", 0.0)
        with pytest.raises(RuntimeError, match="left open"):
            tr.assert_well_formed()
        with pytest.raises(RuntimeError, match="left open"):
            tr.export_json()

    def test_child_cannot_begin_before_parent(self):
        tr = SpanTracer()
        tr.begin("t", "parent", 10.0)
        with pytest.raises(ValueError, match="before its"):
            tr.begin("t", "child", 9.0)

    def test_begin_end_merges_args_and_emits_complete_event(self):
        tr = SpanTracer()
        tr.begin("t", "s", 1.0, args={"a": 1})
        tr.end("t", 3.0, args={"b": 2})
        doc = tr.chrome_trace()
        (ev,) = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert ev["name"] == "s"
        assert ev["ts"] == 1.0e6 and ev["dur"] == 2.0e6  # sim s -> trace us
        assert ev["args"] == {"a": 1, "b": 2}

    def test_null_tracer_is_inert(self):
        tr = NullTracer()
        tr.begin("t", "x", 0.0)
        tr.span("t", "y", 0.0, 1.0)
        tr.instant("t", "z", 0.5)
        tr.end("t", 1.0)  # no begin-tracking, no raise
        tr.assert_well_formed()
        assert tr.span_summary() == {}
        assert json.loads(tr.export_json()) == {
            "displayTimeUnit": "ms",
            "traceEvents": [],
        }

    @given(
        durs=st.lists(
            st.floats(min_value=0.0, max_value=10.0, allow_nan=False),
            min_size=1,
            max_size=8,
        )
    )
    @settings(max_examples=25, deadline=None)
    def test_any_nested_begin_end_sequence_exports_cleanly(self, durs):
        """Strictly nested opens at nondecreasing times always close into a
        well-formed export with one X event per begin."""
        tr = SpanTracer()
        t = 0.0
        for i, d in enumerate(durs):
            tr.begin("trk", f"s{i}", t)
            t += d
        for _ in durs:
            tr.end("trk", t)
        tr.assert_well_formed()
        doc = json.loads(tr.export_json())
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert len(xs) == len(durs)
        assert all(e["dur"] >= 0.0 for e in xs)


# -- metrics registry ------------------------------------------------------------


class TestMetrics:
    def test_counter_rejects_negative_and_backwards(self):
        m = MetricsRegistry()
        c = m.counter("x")
        c.inc(2.0)
        with pytest.raises(ValueError, match=">= 0"):
            c.inc(-1.0)
        with pytest.raises(ValueError, match="backwards"):
            c.inc_to(1.0)
        c.inc_to(5.0)
        assert c.value == 5.0

    def test_cross_kind_name_collision_raises(self):
        m = MetricsRegistry()
        m.counter("queue.depth")
        with pytest.raises(ValueError, match="already registered"):
            m.gauge("queue.depth")
        with pytest.raises(ValueError, match="already registered"):
            m.histogram("queue.depth")
        assert m.counter("queue.depth") is m.counter("queue.depth")

    def test_late_metric_series_backfilled_with_zeros(self):
        m = MetricsRegistry()
        m.counter("early").inc(1.0)
        m.sample(0.0)
        m.sample(1.0)
        m.gauge("late").set(7.0)
        m.sample(2.0)
        s = m.snapshot()["series"]
        assert s["t_s"] == [0.0, 1.0, 2.0]
        assert s["counters"]["early"] == [1.0, 1.0, 1.0]
        assert s["gauges"]["late"] == [0.0, 0.0, 7.0]

    def test_histogram_buckets_by_upper_bound(self):
        h = Histogram(bounds=(1.0, 10.0))
        for v in (0.5, 1.0, 2.0, 10.0, 11.0, 1e9):
            h.observe(v)
        # side="left": a value equal to a bound lands at that bound's bucket
        assert h.buckets == [2, 2, 2]  # (<=1.0], (1.0, 10.0], (10.0, +inf)
        assert h.count == 6
        assert h.total == pytest.approx(0.5 + 1.0 + 2.0 + 10.0 + 11.0 + 1e9)

    def test_percentile_summary_schema(self):
        empty = percentile_summary([], "ttft")
        assert empty == {"ttft_p50_s": 0.0, "ttft_p95_s": 0.0, "ttft_p99_s": 0.0}
        full = percentile_summary([1.0, 2.0, 3.0], "tpot")
        assert set(full) == {"tpot_p50_s", "tpot_p95_s", "tpot_p99_s"}
        assert full["tpot_p50_s"] == 2.0

    def test_null_registry_is_inert(self):
        m = NullRegistry()
        m.counter("x").inc(5.0)
        m.gauge("y").set(1.0)
        m.histogram("z").observe(2.0)
        m.sample(0.0)
        assert m.snapshot() == {}
        assert not m.enabled


# -- flight recorder -------------------------------------------------------------


class TestFlightRecorder:
    def test_negative_record_limit_raises(self):
        with pytest.raises(ValueError, match=">= 0"):
            FlightRecorder(record_limit=-1)
        with pytest.raises(ValueError, match="obs_record_limit"):
            SimConfig(obs_record_limit=-1)

    def test_truncation_past_the_limit(self):
        fr = FlightRecorder(record_limit=2)
        for rid in range(4):
            fr.arrival(rid, "svc", float(rid))
        snap = fr.snapshot()
        assert snap["tracked"] == 2 and snap["truncated"] == 2
        assert [r["rid"] for r in snap["requests"]] == [0, 1]
        # events on untracked requests are silent no-ops, not errors
        fr.note(3, "admitted", 4.0)
        fr.close(3, "completed", 5.0)
        assert fr.snapshot()["tracked"] == 2

    def test_duplicate_arrival_ignored(self):
        fr = FlightRecorder()
        fr.arrival(0, "svc", 0.0)
        fr.arrival(0, "svc", 9.0)
        (rec,) = fr.snapshot()["requests"]
        assert rec["arrival_s"] == 0.0 and len(rec["events"]) == 1

    def test_lifecycle_counters_and_terminal_cause(self):
        fr = FlightRecorder()
        fr.arrival(7, "svc", 0.0, priority=0, deadline_s=5.0)
        fr.note(7, "admitted", 0.1, instance=3)
        fr.note(7, "preempted", 0.5, cause="kv_pressure")
        fr.note(7, "backoff", 0.6)
        fr.note(7, "migrated", 0.9)
        fr.close(7, "deadline_dropped", 5.0, cause="deadline")
        (rec,) = fr.snapshot()["requests"]
        assert rec["preemptions"] == 2  # preempted + migrated
        assert rec["retries"] == 1
        assert rec["outcome"] == "deadline_dropped" and rec["cause"] == "deadline"
        assert rec["deadline_s"] == 5.0
        assert [e["event"] for e in rec["events"]] == [
            "arrival", "admitted", "preempted", "backoff", "migrated",
            "deadline_dropped",
        ]

    def test_record_limit_flows_through_the_bundle(self):
        obs = Observability.on(record_limit=3)
        assert obs.flight.record_limit == 3
        off = Observability.off()
        assert not off.enabled and off.flight is None
        assert off.tracer.span_summary() == {} and off.metrics.snapshot() == {}


def test_obs_cell_flight_block_is_bounded_and_attributed():
    _res, rep, _trace = _obs_run(OBS_CELL)
    flight = rep.obs["flight"]
    assert flight["tracked"] <= flight["record_limit"] == 256
    assert flight["truncated"] > 0  # the micro flash crowd overflows 256
    outcomes = {r["outcome"] for r in flight["requests"]}
    assert "completed" in outcomes
    for rec in flight["requests"]:
        assert rec["events"][0]["event"] == "arrival"
        ts = [e["t_s"] for e in rec["events"]]
        assert ts == sorted(ts)  # lifecycle events in sim-time order


# -- no wall clock in the obs sources --------------------------------------------


def test_obs_sources_never_import_wall_clock():
    """Grep-proof: the obs package is sim-time only.  A wall-clock read
    anywhere under src/repro/obs would leak nondeterminism into the obs
    block (and the trace export), breaking the byte-determinism contract."""
    obs_dir = os.path.join(REPO_ROOT, "src", "repro", "obs")
    forbidden_import = re.compile(
        r"^\s*(import time\b|from time\b|import datetime\b|from datetime\b)",
        re.MULTILINE,
    )
    checked = 0
    for fn in sorted(os.listdir(obs_dir)):
        if not fn.endswith(".py"):
            continue
        with open(os.path.join(obs_dir, fn)) as f:
            src = f.read()
        assert not forbidden_import.search(src), f"{fn} imports wall clock"
        for needle in ("time.time(", "perf_counter", "monotonic("):
            assert needle not in src, f"{fn} reads wall clock via {needle}"
        checked += 1
    assert checked >= 4  # __init__, trace, metrics, flight


# -- the leaderboard report ------------------------------------------------------


def _report_tool():
    import importlib.util

    path = os.path.join(REPO_ROOT, "tools", "report_scenarios.py")
    spec = importlib.util.spec_from_file_location("report_scenarios", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_report_renders_repo_bench_deterministically(tmp_path):
    mod = _report_tool()
    bench = os.path.join(REPO_ROOT, "BENCH_scenarios.json")
    out1 = str(tmp_path / "a.html")
    out2 = str(tmp_path / "b.html")
    assert mod.main(["--bench", bench, "--out", out1, "--no-git"]) == 0
    assert mod.main(["--bench", bench, "--out", out2, "--no-git"]) == 0
    with open(out1, "rb") as f1, open(out2, "rb") as f2:
        a, b = f1.read(), f2.read()
    assert a == b, "the report must be byte-deterministic"
    assert a.startswith(b"<!DOCTYPE html>")
    assert b"<svg" in a  # the per-axis charts rendered
    with open(bench) as f:
        n_cells = len(json.load(f)["cells"])
    assert f"{n_cells} cells".encode() in a


def test_report_rejects_cell_free_documents(tmp_path):
    mod = _report_tool()
    bad = tmp_path / "bad.json"
    bad.write_text('{"schema": 1, "cells": {}}')
    with pytest.raises(SystemExit, match="no cells"):
        mod.main(["--bench", str(bad), "--no-git"])


def test_report_compare_mode_diffs_cell_by_cell(tmp_path):
    """``--compare A B`` renders added/removed cells and per-metric deltas
    as a deterministic HTML section."""
    mod = _report_tool()
    with open(os.path.join(REPO_ROOT, "BENCH_scenarios.json")) as f:
        doc_a = json.load(f)

    doc_b = json.loads(json.dumps(doc_a))  # deep copy
    keys = sorted(doc_b["cells"])
    changed_key, removed_key = keys[0], keys[1]
    doc_b["cells"][changed_key]["gpus_peak"] += 2
    doc_b["cells"][changed_key]["mean_attainment"] -= 0.125
    del doc_b["cells"][removed_key]
    added_key = "synthetic/extra/cell"
    doc_b["cells"][added_key] = json.loads(
        json.dumps(doc_a["cells"][changed_key])
    )

    path_a = tmp_path / "a.json"
    path_b = tmp_path / "b.json"
    path_a.write_text(json.dumps(doc_a))
    path_b.write_text(json.dumps(doc_b))

    # the structural diff is exact
    diff = mod.compare_cells(doc_a, doc_b)
    assert diff["added"] == [added_key]
    assert diff["removed"] == [removed_key]
    assert sorted(diff["changed"]) == [changed_key]
    assert set(diff["changed"][changed_key]) == {"gpus_peak", "mean_attainment"}
    assert len(diff["unchanged"]) == len(doc_a["cells"]) - 2

    # the CLI writes a deterministic page naming every bucket
    out1, out2 = str(tmp_path / "d1.html"), str(tmp_path / "d2.html")
    assert mod.main(["--compare", str(path_a), str(path_b), "--out", out1]) == 0
    assert mod.main(["--compare", str(path_a), str(path_b), "--out", out2]) == 0
    with open(out1, "rb") as f1, open(out2, "rb") as f2:
        page, page2 = f1.read(), f2.read()
    assert page == page2, "the comparison must be byte-deterministic"
    for needle in (added_key, removed_key, changed_key, "gpus_peak", "+2"):
        assert needle.encode() in page, needle
    assert b"1 added" in page and b"1 removed" in page and b"1 changed" in page

    # default out path derives from B; identical docs report no drift
    assert mod.main(["--compare", str(path_a), str(path_a)]) == 0
    with open(str(tmp_path / "a_compare.html"), "rb") as f:
        same = f.read()
    assert b"No per-metric drift" in same and b"0 added" in same


# -- engine stats speak the obs schema -------------------------------------------


def test_serve_stats_summary_matches_obs_metrics_schema():
    pytest.importorskip("jax")
    from repro.serving.engine import ServeStats

    stats = ServeStats(
        served=3, tokens=12, preempted=1, refused=2, wall_s=2.0,
        ttft_s=[0.1, 0.2, 0.3], tpot_s=[0.01, 0.02],
    )
    s = stats.summary("modelA")
    assert s["service"] == "modelA"
    # counter names follow the MetricsRegistry convention the sim emits
    assert set(s["counters"]) == {
        "serving.completed", "serving.preemptions", "serving.refusals",
        "serving.tokens",
    }
    assert s["counters"]["serving.completed"] == 3.0
    assert set(s["latency"]) == {
        "ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
        "tpot_p50_s", "tpot_p95_s", "tpot_p99_s",
    }
    assert s["latency"]["ttft_p50_s"] == pytest.approx(0.2)
    assert s["throughput_rps"] == pytest.approx(1.5)
    # the schema is JSON-clean (what --stats-json writes)
    json.dumps(s, sort_keys=True)


# -- the obs block itself --------------------------------------------------------


def test_obs_block_structure_and_metric_coverage():
    _res, rep, _trace = _obs_run(OBS_CELL)
    obs = rep.obs
    assert set(obs) == {"flight", "metrics", "spans"}
    counters = obs["metrics"]["counters"]
    assert {"serving.completed", "serving.preemptions", "serving.refusals",
            "transitions"} <= set(counters)
    series = obs["metrics"]["series"]
    n = len(series["t_s"])
    assert n > 0
    for kind in ("counters", "gauges"):
        for name, vals in series[kind].items():
            assert len(vals) == n, f"series {kind}:{name} misaligned"
    # the pages gauges only exist in token mode; this is a token cell
    assert "pages.used" in obs["metrics"]["gauges"]
    hist = obs["metrics"]["histograms"]["transition.parallel_s"]
    assert hist["count"] == counters["transitions"] > 0


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        data = compute_golden()
        with open(GOLDEN_PATH, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    else:
        print("run under pytest, or with --regen to rewrite the golden file")
