"""Dry-run integration: the production mesh lowers+compiles in a subprocess
(the 512-device XLA flag must be set before jax initialises, so these tests
shell out instead of importing repro.launch.dryrun in-process)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(REPO, "src")


def run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, timeout=560,
    )


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch,shape",
    [("internvl2-1b", "decode_32k"), ("mamba2-370m", "long_500k")],
)
def test_single_pod_lowers(arch, shape, tmp_path):
    r = run_dryrun("--arch", arch, "--shape", shape, "--out-dir", str(tmp_path))
    assert r.returncode == 0, r.stdout + r.stderr
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    d = json.loads(files[0].read_text())
    assert d["chips"] == 256
    assert d["flops_per_device"] > 0
    assert d["compile_seconds"] > 0


@pytest.mark.slow
def test_multi_pod_lowers(tmp_path):
    r = run_dryrun(
        "--arch", "internvl2-1b", "--shape", "train_4k",
        "--multi-pod", "--out-dir", str(tmp_path),
    )
    assert r.returncode == 0, r.stdout + r.stderr
    d = json.loads(next(tmp_path.glob("*.json")).read_text())
    assert d["chips"] == 512
    assert d["mesh"].startswith("2x16x16")
    # gradient sync across the pod axis must appear as collectives
    assert sum(d["collective_bytes_per_device"].values()) > 0
