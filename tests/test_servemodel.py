"""Token-level serving model tests (repro.sim.servemodel).

Pins the properties ISSUE 6 names for ``SimConfig.serving_model="token"``:

* golden pin — the curated token scenario cell's seeded report SHA and its
  TTFT/TPOT/queue-delay summary are recorded byte-for-byte in
  ``tests/golden/servemodel_golden.json`` (same contract as the optimizer
  and scheduler-zoo goldens), alongside a fluid-cell SHA pin proving the
  token-model wiring left the fluid path's bytes untouched.  Regenerate
  (only on intentional behavior changes) with::

      PYTHONPATH=src python tests/test_servemodel.py --regen

* determinism — same seed, byte-identical token ``SimReport.to_json()``;
  the token-only keys (serving_model / latency / preempted / refused) are
  present in token mode and absent in fluid mode.
* conservation — every drawn arrival is accounted for: per service,
  ``sum(arrivals) == completed + in_system`` (and the served series sums to
  the completion count), over arbitrary seeds.
* calibration — the §8.3 loop: a real Engine run feeds a
  ``MeasuredProfile``; the token model built on the corrected profile
  reproduces the engine's measured throughput within tolerance.
* unit coverage of the engine-twin mechanics: page-pool floor, admission
  refusals, mid-decode preemption + resume, max_len truncation, TTFT and
  queue-delay observation, instance-loss spill.
"""

import json
import os
import sys

import numpy as np
import pytest

if __name__ == "__main__":  # regen mode runs without pytest/conftest
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_compat import given, settings, st

from repro.core import SyntheticPaperProfiles, a100_rules
from repro.core.online_profiles import MeasuredProfile
from repro.sim import (
    ClusterSimulator,
    ScenarioCell,
    SimConfig,
    TokenKnobs,
    TokenRequest,
    TokenServingState,
    Trace,
    run_cell,
)
from repro.sim.servemodel import InstanceModel, TokenMetrics

GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden", "servemodel_golden.json"
)

# the curated token slice's smoke cell (also in smoke_matrix / CI)
TOKEN_CELL = ScenarioCell("flash", "greedy", "micro", "uniform", serving="token")
# a historical fluid cell: its SHA must never move when token code changes
FLUID_PIN_CELL = ScenarioCell("diurnal", "greedy", "small", "uniform")


def compute_golden():
    golden = {"schema": 1, "token_cells": {}, "fluid_pin": {}}
    res, rep = run_cell(TOKEN_CELL, seed=0)
    golden["token_cells"][f"{TOKEN_CELL.name}@seed0"] = {
        "report_sha256": res.report_sha256,
        "latency": rep.latency,
    }
    fres, _ = run_cell(FLUID_PIN_CELL, seed=0)
    golden["fluid_pin"] = {
        "cell": FLUID_PIN_CELL.name,
        "seed": 0,
        "report_sha256": fres.report_sha256,
    }
    return golden


def _load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


# -- golden pins -----------------------------------------------------------------


def test_servemodel_golden_file_exists():
    assert os.path.exists(GOLDEN_PATH), (
        "golden file missing — regenerate with "
        "`PYTHONPATH=src python tests/test_servemodel.py --regen`"
    )


def test_token_cell_and_fluid_pin_match_golden():
    got = compute_golden()
    want = _load_golden()
    assert got["fluid_pin"] == want["fluid_pin"], (
        "the fluid path's bytes moved — the token model must leave "
        "serving_model='fluid' runs bit-identical"
    )
    assert got["token_cells"] == want["token_cells"], (
        "token-model seeded output diverged from the recorded behavior"
    )


# -- a tiny direct token simulation (no scenario harness) -------------------------


def _token_sim(seed, serving_model="token"):
    prof = SyntheticPaperProfiles(n_models=2, seed=2)
    svcs = sorted(prof.services())
    rates = {
        svcs[0]: np.array([30.0, 30.0, 90.0, 90.0, 30.0, 30.0]),
        svcs[1]: np.full(6, 20.0),
    }
    trace = Trace(bin_s=20.0, rates=rates)
    cfg = SimConfig(
        reoptimize_every_s=60.0,
        seed=seed,
        serving_model=serving_model,
        token_knobs=(
            TokenKnobs(profiled_decode_tokens=4)
            if serving_model == "token"
            else None
        ),
    )
    return ClusterSimulator(a100_rules(), prof, trace, cfg)


# -- determinism + serialization schema -------------------------------------------


def test_same_seed_byte_identical_token_report():
    r1 = _token_sim(5).run()
    r2 = _token_sim(5).run()
    assert r1.to_json() == r2.to_json()
    r3 = _token_sim(6).run()
    assert r1.to_json() != r3.to_json()  # the seed actually flows through


def test_token_keys_only_serialized_in_token_mode():
    tok = _token_sim(1).run().to_dict()
    assert tok["serving_model"] == "token"
    assert isinstance(tok["latency"], dict) and "_totals" in tok["latency"]
    for tl in tok["timelines"].values():
        assert "preempted" in tl and "refused" in tl
    fluid = _token_sim(1, serving_model="fluid").run().to_dict()
    assert "serving_model" not in fluid and "latency" not in fluid
    for tl in fluid["timelines"].values():
        assert "preempted" not in tl and "refused" not in tl


def test_token_latency_summary_schema():
    rep = _token_sim(2).run()
    tot = rep.latency["_totals"]
    assert set(tot) == {"preemptions", "refusals", "completed"}
    for svc in rep.services:
        entry = rep.latency[svc]
        for prefix in ("ttft", "tpot", "queue_delay"):
            for p in (50, 95, 99):
                assert entry[f"{prefix}_p{p}_s"] >= 0.0
        # percentiles are monotone
        assert entry["ttft_p50_s"] <= entry["ttft_p95_s"] <= entry["ttft_p99_s"]
    assert tot["completed"] == sum(
        rep.latency[s]["completed"] for s in rep.services
    )


# -- conservation ------------------------------------------------------------------


@given(seed=st.integers(0, 20))
@settings(max_examples=4, deadline=None)
def test_every_arrival_is_accounted_for(seed):
    """Discrete requests cannot leak: per service, the drawn arrivals all
    end up either completed or still in the system, and the per-bin served
    series sums to exactly the completion count."""
    rep = _token_sim(seed).run()
    for svc in rep.services:
        tl = rep.timelines[svc]
        arrived = int(np.sum(tl.arrivals))
        served = int(np.sum(tl.served))
        completed = rep.latency[svc]["completed"]
        in_system = rep.latency[svc]["in_system"]
        assert served == completed
        assert arrived == completed + in_system, (
            svc, arrived, completed, in_system,
        )
        # final backlog sample agrees with the in-system count
        assert int(tl.backlog[-1]) == in_system


# -- calibration against the real Engine (§8.3) -----------------------------------


def test_token_model_calibrates_to_measured_engine_throughput():
    """The MeasuredProfile loop: run the real Engine, feed its measured
    throughput into the profile (ewma=1.0 -> corrected == measured), build
    the token model on the corrected profile with the engine's geometry,
    and check the model reproduces the engine's request throughput."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.serving import Engine, Request, run_closed_loop

    BATCH, MAX_LEN, PROMPT, DECODE = 4, 64, 6, 8
    cfg = get_smoke_config("qwen3-8b")
    m = Model(cfg, remat=False)
    params, _ = m.init(jax.random.PRNGKey(0))
    eng = Engine(
        m, params, batch=BATCH, max_len=MAX_LEN,
        kv_backend="paged", page_size=4, num_pages=8 * BATCH,
    )
    rng = np.random.default_rng(0)

    def make_reqs(n, rid0=0):
        return [
            Request(
                rid=rid0 + i,
                prompt=rng.integers(1, cfg.vocab_size, size=PROMPT).astype(
                    np.int32
                ),
                max_new_tokens=DECODE,
            )
            for i in range(n)
        ]

    run_closed_loop(eng, make_reqs(BATCH))  # warm the jit caches
    base = SyntheticPaperProfiles(n_models=2, seed=2)
    svc = sorted(base.services())[0]
    measured = MeasuredProfile(base, ewma=1.0)
    N = 24
    stats = run_closed_loop(
        eng, make_reqs(N, rid0=100), measured=measured, service=svc, size=1
    )
    assert stats.served == N
    assert measured.correction(svc, 1) != 1.0  # the observation landed

    # token model on the corrected profile, matching the engine's shape;
    # page pool oversized on both sides so KV pressure plays no role here
    knobs = TokenKnobs(
        prompt_tokens=PROMPT,
        decode_tokens=DECODE,
        profiled_decode_tokens=DECODE,
        max_len=MAX_LEN,
        page_size=4,
        hbm_gb_per_unit=1.0,
        prefill_chunk=PROMPT,
    )
    state = TokenServingState([svc], measured, lambda s: 1e9, knobs)
    inst = InstanceModel(
        0, svc, 1, slots=BATCH, knobs=knobs,
        step_time_s=state.step_time_for(svc, 1), now=0.0,
    )
    metrics = TokenMetrics([svc])
    for i in range(N):
        inst.queue.append(TokenRequest(i, svc, 0.0, PROMPT, DECODE))
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at[svc]) == N
    makespan = max(metrics.completed_at[svc])
    model_tput = N / makespan
    rel = abs(model_tput - stats.throughput) / stats.throughput
    assert rel <= 0.35, (
        f"token model {model_tput:.2f} req/s vs engine "
        f"{stats.throughput:.2f} req/s (rel err {rel:.2f})"
    )


# -- engine-twin mechanics ---------------------------------------------------------


def _small_knobs(**over):
    kw = dict(
        prompt_tokens=8, decode_tokens=4, max_len=16, page_size=4,
        hbm_gb_per_unit=1e-12,  # floor-limited pool: max_pages_per_req pages
        prefill_chunk=4,
    )
    kw.update(over)
    return TokenKnobs(**kw)


def _instance(knobs, slots=4, svc="svc"):
    return InstanceModel(
        0, svc, 1, slots=slots, knobs=knobs,
        step_time_s=lambda b: 0.01, now=0.0,
    )


def test_num_pages_flooring_fits_one_max_context_request():
    knobs = _small_knobs()
    # max_len 16 + the one-ahead decode write, page_size 4 -> 5 pages
    assert knobs.max_pages_per_req == 5
    assert knobs.num_pages(1) == 5  # tiny budget floors at one full request
    big = TokenKnobs(max_len=16, page_size=4, hbm_gb_per_unit=1.0)
    assert big.num_pages(2) == 2 * big.num_pages(1) > big.max_pages_per_req


def test_admission_refusal_counts_and_recovers():
    """Two long-prompt requests against a one-request pool: the second is
    refused (OutOfPages) until the first finishes, then completes — and the
    refusal counter records each failed admission attempt."""
    knobs = _small_knobs()
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    # prompt 10 -> reserve 11 tokens = 3 of the 5 pages; two cannot coexist
    inst.queue.append(TokenRequest(0, "svc", 0.0, 10, 2))
    inst.queue.append(TokenRequest(1, "svc", 0.0, 10, 2))
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at["svc"]) == 2
    assert metrics.refusals["svc"] >= 1
    assert inst.in_system == 0
    # both requests got TTFT + queue-delay observations; the refused one
    # waited, so its queueing delay is strictly positive
    assert len(metrics.ttft_s["svc"]) == 2
    assert len(metrics.queue_delay_s["svc"]) == 2
    assert max(metrics.queue_delay_s["svc"]) > 0.0
    assert min(metrics.queue_delay_s["svc"]) == 0.0


def test_mid_decode_preemption_resumes_and_completes():
    """Exact-fit pool: two live requests decode until one cannot grow its
    pages, gets preempted (pages released, generated tokens kept), resumes,
    and still completes its full budget."""
    knobs = _small_knobs()
    inst = _instance(knobs, slots=2)
    metrics = TokenMetrics(["svc"])
    # A: prompt 10 -> 3 pages; B: prompt 6 -> 2 pages; pool is 5 pages, so
    # the first mid-decode page growth must preempt somebody
    a = TokenRequest(0, "svc", 0.0, 10, 4)
    b = TokenRequest(1, "svc", 0.0, 6, 8)
    inst.queue.extend([a, b])
    inst.run_until(1e9, metrics)
    assert len(metrics.completed_at["svc"]) == 2
    assert metrics.preemptions["svc"] >= 1
    assert a.preemptions + b.preemptions == metrics.preemptions["svc"]
    assert inst.in_system == 0
    assert len(inst.pool._free) == knobs.num_pages(1)  # all pages returned


def test_max_len_truncates_like_the_engine():
    knobs = _small_knobs(hbm_gb_per_unit=1.0)
    inst = _instance(knobs, slots=1)
    metrics = TokenMetrics(["svc"])
    req = TokenRequest(0, "svc", 0.0, 10, 20)  # budget exceeds context room
    inst.queue.append(req)
    inst.run_until(1e9, metrics)
    assert req.finish_s > 0.0
    assert req.context_len == knobs.max_len  # truncated at the cap
    assert req.generated == knobs.max_len - 10 < 20


def test_make_request_draws_are_servable_and_rids_unique():
    prof = SyntheticPaperProfiles(n_models=2, seed=2)
    svc = sorted(prof.services())[0]
    state = TokenServingState([svc], prof, lambda s: 100.0, TokenKnobs())
    rng = np.random.default_rng(0)
    reqs = [state.make_request(svc, 0.0, rng) for _ in range(300)]
    assert len({r.rid for r in reqs}) == len(reqs)
    for r in reqs:
        assert r.prompt_tokens >= 1 and r.decode_tokens >= 1
        # prompt + budget + the one-ahead write always fit the context cap
        assert r.prompt_tokens + r.decode_tokens < state.knobs.max_len


def test_vanished_instance_spills_requests_and_counts_preemptions():
    prof = SyntheticPaperProfiles(n_models=2, seed=2)
    svc = sorted(prof.services())[0]
    state = TokenServingState(
        [svc], prof, lambda s: 100.0, _small_knobs(hbm_gb_per_unit=1.0)
    )
    state.sync_instances({7: (svc, 1, 50.0)}, lambda uid: 1.0, 0.0)
    inst = state.instances[7]
    inst.queue.append(TokenRequest(0, svc, 0.0, 4, 8))
    inst.queue.append(TokenRequest(1, svc, 5.0, 4, 8))
    inst.run_until(0.01, state.metrics)  # admit the first, second still queued
    assert len(inst.live) == 1 and len(inst.queue) == 1
    state.sync_instances({}, lambda uid: 1.0, 10.0)  # the instance vanished
    assert not state.instances
    assert len(state.spill[svc]) == 2  # live + queued both spilled
    assert state.metrics.preemptions[svc] == 1  # only the in-flight one
    assert state.in_system(svc) == 2


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        data = compute_golden()
        with open(GOLDEN_PATH, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    else:
        print("run under pytest, or with --regen to rewrite the golden file")
