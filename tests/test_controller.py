"""Controller tests: exchange-and-compact transparency guarantee (§6).

The paper's invariant: during a transition, every service's throughput stays
>= min(old required, new required).  We assert it from the cluster trace for
many random day/night workload pairs (hypothesis).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    SLO,
    ConfigSpace,
    Controller,
    GreedyFast,
    SimulatedCluster,
    SyntheticPaperProfiles,
    Workload,
    a100_rules,
    parallel_makespan,
)
from repro.core.controller import _config_content, _gpu_content
from collections import Counter


def make_pair(seed: int, n=5):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(seed + 100)
    day = {m: SLO(float(rng.lognormal(6.8, 0.5)), 100.0) for m in prof.services()}
    night = {
        m: SLO(day[m].throughput * float(rng.uniform(0.2, 0.6)), 100.0)
        for m in prof.services()
    }
    return prof, Workload.make(day), Workload.make(night)


def deploy(prof, wl):
    return GreedyFast(ConfigSpace(a100_rules(), prof, wl)).solve()


def run_transition(prof, wl_from, wl_to, extra=2):
    dep_from = deploy(prof, wl_from)
    dep_to = deploy(prof, wl_to)
    ctrl = Controller(a100_rules(), prof)
    cluster = SimulatedCluster(a100_rules(), dep_from.num_gpus + extra)
    ctrl.deploy_fresh(cluster, dep_from)
    n0 = len(cluster.actions_applied)
    report = ctrl.transition(cluster, dep_to)
    return cluster, report, dep_from, dep_to, n0


class TestExchangeAndCompact:
    def test_day2night_and_back(self):
        prof, day, night = make_pair(seed=7)
        cluster, rep, dep_day, dep_night, n0 = run_transition(prof, day, night)
        # final content == target deployment content
        want = Counter()
        for c in dep_night.configs:
            want += _config_content(c)
        have = Counter()
        for g in cluster.gpus.values():
            have += _gpu_content(g)
        assert want == have
        assert rep.final_gpus_busy <= dep_night.num_gpus
        # invariant from the trace
        for _, tp in cluster.trace[n0:]:
            for svc in prof.services():
                lo = min(
                    day.services[day.index(svc)].slo.throughput,
                    night.services[night.index(svc)].slo.throughput,
                )
                assert tp.get(svc, 0.0) >= lo - 1e-6

    def test_parallel_not_slower_than_serial(self):
        prof, day, night = make_pair(seed=3)
        _, rep, *_ = run_transition(prof, day, night)
        assert rep.parallel_seconds <= rep.serial_seconds + 1e-9

    def test_shrinking_mostly_deletes_growing_mostly_creates(self):
        """Figure 13b's qualitative claim."""
        prof, day, night = make_pair(seed=11)
        cluster, rep_shrink, *_ = run_transition(prof, day, night)
        counts_shrink = rep_shrink.action_counts
        ctrl = Controller(a100_rules(), prof)
        rep_grow = ctrl.transition(cluster, deploy(prof, day))
        counts_grow = rep_grow.action_counts
        assert counts_shrink.get("delete", 0) >= counts_shrink.get("create", 0)
        assert counts_grow.get("create", 0) >= counts_grow.get("delete", 0)

    @given(seed=st.integers(0, 40))
    @settings(max_examples=10, deadline=None)
    def test_invariant_property(self, seed):
        prof, day, night = make_pair(seed=seed, n=4)
        cluster, rep, dep_day, dep_night, n0 = run_transition(prof, day, night)
        for _, tp in cluster.trace[n0:]:
            for svc in prof.services():
                lo = min(
                    day.services[day.index(svc)].slo.throughput,
                    night.services[night.index(svc)].slo.throughput,
                )
                assert tp.get(svc, 0.0) >= lo - 1e-6
        # every intermediate partition stayed legal is enforced by apply();
        # final state must carry the full new content
        want = Counter()
        for c in dep_night.configs:
            want += _config_content(c)
        have = Counter()
        for g in cluster.gpus.values():
            have += _gpu_content(g)
        assert want == have


class TestMakespan:
    def test_disjoint_actions_overlap(self):
        from repro.core.cluster import Action

        a1 = Action("create", 0, size=1, service="s")
        a2 = Action("create", 1, size=1, service="s")
        assert parallel_makespan([a1, a2]) == pytest.approx(a1.seconds())
        a3 = Action("create", 0, size=1, service="s")
        assert parallel_makespan([a1, a3]) == pytest.approx(2 * a1.seconds())
