"""shard_map MoE dispatch equals the pjit dispatch (subprocess: needs a
multi-device host mesh, which must be configured before jax init)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models.common import ParamFactory
from repro.models.moe import moe_forward, moe_forward_shard_map, moe_init

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(
    get_smoke_config("deepseek-v3-671b"),
    num_experts=8, experts_per_token=2, capacity_factor=8.0,
)
f = ParamFactory(jax.random.PRNGKey(0), jnp.float32)
moe_init(f, cfg)
params = f.params
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
with mesh:
    ref, aux_ref = jax.jit(lambda p, x: moe_forward(p, cfg, x))(params, x)
    out, aux = jax.jit(lambda p, x: moe_forward_shard_map(p, cfg, x, mesh))(params, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)
assert abs(float(aux) - float(aux_ref)) < 0.02  # estimator variant
print("MATCH")
"""


@pytest.mark.slow
def test_shard_map_moe_matches_pjit():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # the forced host-device mesh is a CPU-platform feature; pinning cpu also
    # skips the TPU metadata probe (60s+ stall on TPU-less CI hosts)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=540,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MATCH" in r.stdout
