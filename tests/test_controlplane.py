"""Control-plane tests (repro.controlplane + its closed-loop wiring).

The properties ISSUE 4 pins:
  (a) with the ``none`` fault profile, ``control_plane=`` mode reproduces
      the direct-transition path **bit-for-bit** (SimReport bytes equal);
  (b) fault-injected runs are seed-deterministic: same seed + same
      profile => byte-identical reports and cell SHAs (golden-pinned);
  (c) a GPU-failure scenario demonstrates SLO re-attainment after
      recovery, with availability/recovery-time metrics;
  (d) the reconciler retries botched actions under exponential backoff
      and resumes from partial progress instead of thrashing;
  (e) ``parallel_makespan`` properties: bounded by serial sum, at least
      the longest action, invariant under same-device reordering.

Golden regeneration (intentional behavior changes only)::

    PYTHONPATH=src python tests/test_controlplane.py --regen
"""

import json
import os
import sys

import numpy as np
import pytest

if __name__ == "__main__":  # regen mode runs without pytest/conftest
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_compat import given, settings, st

from repro.controlplane import (
    FAULT_PROFILES,
    AdmissionController,
    ControlPlane,
    DesiredState,
    FaultInjector,
    FaultProfile,
    ObservedState,
    Reconciler,
    diff,
)
from repro.core import SLO, SyntheticPaperProfiles, Workload, a100_rules
from repro.core.cluster import (
    ACTION_SECONDS,
    Action,
    ActionFault,
    SimulatedCluster,
    parallel_makespan,
)
from repro.core.controller import Controller
from repro.core.optimizer import TwoPhaseOptimizer
from repro.sim import ClusterSimulator, ScenarioCell, SimConfig, run_cell
from repro.sim.traffic import diurnal_trace

GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden", "controlplane_golden.json"
)

# fault cells pinned byte-for-byte (cell name pieces + seed)
GOLDEN_CELLS = [
    ScenarioCell("surge", "greedy", "small", "uniform", "gpu_loss"),
    ScenarioCell("surge", "greedy", "small", "uniform", "chaos"),
]
GOLDEN_SEED = 0


def day_night(seed=0, n_models=4, hours=3.0):
    prof = SyntheticPaperProfiles(n_models=n_models, seed=9)
    rng = np.random.default_rng(42)
    peaks = {m: float(rng.lognormal(7.0, 0.5)) for m in prof.services()}
    trace = diurnal_trace(
        peaks, duration_s=hours * 3600.0, bin_s=60.0, night_frac=0.25, seed=seed
    )
    return prof, trace


def small_problem(n=3, seed=9):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(7)
    slos = {
        m: SLO(float(rng.lognormal(6.5, 0.5)), 100.0) for m in prof.services()
    }
    wl = Workload.make(slos)
    return prof, wl


def deploy_small(prof, wl):
    """An optimized deployment on a fresh cluster, plus its DesiredState."""
    rules = a100_rules()
    opt = TwoPhaseOptimizer(rules, prof, wl)
    dep = opt.run(skip_phase2=True).best_deployment
    ctrl = Controller(rules, prof)
    cluster = SimulatedCluster(rules, dep.num_gpus)
    ctrl.deploy_fresh(cluster, dep)
    desired = DesiredState(
        deployment=dep,
        required={s.name: s.slo.throughput for s in wl.services},
    )
    return rules, ctrl, cluster, dep, desired


# -- (a) zero-behavior-change default -------------------------------------------


class TestNoneProfileBitForBit:
    def test_control_plane_reproduces_direct_path(self):
        prof, trace = day_night()
        direct = ClusterSimulator(
            a100_rules(), prof, trace, SimConfig(seed=3)
        ).run()
        via_cp = ClusterSimulator(
            a100_rules(), prof, trace, SimConfig(seed=3, control_plane=True)
        ).run()
        assert direct.to_json() == via_cp.to_json()

    def test_scenario_cell_sha_unchanged_by_control_plane(self):
        """The existing direct-transition scenario cells stay bit-for-bit
        when re-run through control_plane= mode with the none profile."""
        from repro.sim.scenarios import build_cell

        cell = ScenarioCell("surge", "greedy", "small", "uniform", "none")
        sim_direct, _ = build_cell(cell, seed=0)
        assert sim_direct.control_plane is None
        rep_direct = sim_direct.run()

        sim_cp, _ = build_cell(cell, seed=0)
        sim_cp.config.control_plane = True
        sim_cp.control_plane = __import__(
            "repro.controlplane.reconciler", fromlist=["build_control_plane"]
        ).build_control_plane(
            sim_cp.driver.controller, "none", 0, sim_cp.trace.duration_s
        )
        sim_cp.driver.control_plane = sim_cp.control_plane
        rep_cp = sim_cp.run()
        assert rep_direct.to_json() == rep_cp.to_json()

    def test_none_profile_report_has_no_new_keys(self):
        prof, trace = day_night(hours=2.0)
        rep = ClusterSimulator(
            a100_rules(), prof, trace, SimConfig(seed=1, control_plane=True)
        ).run()
        d = rep.to_dict()
        assert "faults" not in d
        for tl in d["timelines"].values():
            assert "shed" not in tl
        for t in d["transitions"]:
            assert "reconcile" not in t and "trigger" not in t


# -- spec / diff -----------------------------------------------------------------


class TestSpecAndDiff:
    def test_observe_and_converged(self):
        prof, wl = small_problem()
        _, _, cluster, dep, desired = deploy_small(prof, wl)
        obs = ObservedState.observe(cluster)
        d = diff(obs, desired)
        assert d.converged and d.summary() == "converged"
        assert obs.content() == desired.content()
        provided = obs.provided()
        for svc, req in desired.required.items():
            assert provided[svc] >= req - 1e-6

    def test_gpu_failure_diverges(self):
        prof, wl = small_problem()
        _, _, cluster, dep, desired = deploy_small(prof, wl)
        victim = max(
            cluster.gpus,
            key=lambda g: len(cluster.gpus[g].instances),
        )
        killed = cluster.fail_gpu(victim)
        assert killed
        d = diff(ObservedState.observe(cluster), desired)
        assert not d.converged
        assert sum(d.missing.values()) == len(killed)
        assert d.shortfall  # lost throughput shows up as shortfall

    def test_drain_diverges_via_misplaced(self):
        prof, wl = small_problem()
        _, _, cluster, dep, desired = deploy_small(prof, wl)
        busy = [gid for gid, g in cluster.gpus.items() if g.busy()]
        cluster.drain_gpu(busy[0])
        d = diff(ObservedState.observe(cluster), desired)
        assert not d.converged
        assert not d.missing and not d.surplus
        assert len(d.misplaced) == len(
            [
                r
                for r in cluster.gpus[busy[0]].instances.values()
                if r.service
            ]
        )


# -- reconciler ------------------------------------------------------------------


class TestReconciler:
    def test_heals_gpu_failure(self):
        prof, wl = small_problem()
        rules, ctrl, cluster, dep, desired = deploy_small(prof, wl)
        victim = max(
            cluster.gpus, key=lambda g: len(cluster.gpus[g].instances)
        )
        cluster.fail_gpu(victim)
        rec = Reconciler(ctrl)
        assert rec.diverged(cluster, desired)
        report, stats = rec.reconcile(cluster, desired)
        assert stats.converged
        assert not rec.diverged(cluster, desired)
        assert report.action_counts.get("create", 0) > 0
        # nothing was ever scheduled back onto the dead device
        assert not cluster.gpus[victim].instances

    def test_drain_empties_the_machine(self):
        prof, wl = small_problem()
        rules, ctrl, cluster, dep, desired = deploy_small(prof, wl)
        busy = [gid for gid, g in cluster.gpus.items() if g.busy()]
        machine = cluster.gpus[busy[0]].machine
        cluster.drain_machine(machine)
        report, stats = Reconciler(ctrl).reconcile(cluster, desired)
        assert stats.converged
        for gid in cluster.machine_gpus(machine):
            assert not cluster.gpus[gid].busy()
        # target multiset is intact elsewhere
        assert diff(ObservedState.observe(cluster), desired).converged

    def test_retries_with_exponential_backoff_and_resumes(self):
        """Every create attempt fails until the injector's Nth draw; the
        reconciler must re-plan (keeping partial progress) and charge
        exponential backoff."""
        prof, wl = small_problem()
        rules, ctrl, cluster, dep, desired = deploy_small(prof, wl)
        victim = max(
            cluster.gpus, key=lambda g: len(cluster.gpus[g].instances)
        )
        killed = cluster.fail_gpu(victim)

        class FailTwice:
            profile = FAULT_PROFILES["flaky_mig"]

            def __init__(self):
                self.calls = 0
                self.created_before_each_attempt = []

            def action_hook(self, action):
                if action.kind == "create" and self.calls < 2:
                    self.calls += 1
                    raise ActionFault(
                        action, "injected", wasted_s=ACTION_SECONDS["create"]
                    )
                return 1.0

            def backoff_s(self, attempt):
                return 5.0 * 2 ** (attempt - 1)

        inj = FailTwice()
        rec = Reconciler(ctrl, injector=inj)
        report, stats = rec.reconcile(cluster, desired)
        assert stats.converged
        assert stats.retried == 2
        assert stats.iterations == 3
        assert stats.backoff_s == 5.0 + 10.0  # 5 * 2^(attempt-1)
        assert stats.wasted_s == 2 * ACTION_SECONDS["create"]
        # wasted + backoff are charged into the makespan
        assert report.parallel_seconds > stats.backoff_s + stats.wasted_s
        # partial progress: in total only the killed instances were created
        # (each re-plan resumed, never redoing completed creates)
        assert report.action_counts["create"] == len(killed)
        assert diff(ObservedState.observe(cluster), desired).converged

    def test_gives_up_without_thrashing(self):
        """An unreachable target (device lost, nothing schedulable) stops
        after a no-progress pass instead of looping max_iterations times."""
        prof, wl = small_problem(n=2)
        rules, ctrl, cluster, dep, desired = deploy_small(prof, wl)
        # drain everything: no schedulable device can host repairs
        for gid in list(cluster.gpus):
            cluster.drain_gpu(gid)

        class NoCreates:
            profile = FAULT_PROFILES["flaky_mig"]

            def action_hook(self, action):
                raise ActionFault(action, "injected", wasted_s=1.0)

            def backoff_s(self, attempt):
                return 1.0

        rec = Reconciler(ctrl, injector=NoCreates(), max_iterations=4)
        report, stats = rec.reconcile(cluster, desired)
        assert not stats.converged
        assert stats.abandoned > 0

    def test_straggler_inflates_makespan(self):
        prof, wl = small_problem()
        rules, ctrl, cluster, dep, desired = deploy_small(prof, wl)
        victim = max(
            cluster.gpus, key=lambda g: len(cluster.gpus[g].instances)
        )
        cluster.fail_gpu(victim)
        baseline_cluster_state = None  # same plan both times by determinism

        class AllStraggle:
            profile = FAULT_PROFILES["stragglers"]

            def action_hook(self, action):
                return 4.0

            def backoff_s(self, attempt):
                return 0.0

        report, stats = Reconciler(ctrl, injector=AllStraggle()).reconcile(
            cluster, desired
        )
        assert stats.converged
        n = len(report.actions)
        assert n > 0
        assert report.serial_seconds == pytest.approx(
            4.0 * sum(a.seconds() for a in report.actions)
        )


# -- (c, d) closed-loop fault scenarios ------------------------------------------


class TestFaultScenarios:
    def test_gpu_loss_recovers_slo(self):
        """The acceptance demo: a failure dents availability, the control
        plane repairs it, and the SLO is re-attained before the trace ends."""
        res, rep = run_cell(
            ScenarioCell("surge", "greedy", "small", "uniform", "gpu_loss"),
            seed=0,
        )
        assert len(rep.faults) == 1
        fault = rep.faults[0]
        assert fault.kind == "gpu_failure" and fault.killed_instances > 0
        assert res.availability < 1.0
        assert res.recovery_time_s is not None
        # recovered well before the end of the 2 h trace
        assert 0.0 < res.recovery_time_s < rep.times[-1] - fault.time_s
        # a fault-triggered reconcile pass ran and converged
        repairs = [t for t in rep.transitions if t.trigger == "fault"]
        assert repairs and all(t.reconcile["converged"] for t in repairs)
        # SLO is re-attained: the recovery bin itself is attained, the
        # outage window really dented availability, and the run ends
        # healthy (later dips are the surge trace's own, not the fault's)
        ok = rep._all_attained()
        k = int(np.searchsorted(rep.times, fault.time_s + res.recovery_time_s))
        assert ok[k]
        outage = ok[
            int(np.searchsorted(rep.times, fault.time_s - 1e-9)) : k
        ]
        assert len(outage) > 0 and not outage.all()
        assert ok[-3:].all()
        # degraded-mode admission control shed the over-capacity load
        assert res.shed_requests > 0.0

    def test_fault_cells_report_reconcile_metrics(self):
        res, rep = run_cell(
            ScenarioCell("surge", "greedy", "small", "uniform", "chaos"),
            seed=0,
        )
        d = res.to_dict()
        assert d["fault_events"] >= 1
        assert d["reconcile_iterations"] >= d["transitions"]
        assert d["actions_retried"] > 0  # chaos's flaky creates really fire
        reconciles = [t.reconcile for t in rep.transitions if t.reconcile]
        assert reconciles
        retried = [r for r in reconciles if r["retried"]]
        assert retried and all(r["backoff_s"] > 0 for r in retried)

    @given(seed=st.integers(0, 6))
    @settings(max_examples=3, deadline=None)
    def test_fault_cells_seed_deterministic(self, seed):
        cell = ScenarioCell("surge", "greedy", "small", "uniform", "gpu_loss")
        res1, rep1 = run_cell(cell, seed)
        res2, rep2 = run_cell(cell, seed)
        assert rep1.to_json() == rep2.to_json()
        assert res1.report_sha256 == res2.report_sha256
        assert res1.to_dict() == res2.to_dict()

    def test_shed_is_charged_honestly(self):
        """Shed requests count as arrivals but are never served."""
        _, rep = run_cell(
            ScenarioCell("surge", "greedy", "small", "uniform", "gpu_loss"),
            seed=0,
        )
        assert rep.shed_total() > 0
        for svc, tl in rep.timelines.items():
            assert tl.shed is not None
            assert (tl.shed >= -1e-9).all()
            # conservation: everything served came from arrivals minus shed
            # (backlog may carry between bins, so compare totals)
            assert np.sum(tl.served) <= np.sum(tl.arrivals) - np.sum(
                tl.shed
            ) + 1e-6


# -- degraded-mode admission control ---------------------------------------------


class TestAdmission:
    def test_admits_everything_when_capacity_suffices(self):
        adm = AdmissionController()
        assert adm.admit(100.0, 100.0) == (100.0, 0.0)
        assert adm.admit(0.0, 50.0) == (0.0, 0.0)

    def test_sheds_excess(self):
        adm = AdmissionController()
        admitted, shed = adm.admit(100.0, 60.0)
        assert admitted == 60.0 and shed == 40.0

    def test_min_admit_floor(self):
        adm = AdmissionController(min_admit_frac=0.5)
        admitted, shed = adm.admit(100.0, 10.0)
        assert admitted == 50.0 and shed == 50.0

    def test_zero_capacity_sheds_everything_above_the_floor(self):
        adm = AdmissionController()
        assert adm.admit(40.0, 0.0) == (0.0, 40.0)
        plan = adm.admit_by_class([(0, 1.0, 10.0), (2, 1.0, 5.0)], 0.0)
        assert plan == [(0.0, 10.0), (0.0, 5.0)]
        floor = AdmissionController(min_admit_frac=0.1)
        assert floor.admit(40.0, 0.0) == (4.0, 36.0)

    def test_no_shedding_without_a_visible_outage(self):
        """A burst that merely exceeds capacity is queued, not shed: the
        degraded path engages only while the control plane can *see* an
        outage, so fault-free runs report zero shed requests."""
        res, rep = run_cell(
            ScenarioCell("flash", "greedy", "micro", "uniform"), seed=0
        )
        assert res.shed_requests == 0.0
        assert all(tl.shed is None or float(np.sum(tl.shed)) == 0.0
                   for tl in rep.timelines.values())

    def test_admit_by_class_sheds_lowest_class_first(self):
        adm = AdmissionController()
        # capacity 10 covers critical (4) + standard (4), leaves 2 of the
        # batch class's 8: only batch sheds
        plan = adm.admit_by_class(
            [(0, 1.0, 4.0), (1, 1.0, 4.0), (2, 1.0, 8.0)], 10.0
        )
        assert plan[0] == (4.0, 0.0) and plan[1] == (4.0, 0.0)
        assert plan[2][0] == pytest.approx(2.0)
        assert plan[2][1] == pytest.approx(6.0)

    def test_admit_by_class_weighted_fairness_within_marginal_class(self):
        adm = AdmissionController()
        # one class, two entries, 3:1 weights, capacity half the demand:
        # water-filling splits 6 as 4.5/1.5
        plan = adm.admit_by_class([(1, 3.0, 6.0), (1, 1.0, 6.0)], 6.0)
        assert plan[0][0] == pytest.approx(4.5)
        assert plan[1][0] == pytest.approx(1.5)
        # a small-demand entry saturates; its surplus re-flows
        plan = adm.admit_by_class([(1, 3.0, 1.0), (1, 1.0, 6.0)], 6.0)
        assert plan[0][0] == pytest.approx(1.0)
        assert plan[1][0] == pytest.approx(5.0)

    @given(
        entries=st.lists(
            st.tuples(
                st.integers(0, 2),
                st.floats(min_value=0.0, max_value=5.0),
                st.floats(min_value=0.0, max_value=50.0),
            ),
            min_size=1, max_size=8,
        ),
        capacity=st.floats(min_value=0.0, max_value=120.0),
    )
    @settings(max_examples=60, deadline=None)
    def test_admit_by_class_conserves_every_request(self, entries, capacity):
        """Property: per entry, admitted + shed == demand *exactly* (no
        request invented or lost), 0 <= admitted <= demand, and a higher
        class is never shed while a lower class is admitted beyond its
        floor."""
        adm = AdmissionController()
        plan = adm.admit_by_class(entries, capacity)
        assert len(plan) == len(entries)
        for (cls, _w, demand), (admitted, shed) in zip(entries, plan):
            assert admitted + shed == demand  # exact, not approximate
            assert 0.0 <= admitted <= demand
        total = sum(a for a, _ in plan)
        assert total <= max(capacity, 0.0) + 1e-9
        # class ordering: any class with shed traffic means every lower
        # class index (higher priority) was fully admitted
        shed_classes = {
            c for (c, _w, _d), (_a, s) in zip(entries, plan) if s > 1e-9
        }
        if shed_classes:
            top = min(shed_classes)
            for (c, _w, d), (a, _s) in zip(entries, plan):
                if c < top:
                    assert a == d


# -- fault injector determinism ---------------------------------------------------


class TestInjector:
    @given(seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_schedule_is_seeded(self, seed):
        p = FAULT_PROFILES["chaos"]
        a = FaultInjector(p, seed, 7200.0).device_faults()
        b = FaultInjector(p, seed, 7200.0).device_faults()
        assert [(f.time_s, f.kind) for f in a] == [
            (f.time_s, f.kind) for f in b
        ]
        lo, hi = p.failure_window
        for f in a:
            if f.kind == "gpu_failure":
                assert lo * 7200.0 <= f.time_s <= hi * 7200.0

    def test_profiles_differ(self):
        a = FaultInjector(FAULT_PROFILES["gpu_loss"], 0, 7200.0)
        b = FaultInjector(FAULT_PROFILES["chaos"], 0, 7200.0)
        assert [f.time_s for f in a.device_faults()] != [
            f.time_s for f in b.device_faults()
        ]

    def test_registry_contents(self):
        assert "none" in FAULT_PROFILES
        assert {"gpu_loss", "drain", "flaky_mig", "stragglers", "chaos"} <= set(
            FAULT_PROFILES
        )
        none = FAULT_PROFILES["none"]
        assert not none.injects_actions and not none.injects_devices


# -- (e) parallel_makespan properties --------------------------------------------

_KINDS = ("create", "delete", "repartition")


def _single_gpu_actions(spec):
    """[(kind idx, gpu)] -> single-device actions (no migrations)."""
    return [Action(_KINDS[k % len(_KINDS)], gpu=g % 5) for k, g in spec]


class TestMakespanProperties:
    @given(
        spec=st.lists(
            st.tuples(st.integers(0, 2), st.integers(0, 4)),
            min_size=1,
            max_size=24,
        )
    )
    @settings(max_examples=40, deadline=None)
    def test_bounded_by_serial_and_longest(self, spec):
        actions = _single_gpu_actions(spec)
        ms = parallel_makespan(actions)
        serial = sum(a.seconds() for a in actions)
        longest = max(a.seconds() for a in actions)
        assert ms <= serial + 1e-9
        assert ms >= longest - 1e-9

    @given(
        spec=st.lists(
            st.tuples(st.integers(0, 2), st.integers(0, 4)),
            min_size=2,
            max_size=24,
        ),
        swap=st.integers(0, 1 << 16),
    )
    @settings(max_examples=40, deadline=None)
    def test_invariant_under_same_device_reordering(self, spec, swap):
        """Permuting single-device actions *within one device* (keeping
        other devices' action order) never changes the makespan."""
        actions = _single_gpu_actions(spec)
        base = parallel_makespan(actions)
        # rotate the actions of one device in place
        rng = np.random.default_rng(swap)
        gpu = int(rng.integers(5))
        idx = [i for i, a in enumerate(actions) if a.gpu == gpu]
        if len(idx) >= 2:
            rolled = [actions[i] for i in idx]
            rolled = rolled[1:] + rolled[:1]
            permuted = list(actions)
            for i, a in zip(idx, rolled):
                permuted[i] = a
            assert parallel_makespan(permuted) == pytest.approx(base)

    @given(
        spec=st.lists(
            st.tuples(st.integers(0, 2), st.integers(0, 4)),
            min_size=1,
            max_size=24,
        ),
        k=st.integers(1, 4),
    )
    @settings(max_examples=30, deadline=None)
    def test_bounded_concurrency_never_faster(self, spec, k):
        actions = _single_gpu_actions(spec)
        unbounded = parallel_makespan(actions)
        bounded = parallel_makespan(actions, max_concurrent=k)
        serial = sum(a.seconds() for a in actions)
        assert bounded >= unbounded - 1e-9
        assert bounded <= serial + 1e-9
        # one slot degenerates to the serial schedule
        assert parallel_makespan(actions, max_concurrent=1) == pytest.approx(
            serial
        )

    def test_seconds_override(self):
        actions = [Action("create", 0), Action("create", 1)]
        assert parallel_makespan(actions) == pytest.approx(62.0)
        assert parallel_makespan(actions, seconds=[10.0, 40.0]) == pytest.approx(
            40.0
        )

    def test_migrations_conflict_across_both_gpus(self):
        a = Action("migrate", 0, uid=1, dst_gpu=1)
        b = Action("create", 1, size=1)
        # b waits for the migrate touching gpu1
        assert parallel_makespan([a, b]) == pytest.approx(
            a.seconds() + b.seconds()
        )


# -- golden pins -----------------------------------------------------------------


def compute_golden():
    cells = {}
    for cell in GOLDEN_CELLS:
        res, _ = run_cell(cell, GOLDEN_SEED)
        d = res.to_dict()
        cells[cell.name] = {
            "report_sha256": d["report_sha256"],
            "availability": d["availability"],
            "recovery_time_s": d["recovery_time_s"],
            "fault_events": d["fault_events"],
            "reconcile_iterations": d["reconcile_iterations"],
            "actions_retried": d["actions_retried"],
            "actions_abandoned": d["actions_abandoned"],
            "shed_requests": d["shed_requests"],
            "gpus_peak": d["gpus_peak"],
        }
    return {"schema": 1, "seed": GOLDEN_SEED, "cells": cells}


def test_fault_cells_match_golden():
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    current = compute_golden()
    assert current == golden, (
        "seeded control-plane cells drifted from tests/golden/"
        "controlplane_golden.json — if intentional, regenerate with "
        "`PYTHONPATH=src python tests/test_controlplane.py --regen` and "
        "commit with a [golden-regen] marker"
    )


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(compute_golden(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    else:
        print(__doc__)
