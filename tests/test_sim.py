"""Closed-loop simulator tests (repro.sim).

The three properties the ISSUE pins:
  (a) WeightedRouter dispatch counts converge to throughput-proportional
      shares on long runs,
  (b) the same seed yields byte-identical simulation reports,
  (c) the §6 transparency invariant holds at every mid-transition trace
      point of a seeded day->night scenario.
Plus unit coverage for the trace generators and the event queue.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import SLO, SyntheticPaperProfiles, Workload, a100_rules
from repro.serving.router import InstanceHandle, WeightedRouter
from repro.sim import (
    ClusterSimulator,
    EventQueue,
    ReoptimizeDriver,
    SimConfig,
    diurnal_trace,
    flash_crowd_trace,
    poisson_burst_trace,
    replay_trace,
)
from repro.core.cluster import ACTION_SECONDS, SimulatedCluster


def day_night_scenario(seed: int, n_models: int = 5, hours: float = 4.0):
    """A seeded diurnal scenario big enough that day needs more instances
    than night (so the re-optimizer must act)."""
    prof = SyntheticPaperProfiles(n_models=n_models, seed=9)
    rng = np.random.default_rng(42 + seed)
    peaks = {m: float(rng.lognormal(7.0, 0.5)) for m in prof.services()}
    trace = diurnal_trace(
        peaks, duration_s=hours * 3600.0, bin_s=60.0, night_frac=0.25, seed=seed
    )
    return prof, trace


# -- (a) router convergence -----------------------------------------------------


class TestRouterConvergence:
    @given(seed=st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_dispatch_proportional_to_throughput(self, seed):
        rng = np.random.default_rng(seed)
        weights = [float(w) for w in rng.uniform(10.0, 500.0, size=rng.integers(2, 7))]
        handles = [
            InstanceHandle(instance_id=i, size=1, throughput=w)
            for i, w in enumerate(weights)
        ]
        router = WeightedRouter(handles)
        n = 20_000
        for _ in range(n):
            router.pick()
        counts = router.dispatch_counts()
        total_w = sum(weights)
        for i, w in enumerate(weights):
            share = counts[i] / n
            expect = w / total_w
            # smooth WRR error is bounded by one pick per instance per cycle
            assert share == pytest.approx(expect, abs=len(weights) / n + 1e-3)

    def test_smooth_wrr_is_deterministic(self):
        handles = lambda: [
            InstanceHandle(instance_id=i, size=1, throughput=t)
            for i, t in enumerate((5.0, 3.0, 2.0))
        ]
        r1, r2 = WeightedRouter(handles()), WeightedRouter(handles())
        seq1 = [r1.pick().instance_id for _ in range(100)]
        seq2 = [r2.pick().instance_id for _ in range(100)]
        assert seq1 == seq2


# -- traffic generators ---------------------------------------------------------


class TestTraffic:
    def test_diurnal_shape(self):
        tr = diurnal_trace({"a": 100.0}, duration_s=3600, bin_s=60, night_frac=0.2)
        r = tr.rates["a"]
        assert len(r) == 60
        assert r[0] == pytest.approx(100.0, rel=0.05)  # starts at midday peak
        assert r.min() >= 0.2 * 100.0 * 0.95  # trough near night_frac * peak
        assert tr.rate_at("a", 0.0) == r[0]
        assert tr.rate_at("a", 1e9) == r[-1]  # clamped past the end

    def test_flash_crowd_peaks_then_decays(self):
        tr = flash_crowd_trace(
            {"a": 10.0}, duration_s=3600, at_s=600, bin_s=60, mult=5.0,
            ramp_s=120, decay_s=300,
        )
        r = tr.rates["a"]
        assert r[:9].max() == pytest.approx(10.0)  # before the crowd
        assert r.max() > 40.0  # near 5x at the spike
        assert r[-1] < 12.0  # decayed back

    def test_poisson_burst_seeded(self):
        kw = dict(duration_s=7200, bin_s=60, burst_mult=4.0, burst_prob=0.1)
        t1 = poisson_burst_trace({"a": 10.0}, seed=5, **kw)
        t2 = poisson_burst_trace({"a": 10.0}, seed=5, **kw)
        t3 = poisson_burst_trace({"a": 10.0}, seed=6, **kw)
        np.testing.assert_array_equal(t1.rates["a"], t2.rates["a"])
        assert t1.rates["a"].max() == pytest.approx(40.0)  # bursts happened
        assert not np.array_equal(t1.rates["a"], t3.rates["a"])

    def test_replay_and_mean_rates(self):
        tr = replay_trace({"a": [10.0, 20.0, 30.0, 40.0]}, bin_s=60.0)
        assert tr.duration_s == 240.0
        assert tr.mean_rates(0, 120)["a"] == pytest.approx(15.0)
        assert tr.mean_rates(120, 240)["a"] == pytest.approx(35.0)

    def test_mean_rates_weights_partial_edge_bins(self):
        """A window that is not a bin multiple must weight edge bins by
        overlap: [30, 120) covers 30s of bin 0 and 60s of bin 1."""
        tr = replay_trace({"a": [10.0, 20.0, 30.0, 40.0]}, bin_s=60.0)
        want = (10.0 * 30.0 + 20.0 * 60.0) / 90.0  # not the naive 15.0
        assert tr.mean_rates(30, 120)["a"] == pytest.approx(want)
        # both edges partial: [30, 90) = 30s of each bin
        assert tr.mean_rates(30, 90)["a"] == pytest.approx(15.0)
        # bin-aligned windows stay bit-identical to the unweighted mean
        aligned = tr.mean_rates(60, 180)["a"]
        assert aligned == float(np.mean(np.asarray([20.0, 30.0])))


# -- events ---------------------------------------------------------------------


class TestEventQueue:
    def test_time_order_with_fifo_tiebreak(self):
        q = EventQueue()
        q.push(2.0, "b")
        q.push(1.0, "a1")
        q.push(1.0, "a2")
        q.push(0.5, "z")
        kinds = [ev.kind for ev in q.drain()]
        assert kinds == ["z", "a1", "a2", "b"]


# -- input validation: real exceptions, not asserts ------------------------------


class TestInputValidation:
    """The guards converted from ``assert`` must raise even under
    ``python -O`` (where asserts compile away) — pin each one."""

    def test_clock_rejects_backwards_time(self):
        from repro.sim.events import Clock

        clk = Clock(t0=10.0)
        with pytest.raises(RuntimeError, match="clock moved backwards"):
            clk.advance_to(9.0)
        assert clk.advance_to(10.0 - 1e-12) == 10.0  # tolerance, not a trap
        assert clk.advance_to(11.0) == 11.0

    def test_trace_rejects_degenerate_shapes(self):
        from repro.sim import Trace

        with pytest.raises(ValueError, match="bin width"):
            Trace(bin_s=0.0, rates={"a": np.ones(3)})
        with pytest.raises(ValueError, match="at least one service"):
            Trace(bin_s=60.0, rates={})
        with pytest.raises(ValueError):
            Trace(bin_s=60.0, rates={"a": np.ones(3), "b": np.ones(4)})

    def test_generators_reject_sub_bin_durations(self):
        with pytest.raises(ValueError):
            diurnal_trace({"a": 10.0}, duration_s=10.0, bin_s=60.0)

    def test_diurnal_rejects_night_frac_out_of_range(self):
        for bad in (-0.1, 1.5):
            with pytest.raises(ValueError, match="night_frac"):
                diurnal_trace(
                    {"a": 10.0}, duration_s=3600.0, bin_s=60.0, night_frac=bad
                )

    def test_correlated_surge_rejects_bad_knobs(self):
        from repro.sim import correlated_surge_trace

        peaks = {"a": 10.0, "b": 10.0}
        with pytest.raises(ValueError, match="correlation"):
            correlated_surge_trace(
                peaks, duration_s=3600.0, bin_s=60.0, correlation=1.5
            )
        with pytest.raises(ValueError):
            correlated_surge_trace(
                peaks, duration_s=3600.0, bin_s=60.0, surge_len_bins=0
            )
        with pytest.raises(ValueError):
            correlated_surge_trace(
                peaks, duration_s=3600.0, bin_s=60.0, n_surges=0
            )

    def test_duplicate_fault_profile_registration_raises(self):
        from repro.controlplane.faults import (
            FAULT_PROFILES,
            FaultProfile,
            register_fault_profile,
        )

        assert "gpu_loss" in FAULT_PROFILES
        with pytest.raises(ValueError, match="already registered"):
            register_fault_profile(FaultProfile("gpu_loss", gpu_failures=1))


# -- (b) determinism ------------------------------------------------------------


class TestDeterminism:
    @given(seed=st.integers(0, 20))
    @settings(max_examples=4, deadline=None)
    def test_same_seed_byte_identical_report(self, seed):
        prof, trace = day_night_scenario(seed=0, hours=2.0)
        cfg = SimConfig(seed=seed, reoptimize_every_s=1800.0)
        r1 = ClusterSimulator(a100_rules(), prof, trace, cfg).run()
        r2 = ClusterSimulator(a100_rules(), prof, trace, cfg).run()
        assert r1.to_json() == r2.to_json()

    def test_different_seed_changes_arrivals(self):
        prof, trace = day_night_scenario(seed=0, hours=1.0)
        r1 = ClusterSimulator(
            a100_rules(), prof, trace, SimConfig(seed=1, reoptimize_every_s=1800.0)
        ).run()
        r2 = ClusterSimulator(
            a100_rules(), prof, trace, SimConfig(seed=2, reoptimize_every_s=1800.0)
        ).run()
        assert r1.to_json() != r2.to_json()

    def test_fluid_arrivals_are_exact(self):
        prof, trace = day_night_scenario(seed=0, hours=1.0)
        cfg = SimConfig(seed=0, arrivals="fluid", reoptimize_every_s=1800.0)
        rep = ClusterSimulator(a100_rules(), prof, trace, cfg).run()
        for svc in rep.services:
            got = rep.timelines[svc].arrivals.sum()
            want = trace.rates[svc].sum() * trace.bin_s
            assert got == pytest.approx(want, rel=1e-9)


# -- (c) transparency on the day->night scenario -------------------------------


class TestClosedLoop:
    def run_scenario(self, seed=3):
        prof, trace = day_night_scenario(seed=0, hours=4.0)
        cfg = SimConfig(seed=seed, reoptimize_every_s=1800.0)
        return ClusterSimulator(a100_rules(), prof, trace, cfg).run()

    def test_reoptimizer_acts_and_transparency_holds(self):
        rep = self.run_scenario()
        acted = [t for t in rep.transitions if t.action_counts]
        assert acted, "day->night demand shift must trigger a real transition"
        # §6: at every trace point, every service >= min(old, new) required
        assert rep.transparent
        assert rep.transparency_margin() >= 0.0
        for t in rep.transitions:
            for svc, margin in t.transparency_margin.items():
                assert margin >= -1e-6, (t.start_s, svc, margin)

    def test_action_latencies_are_charged(self):
        """A transition with creates must span Figure-13c create latency."""
        rep = self.run_scenario()
        grows = [
            t for t in rep.transitions if t.action_counts.get("create", 0) > 0
        ]
        assert grows, "night->day must create instances"
        for t in grows:
            # at least one create's Fig.-13c latency (the canonical table)
            assert t.parallel_seconds >= ACTION_SECONDS["create"]
            assert t.end_s == pytest.approx(t.start_s + t.parallel_seconds)

    def test_slo_attainment_accounted(self):
        rep = self.run_scenario()
        for svc in rep.services:
            assert rep.mean_attainment(svc) > 0.95
            assert rep.served_fraction(svc) > 0.95
        assert rep.reoptimize_checks >= 3

    @given(seed=st.integers(0, 12))
    @settings(max_examples=3, deadline=None)
    def test_transparency_property(self, seed):
        prof, trace = day_night_scenario(seed=0, hours=2.0)
        cfg = SimConfig(seed=seed, reoptimize_every_s=1800.0)
        rep = ClusterSimulator(a100_rules(), prof, trace, cfg).run()
        assert rep.transparent


# -- driver unit coverage -------------------------------------------------------


class TestReoptimizeDriver:
    def test_workload_floor_and_threshold(self):
        prof = SyntheticPaperProfiles(n_models=3, seed=9)
        driver = ReoptimizeDriver(a100_rules(), prof, headroom=1.1)
        svcs = prof.services()
        wl = driver.workload_for({s: 0.0 for s in svcs})
        assert all(s.slo.throughput == 1.0 for s in wl.services)  # floored
        driver.workload = driver.workload_for({s: 100.0 for s in svcs})
        small = driver.workload_for({s: 105.0 for s in svcs})
        big = driver.workload_for({s: 200.0 for s in svcs})
        assert not driver.demand_moved(small)  # under 15% threshold
        assert driver.demand_moved(big)

    def test_initial_deploy_covers_demand(self):
        prof = SyntheticPaperProfiles(n_models=3, seed=9)
        driver = ReoptimizeDriver(a100_rules(), prof)
        cluster = SimulatedCluster(a100_rules(), 1)
        rates = {s: 500.0 for s in prof.services()}
        dep = driver.initial_deploy(cluster, rates)
        provided = cluster.throughput()
        for s in driver.workload.services:
            assert provided[s.name] >= s.slo.throughput - 1e-6
        assert cluster.gpus_in_use() == dep.num_gpus


# -- regression: margin construction order must not reach report bytes ----------


class TestReoptimizeRegressions:
    def test_margin_fix_keeps_report_bytes(self):
        """PR 10 replaced the hash-order ``set(old) | set(new)`` margin-dict
        construction in ``ReoptimizeDriver`` with a sorted union.  The fix
        must be byte-invisible: this SHA was pinned on the pre-fix code and
        the scenario drives 5 transitions with full transparency-margin maps,
        so any serialization drift (now or later) lands here before it
        reaches the golden matrix."""
        import hashlib

        prof, trace = day_night_scenario(seed=0, hours=4.0)
        cfg = SimConfig(seed=3, reoptimize_every_s=1800.0)
        rep = ClusterSimulator(a100_rules(), prof, trace, cfg).run()
        assert [len(t.transparency_margin) for t in rep.transitions] == [5] * 5
        assert (
            hashlib.sha256(rep.to_json().encode()).hexdigest()
            == "907866b707fabb671aa5213df4e78e2a229ac83d5ad087e4fae8f13bfde596a8"
        )

    def test_reoptimize_before_deploy_raises(self):
        """The driver's old ``assert`` (stripped under ``python -O``) is now
        a RuntimeError: reoptimize() without initial_deploy() has no deployed
        workload to transition from."""
        prof = SyntheticPaperProfiles(n_models=3, seed=9)
        driver = ReoptimizeDriver(a100_rules(), prof)
        cluster = SimulatedCluster(a100_rules(), 1)
        with pytest.raises(RuntimeError, match="initial_deploy"):
            driver.reoptimize(cluster, {s: 500.0 for s in prof.services()}, 0.0)
