"""Training substrate + serving engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import Engine, Request, run_closed_loop
from repro.training import adamw, checkpoint, data, make_train_step


def test_loss_decreases_on_learnable_data():
    cfg = get_smoke_config("qwen3-8b")
    m = Model(cfg, remat=False)
    params, _ = m.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, adamw.AdamWConfig(lr=1e-3, warmup_steps=5)))
    ostate = adamw.init(params)
    losses = []
    for b in data.batches(cfg, data.DataConfig(batch=4, seq_len=32), 10):
        params, ostate, metrics = step(params, ostate, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_data_pipeline_deterministic():
    cfg = get_smoke_config("qwen3-8b")
    b1 = data.synthetic_batch(cfg, data.DataConfig(batch=2, seq_len=16, seed=3), 7)
    b2 = data.synthetic_batch(cfg, data.DataConfig(batch=2, seq_len=16, seed=3), 7)
    assert bool(jnp.all(b1["tokens"] == b2["tokens"]))
    b3 = data.synthetic_batch(cfg, data.DataConfig(batch=2, seq_len=16, seed=3), 8)
    assert not bool(jnp.all(b1["tokens"] == b3["tokens"]))
    # affine rule holds
    t, l = np.asarray(b1["tokens"]), np.asarray(b1["labels"])
    assert np.all(l == (31 * t + 17) % cfg.vocab_size)


def test_adam_update_magnitude_bounded_by_lr():
    """Adam's normalized update is O(lr) even for enormous gradients, and the
    reported grad-norm is the raw (pre-clip) one."""
    cfg = adamw.AdamWConfig(lr=0.1, clip_norm=1.0, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.ones((4, 4))}
    state = adamw.init(params)
    grads = {"w": jnp.full((4, 4), 1e6)}
    new_params, state, gnorm = adamw.update(cfg, grads, state, params)
    assert float(gnorm) > 1e5
    assert float(jnp.max(jnp.abs(new_params["w"] - params["w"]))) <= 0.1 * 1.01


def test_checkpoint_roundtrip_bf16():
    cfg = get_smoke_config("zamba2-1.2b")
    m = Model(cfg, remat=False)
    params, _ = m.init(jax.random.PRNGKey(1))
    checkpoint.save("/tmp/test_ckpt.npz", params)
    restored = checkpoint.restore("/tmp/test_ckpt.npz", params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert bool(jnp.all(a == b))


def test_engine_continuous_batching_refills_slots():
    cfg = get_smoke_config("internvl2-1b")
    m = Model(cfg, remat=False)
    params, _ = m.init(jax.random.PRNGKey(0))
    engine = Engine(m, params, batch=2, max_len=64)
    reqs = [
        Request(rid=i, prompt=np.arange(1, 4, dtype=np.int32), max_new_tokens=3)
        for i in range(5)
    ]
    stats = run_closed_loop(engine, reqs)
    assert stats.served == 5
    assert stats.tokens == 15
    # more requests than slots => slots were reused
    assert engine.steps >= 3
