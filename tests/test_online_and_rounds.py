"""Paper §6 round granularity + §8.3 online profile updating."""

import numpy as np
import pytest

from repro.core import (
    SLO,
    ConfigSpace,
    Controller,
    GreedyFast,
    SimulatedCluster,
    SyntheticPaperProfiles,
    Workload,
    a100_rules,
)
from repro.core.online_profiles import MeasuredProfile


def make_pair(seed=5, n=6):
    prof = SyntheticPaperProfiles(n_models=n, seed=seed)
    rng = np.random.default_rng(seed)
    day = {m: SLO(float(rng.lognormal(6.8, 0.5)), 100.0) for m in prof.services()}
    night = {
        m: SLO(day[m].throughput * float(rng.uniform(0.3, 0.6)), 100.0)
        for m in prof.services()
    }
    return prof, Workload.make(day), Workload.make(night)


class TestRoundGranularity:
    def _run(self, services_per_round):
        prof, day, night = make_pair()
        dep_day = GreedyFast(ConfigSpace(a100_rules(), prof, day)).solve()
        dep_night = GreedyFast(ConfigSpace(a100_rules(), prof, night)).solve()
        ctrl = Controller(a100_rules(), prof)
        cluster = SimulatedCluster(a100_rules(), dep_day.num_gpus + 2)
        ctrl.deploy_fresh(cluster, dep_day)
        n0 = len(cluster.actions_applied)
        rep = ctrl.transition(cluster, dep_night, services_per_round=services_per_round)
        # invariant holds under any granularity
        for _, tp in cluster.trace[n0:]:
            for svc in prof.services():
                lo = min(
                    day.services[day.index(svc)].slo.throughput,
                    night.services[night.index(svc)].slo.throughput,
                )
                assert tp.get(svc, 0.0) >= lo - 1e-6
        return rep

    def test_invariant_and_makespan_tradeoff(self):
        rep_serial = self._run(services_per_round=1)
        rep_batch = self._run(services_per_round=None)
        # full-batch rounds interleave services => at least as parallel
        assert rep_batch.parallel_seconds <= rep_serial.parallel_seconds + 1e-9
        # both land on the same final deployment size
        assert rep_batch.final_gpus_busy == rep_serial.final_gpus_busy


class TestMeasuredProfile:
    def test_ewma_converges_to_observed_ratio(self):
        base = SyntheticPaperProfiles(n_models=3, seed=1)
        mp = MeasuredProfile(base, ewma=0.5)
        m = base.services()[0]
        b = base.best_batch(m, 1, 100.0)
        predicted = b * 1000.0 / base.latency_ms(m, 1, b)
        for _ in range(12):
            mp.observe(m, 1, b, measured_tput=predicted * 0.9)
        assert mp.correction(m, 1) == pytest.approx(0.9, rel=0.02)
        assert mp.throughput(m, 1, 100.0) == pytest.approx(
            base.throughput(m, 1, 100.0) * 0.9, rel=0.05
        )

    def test_reoptimizing_with_corrections_restores_slo(self):
        """The paper's fix for the <5% shortfall: measure, update, re-plan."""
        base = SyntheticPaperProfiles(n_models=8, seed=2)
        rng = np.random.default_rng(0)
        # large workload => little integer-rounding slack in the plan
        wl = Workload.make(
            {m: SLO(float(rng.lognormal(8.5, 0.4)), 100.0) for m in base.services()}
        )
        # real-world throughput is 10% below profile for every (svc, size)
        degrade = 0.90
        stale = GreedyFast(ConfigSpace(a100_rules(), base, wl)).solve()
        provided_stale = {m: 0.0 for m in base.services()}
        for cfg in stale.configs:
            for a in cfg.assignments:
                if a.service:
                    provided_stale[a.service] += a.throughput * degrade
        shortfall = [
            provided_stale[s.name] / s.slo.throughput for s in wl.services
        ]
        assert min(shortfall) < 1.0  # the stale plan misses SLO

        mp = MeasuredProfile(base, ewma=0.5)
        for m in base.services():
            for size in base.sizes():
                b = base.best_batch(m, size, 100.0)
                if b == 0:
                    continue
                pred = b * 1000.0 / base.latency_ms(m, size, b)
                for _ in range(10):
                    mp.observe(m, size, b, pred * degrade)
        replanned = GreedyFast(ConfigSpace(a100_rules(), mp, wl)).solve()
        provided = {m: 0.0 for m in base.services()}
        for cfg in replanned.configs:
            for a in cfg.assignments:
                if a.service:
                    # a.throughput already uses the corrected profile;
                    # real throughput = base * degrade ≈ corrected
                    provided[a.service] += a.throughput
        for s in wl.services:
            assert provided[s.name] >= s.slo.throughput * 0.999
