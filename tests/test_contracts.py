"""Tests for the static contract checker (``tools/contracts``).

Three layers:

* **rule unit tests** on fixture snippets — tiny synthetic ``src/repro``
  trees in tmp dirs, one per scenario, so each rule's positive *and*
  negative space is pinned;
* **framework tests** — waiver grammar (reason mandatory), baseline
  round-trip and staleness;
* **end-to-end** — the shipped tree passes (exit 0), and seeding a
  violation into a copy makes the CLI exit non-zero naming the rule and
  the ``file:line`` anchor.
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
TOOLS_DIR = REPO_ROOT / "tools"
sys.path.insert(0, str(TOOLS_DIR))

from contracts import (  # noqa: E402
    Finding,
    WAIVER_SYNTAX_RULE,
    load_baseline,
    load_project,
    parse_waivers,
    run_checks,
    save_baseline,
)
from contracts.rules import RULES  # noqa: E402

CLI = TOOLS_DIR / "check_contracts.py"


# -- fixture tree builder ----------------------------------------------------------


def make_tree(tmp_path: Path, files: dict) -> Path:
    """Write ``files`` (rel-path -> source) under ``tmp_path/src`` with
    ``__init__.py`` auto-created for every package directory."""
    root = tmp_path / "src"
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
        d = p.parent
        while d != root:
            init = d / "__init__.py"
            if not init.exists():
                init.write_text("")
            d = d.parent
    return root


def active(tmp_path: Path, files: dict, rule: str):
    root = make_tree(tmp_path, files)
    return run_checks(root, baseline_path=None, rule_ids=[rule]).active


# -- import-boundary ---------------------------------------------------------------


class TestImportBoundary:
    def test_direct_jax_import_flagged(self, tmp_path):
        found = active(
            tmp_path, {"repro/core/bad.py": "import jax\n"}, "import-boundary"
        )
        assert len(found) == 1
        f = found[0]
        assert f.rule == "import-boundary"
        assert f.file == "src/repro/core/bad.py"
        assert f.line == 1
        assert "jax" in f.message

    def test_lazy_import_inside_deterministic_package_still_flagged(self, tmp_path):
        src = "def f():\n    import jax\n    return jax\n"
        found = active(
            tmp_path, {"repro/sim/lazy.py": src}, "import-boundary"
        )
        assert len(found) == 1
        assert found[0].line == 2

    def test_transitive_chain_flagged_at_direct_site(self, tmp_path):
        files = {
            "repro/core/user.py": "from repro.helpers import util\n",
            "repro/helpers/util.py": "import numpy\nimport jax.numpy\n",
        }
        found = active(tmp_path, files, "import-boundary")
        assert len(found) == 1
        f = found[0]
        # anchored at the import statement that pulls jax in...
        assert f.file == "src/repro/helpers/util.py"
        assert f.line == 2
        # ...with the chain naming the deterministic module it poisons
        assert "repro.core.user" in f.message

    def test_pep562_lazy_boundary_outside_scope_is_sanctioned(self, tmp_path):
        files = {
            "repro/core/user.py": "from repro.helpers import PLAIN\n",
            "repro/helpers/__init__.py": (
                "PLAIN = 1\n"
                "def __getattr__(name):\n"
                "    from repro.helpers.engine import Engine\n"
                "    return Engine\n"
            ),
            "repro/helpers/engine.py": "import jax\nclass Engine: pass\n",
        }
        assert active(tmp_path, files, "import-boundary") == []

    def test_ancestor_package_inits_are_in_closure(self, tmp_path):
        # importing repro.helpers.leaf executes repro/helpers/__init__.py
        files = {
            "repro/core/user.py": "import repro.helpers.leaf\n",
            "repro/helpers/__init__.py": "import jax\n",
            "repro/helpers/leaf.py": "x = 1\n",
        }
        found = active(tmp_path, files, "import-boundary")
        assert len(found) == 1
        assert found[0].file == "src/repro/helpers/__init__.py"

    def test_relative_import_resolution(self, tmp_path):
        files = {
            "repro/obs/a.py": "from . import b\n",
            "repro/obs/b.py": "import jaxlib\n",
        }
        found = active(tmp_path, files, "import-boundary")
        assert len(found) == 1
        assert "jaxlib" in found[0].message


# -- wall-clock --------------------------------------------------------------------


class TestWallClock:
    def test_time_in_deterministic_package(self, tmp_path):
        found = active(
            tmp_path, {"repro/controlplane/x.py": "import time\n"}, "wall-clock"
        )
        assert [f.line for f in found] == [1]
        assert "time" in found[0].message

    def test_datetime_function_local(self, tmp_path):
        src = "def now():\n    from datetime import datetime\n    return datetime\n"
        found = active(tmp_path, {"repro/sim/x.py": src}, "wall-clock")
        assert [f.line for f in found] == [2]

    def test_outside_scope_untouched(self, tmp_path):
        assert (
            active(tmp_path, {"repro/models/x.py": "import time\n"}, "wall-clock")
            == []
        )


# -- seeded-rng --------------------------------------------------------------------


class TestSeededRng:
    def test_argless_default_rng(self, tmp_path):
        src = "import numpy as np\nrng = np.random.default_rng()\n"
        found = active(tmp_path, {"repro/core/x.py": src}, "seeded-rng")
        assert [f.line for f in found] == [2]

    def test_seeded_default_rng_ok(self, tmp_path):
        src = "import numpy as np\nrng = np.random.default_rng(42)\n"
        assert active(tmp_path, {"repro/core/x.py": src}, "seeded-rng") == []

    def test_legacy_module_call(self, tmp_path):
        src = "import numpy as np\nx = np.random.rand(3)\nnp.random.seed(0)\n"
        found = active(tmp_path, {"repro/core/x.py": src}, "seeded-rng")
        assert [f.line for f in found] == [2, 3]

    def test_bare_default_rng_import(self, tmp_path):
        src = "from numpy.random import default_rng\nrng = default_rng()\n"
        found = active(tmp_path, {"repro/core/x.py": src}, "seeded-rng")
        assert [f.line for f in found] == [2]

    def test_generator_methods_ok(self, tmp_path):
        src = (
            "import numpy as np\n"
            "rng = np.random.default_rng(7)\n"
            "x = rng.integers(10)\n"
        )
        assert active(tmp_path, {"repro/core/x.py": src}, "seeded-rng") == []


# -- no-bare-assert ----------------------------------------------------------------


class TestNoBareAssert:
    def test_assert_flagged(self, tmp_path):
        src = "def f(x):\n    assert x > 0\n    return x\n"
        found = active(tmp_path, {"repro/core/x.py": src}, "no-bare-assert")
        assert [f.line for f in found] == [2]
        assert "python -O" in found[0].message

    def test_raise_not_flagged(self, tmp_path):
        src = "def f(x):\n    if x <= 0:\n        raise ValueError(x)\n    return x\n"
        assert active(tmp_path, {"repro/core/x.py": src}, "no-bare-assert") == []


# -- unordered-iteration -----------------------------------------------------------


class TestUnorderedIteration:
    def test_set_literal_for_loop(self, tmp_path):
        src = "for x in {1, 2, 3}:\n    print(x)\n"
        found = active(tmp_path, {"repro/sim/report.py": src}, "unordered-iteration")
        assert [f.line for f in found] == [1]

    def test_set_union_comprehension(self, tmp_path):
        src = "a, b = {1}, {2}\nout = [x for x in set(a) | set(b)]\n"
        found = active(
            tmp_path, {"repro/sim/reoptimize.py": src}, "unordered-iteration"
        )
        assert [f.line for f in found] == [2]

    def test_sorted_wrap_ok(self, tmp_path):
        src = "a, b = {1}, {2}\nout = [x for x in sorted(set(a) | set(b))]\n"
        assert (
            active(tmp_path, {"repro/sim/reoptimize.py": src}, "unordered-iteration")
            == []
        )

    def test_obs_package_in_scope(self, tmp_path):
        src = "for x in frozenset((1, 2)):\n    pass\n"
        found = active(tmp_path, {"repro/obs/spans.py": src}, "unordered-iteration")
        assert [f.line for f in found] == [1]

    def test_out_of_scope_module_untouched(self, tmp_path):
        src = "for x in {1, 2}:\n    pass\n"
        assert (
            active(tmp_path, {"repro/core/x.py": src}, "unordered-iteration") == []
        )

    def test_set_method_result(self, tmp_path):
        src = "a, b = {1}, {2}\nfor x in a.union(b):\n    pass\n"
        found = active(
            tmp_path, {"repro/sim/scenarios.py": src}, "unordered-iteration"
        )
        assert [f.line for f in found] == [2]


# -- waivers -----------------------------------------------------------------------


class TestWaivers:
    def test_inline_waiver_same_line(self, tmp_path):
        src = "import time  # contract-ok: wall-clock deadline bound only\n"
        root = make_tree(tmp_path, {"repro/core/x.py": src})
        res = run_checks(root, rule_ids=["wall-clock"])
        assert res.active == []
        assert len(res.waived) == 1

    def test_waiver_on_line_above(self, tmp_path):
        src = (
            "# contract-ok: wall-clock deadline bound only\n"
            "import time\n"
        )
        root = make_tree(tmp_path, {"repro/core/x.py": src})
        res = run_checks(root, rule_ids=["wall-clock"])
        assert res.active == []
        assert len(res.waived) == 1

    def test_waiver_wrong_rule_does_not_cover(self, tmp_path):
        src = "import time  # contract-ok: no-bare-assert misdirected waiver\n"
        root = make_tree(tmp_path, {"repro/core/x.py": src})
        res = run_checks(root, rule_ids=["wall-clock"])
        assert [f.rule for f in res.active] == ["wall-clock"]

    def test_reason_is_mandatory(self, tmp_path):
        src = "import time  # contract-ok: wall-clock\n"
        root = make_tree(tmp_path, {"repro/core/x.py": src})
        res = run_checks(root, rule_ids=["wall-clock"])
        rules_hit = sorted(f.rule for f in res.active)
        # the reason-free waiver does NOT waive, and is itself a violation
        assert rules_hit == [WAIVER_SYNTAX_RULE, "wall-clock"]

    def test_waiver_syntax_finding_cannot_be_waived(self, tmp_path):
        src = (
            "# contract-ok: waiver-syntax trying to waive the meta-rule\n"
            "import time  # contract-ok: wall-clock\n"
        )
        root = make_tree(tmp_path, {"repro/core/x.py": src})
        res = run_checks(root, rule_ids=["wall-clock"])
        assert WAIVER_SYNTAX_RULE in {f.rule for f in res.active}

    def test_parse_waivers_extracts_reason(self, tmp_path):
        root = make_tree(
            tmp_path,
            {"repro/core/x.py": "import time  # contract-ok: wall-clock why not\n"},
        )
        sf = load_project(root).modules["repro.core.x"]
        waivers, malformed = parse_waivers(sf)
        assert malformed == []
        assert len(waivers) == 1
        assert waivers[0].rule == "wall-clock"
        assert waivers[0].reason == "why not"


# -- baseline ----------------------------------------------------------------------


class TestBaseline:
    def test_round_trip_and_suppression(self, tmp_path):
        root = make_tree(tmp_path, {"repro/core/x.py": "import time\n"})
        res = run_checks(root, rule_ids=["wall-clock"])
        assert len(res.active) == 1
        bl = tmp_path / "baseline.json"
        save_baseline(bl, res.active)
        entries = load_baseline(bl)
        assert [(e["rule"], e["file"], e["line"]) for e in entries] == [
            ("wall-clock", "src/repro/core/x.py", 1)
        ]
        res2 = run_checks(root, baseline_path=bl, rule_ids=["wall-clock"])
        assert res2.ok
        assert len(res2.baselined) == 1

    def test_stale_entry_reported_not_fatal(self, tmp_path):
        root = make_tree(tmp_path, {"repro/core/x.py": "x = 1\n"})
        bl = tmp_path / "baseline.json"
        save_baseline(
            bl, [Finding("wall-clock", "src/repro/core/x.py", 1, "gone")]
        )
        res = run_checks(root, baseline_path=bl, rule_ids=["wall-clock"])
        assert res.ok
        assert len(res.stale_baseline) == 1

    def test_missing_baseline_is_empty(self, tmp_path):
        assert load_baseline(tmp_path / "nope.json") == []


# -- registry ----------------------------------------------------------------------


def test_rule_registry_complete():
    assert sorted(RULES) == [
        "import-boundary",
        "no-bare-assert",
        "seeded-rng",
        "unordered-iteration",
        "wall-clock",
    ]
    for rid, cls in RULES.items():
        assert cls.id == rid
        assert cls.description


# -- end-to-end over the shipped tree ----------------------------------------------


def _run_cli(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(CLI), *args],
        capture_output=True,
        text=True,
        cwd=str(REPO_ROOT),
        env={**os.environ, "PYTHONPATH": ""},  # stdlib-only: no src on path
    )


class TestEndToEnd:
    def test_shipped_tree_is_clean(self):
        proc = _run_cli()
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "0 violations" in proc.stdout

    def test_shipped_baseline_small_and_waivers_present(self):
        doc = json.loads((TOOLS_DIR / "contracts" / "baseline.json").read_text())
        assert len(doc["entries"]) <= 5
        res = run_checks(REPO_ROOT / "src",
                         baseline_path=TOOLS_DIR / "contracts" / "baseline.json")
        assert res.ok
        assert len(res.waived) >= 3
        # every shipped waiver carries a reason by construction (reason-free
        # waivers would show up as active waiver-syntax findings)
        assert not any(f.rule == WAIVER_SYNTAX_RULE for f in res.active)

    def test_seeded_violation_fails_with_anchor(self, tmp_path):
        shadow = tmp_path / "src"
        shutil.copytree(
            REPO_ROOT / "src", shadow, ignore=shutil.ignore_patterns("__pycache__")
        )
        victim = shadow / "repro" / "core" / "rms.py"
        victim.write_text(victim.read_text() + "\nimport jax\n")
        proc = _run_cli(
            "--root", str(shadow),
            "--baseline", str(TOOLS_DIR / "contracts" / "baseline.json"),
        )
        assert proc.returncode == 1
        assert "[import-boundary]" in proc.stdout
        # the anchor names the seeded file and its line
        n_lines = victim.read_text().count("\n")
        assert f"repro/core/rms.py:{n_lines}" in proc.stdout

    def test_list_rules(self):
        proc = _run_cli("--list-rules")
        assert proc.returncode == 0
        for rid in RULES:
            assert rid in proc.stdout

    def test_single_rule_selection(self):
        proc = _run_cli("--rule", "wall-clock", "-q")
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_unknown_rule_rejected(self, tmp_path):
        root = make_tree(tmp_path, {"repro/core/x.py": "x = 1\n"})
        with pytest.raises(ValueError, match="unknown rule"):
            run_checks(root, rule_ids=["no-such-rule"])
