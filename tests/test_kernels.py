"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py.

Kernels run in interpret mode (the container is CPU; TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_bhd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssm_scan import ssm_scan_bshp

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,S,D,bq,bk",
    [
        (1, 2, 2, 128, 64, 64, 64),   # MHA
        (2, 4, 2, 256, 64, 128, 64),  # GQA
        (1, 8, 1, 256, 128, 64, 128), # MQA, head_dim 128
        (2, 2, 2, 192, 32, 64, 96),   # uneven-ish blocks (both divide 192)
    ],
)
def test_flash_attention_shapes(B, H, KV, S, D, bq, bk, dtype):
    q = rand(0, (B, H, S, D), dtype)
    k = rand(1, (B, KV, S, D), dtype)
    v = rand(2, (B, KV, S, D), dtype)
    out = flash_attention_bhsd(q, k, v, block_q=bq, block_k=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    B, H, KV, S, D = 1, 2, 2, 256, 64
    q, k, v = rand(0, (B, H, S, D), jnp.float32), rand(1, (B, KV, S, D), jnp.float32), rand(2, (B, KV, S, D), jnp.float32)
    out = flash_attention_bhsd(q, k, v, window=window, block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,S,D,bk,valid_to",
    [
        (1, 4, 4, 256, 64, 128, 255),
        (2, 8, 2, 512, 64, 128, 300),
        (1, 4, 1, 256, 128, 256, 17),
    ],
)
def test_decode_attention_shapes(B, H, KV, S, D, bk, valid_to, dtype):
    q = rand(0, (B, H, D), dtype)
    k = rand(1, (B, S, KV, D), dtype)
    v = rand(2, (B, S, KV, D), dtype)
    valid = jnp.arange(S) <= valid_to
    out = decode_attention_bhd(
        q, k, v, jnp.broadcast_to(valid.astype(jnp.int32), (B, S)),
        block_k=bk, interpret=True,
    )
    expect = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [
        (1, 128, 2, 32, 16, 32),
        (2, 256, 4, 64, 32, 64),
        (1, 64, 8, 16, 64, 64),   # single chunk
        (2, 96, 2, 32, 16, 32),   # 3 chunks
    ],
)
def test_ssm_scan_shapes(B, S, H, P, N, chunk):
    x = rand(3, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(4, (B, S, H), jnp.float32))
    A = -jnp.exp(rand(5, (H,), jnp.float32) * 0.5)
    B_ = rand(6, (B, S, N), jnp.float32)
    C_ = rand(7, (B, S, N), jnp.float32)
    y, fin = ssm_scan_bshp(x, dt, A, B_, C_, chunk=chunk, interpret=True)
    yr, finr = ref.ssm_scan_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr), atol=2e-3, rtol=2e-3)


@given(
    s_blocks=st.integers(2, 4),
    h=st.sampled_from([1, 2, 4]),
    kv_div=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
)
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(s_blocks, h, kv_div, d):
    """Property: kernel == oracle for arbitrary GQA-compatible geometry."""
    if h % kv_div:
        return
    B, S = 1, 64 * s_blocks
    kv = h // kv_div
    q = rand(10, (B, h, S, d), jnp.float32)
    k = rand(11, (B, kv, S, d), jnp.float32)
    v = rand(12, (B, kv, S, d), jnp.float32)
    out = flash_attention_bhsd(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=3e-5, rtol=3e-5)


def test_ops_wrappers_match_bridge():
    """ops.py layout adapters agree with the models' jnp bridge."""
    from repro.kernels import ops
    from repro.models import kernels_bridge as kb

    B, S, H, KV, D = 1, 128, 4, 2, 64
    q = rand(0, (B, S, H, D), jnp.float32)
    k = rand(1, (B, S, KV, D), jnp.float32)
    v = rand(2, (B, S, KV, D), jnp.float32)
    out = ops.flash_attention(q, k, v)
    expect = kb.causal_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=3e-5, rtol=3e-5)

    valid = jnp.arange(S) <= 77
    qd = rand(3, (B, 1, H, D), jnp.float32)
    outd = ops.decode_attention(qd, k, v, valid, block_k=64)
    expectd = kb.decode_attention(qd, k, v, valid)
    np.testing.assert_allclose(np.asarray(outd), np.asarray(expectd), atol=3e-5, rtol=3e-5)
