"""End-to-end behaviour tests for the whole MIG-Serving system:
profiles → optimizer → controller → per-instance serving engines."""

import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    SLO,
    ConfigSpace,
    Controller,
    GreedyFast,
    SimulatedCluster,
    SyntheticPaperProfiles,
    Workload,
    a100_rules,
)
from repro.core.arch_bridge import tpu_arch_profiles
from repro.core.tpu_slice import pod_slice_rules, slice_mesh_shape
from repro.models import Model
from repro.serving import Engine, InstanceHandle, Request, WeightedRouter, run_closed_loop


def test_end_to_end_schedule_deploy_serve():
    """The full pipeline: optimize a deployment for 3 services, deploy it on
    the simulated cluster, then actually serve batched requests with an
    Engine per instance and verify every request completes."""
    prof = SyntheticPaperProfiles(n_models=3, seed=5)
    rng = np.random.default_rng(0)
    slos = {m: SLO(float(rng.lognormal(6.0, 0.4)), 100.0) for m in prof.services()}
    wl = Workload.make(slos)
    dep = GreedyFast(ConfigSpace(a100_rules(), prof, wl)).solve()
    assert dep.is_valid(wl)

    ctrl = Controller(a100_rules(), prof)
    cluster = SimulatedCluster(a100_rules(), dep.num_gpus)
    ctrl.deploy_fresh(cluster, dep)
    assert cluster.gpus_in_use() == dep.num_gpus

    # serve real tokens through a real model on one scheduled instance
    cfg = get_smoke_config("qwen3-8b")
    model = Model(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, batch=2, max_len=64)
    reqs = [
        Request(rid=i, prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
        for i in range(4)
    ]
    stats = run_closed_loop(engine, reqs)
    assert stats.served == 4
    assert all(r.done for r in reqs)


def test_closed_loop_roofline_profiles_schedule_all_ten_archs():
    """Beyond-paper closed loop: the 10 assigned architectures scheduled on
    pod-granularity TPU slices using roofline-derived profiles."""
    rules = pod_slice_rules()
    prof = tpu_arch_profiles()
    rng = np.random.default_rng(1)
    slos = {}
    for m in prof.services():
        base = prof.throughput(m, prof.min_size(m), 50.0)
        slos[m] = SLO(base * float(rng.uniform(1.5, 4.0)), 50.0)
    wl = Workload.make(slos)
    space = ConfigSpace(rules, prof, wl)
    dep = GreedyFast(space).solve()
    assert dep.is_valid(wl)
    # the big MoE/dense archs only ever land on slices they fit on
    for cfgp in dep.configs:
        for a in cfgp.assignments:
            if a.service is not None:
                assert a.size >= prof.min_size(a.service)


def test_router_weighted_dispatch():
    insts = [
        InstanceHandle(0, 1, throughput=10.0),
        InstanceHandle(1, 2, throughput=30.0),
    ]
    router = WeightedRouter(insts)
    for _ in range(400):
        router.pick()
    counts = router.dispatch_counts()
    assert counts[1] == pytest.approx(300, abs=2)
    assert counts[0] == pytest.approx(100, abs=2)


def test_slice_meshes_match_scheduled_sizes():
    rules = pod_slice_rules()
    for s in rules.instance_sizes:
        r, c = slice_mesh_shape(s)
        assert r * c == s
