"""Paged attention kernel + page-pool manager."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.ref import paged_decode_attention_ref
from repro.serving.paged_cache import OutOfPages, PagePool


def rand(i, shape):
    return jax.random.normal(jax.random.PRNGKey(i), shape)


@pytest.mark.parametrize(
    "B,H,KV,D,num_pages,page_size,max_pages",
    [
        (2, 4, 2, 64, 8, 16, 3),
        (3, 8, 2, 64, 16, 32, 4),
        (1, 8, 1, 128, 8, 64, 2),  # MQA
        (2, 4, 4, 32, 12, 8, 6),   # MHA small pages
    ],
)
def test_paged_kernel_matches_ref(B, H, KV, D, num_pages, page_size, max_pages):
    rng = np.random.default_rng(0)
    q = rand(0, (B, H, D))
    pk = rand(1, (num_pages, page_size, KV, D))
    pv = rand(2, (num_pages, page_size, KV, D))
    pt = jnp.asarray(
        rng.integers(0, num_pages, size=(B, max_pages)), jnp.int32
    )
    lengths = jnp.asarray(
        rng.integers(1, max_pages * page_size + 1, size=(B,)), jnp.int32
    )
    out = paged_decode_attention(q, pk, pv, pt, lengths, interpret=True)
    ref = paged_decode_attention_ref(q, pk, pv, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


class TestPagePool:
    def test_alloc_grow_release_reuse(self):
        pool = PagePool(num_pages=4, page_size=8, max_pages_per_req=3)
        pool.admit(1)
        pool.append_tokens(1, 8)   # exactly one page
        assert pool.free_pages == 3
        pool.append_tokens(1, 1)   # crosses into page 2
        assert pool.free_pages == 2
        pt, lens = pool.tables([1])
        assert lens[0] == 9
        assert pt.shape == (1, 3)
        pool.release(1)
        assert pool.free_pages == 4

    def test_pool_exhaustion_signals_admission_control(self):
        pool = PagePool(num_pages=2, page_size=4, max_pages_per_req=4)
        pool.admit(1)
        pool.append_tokens(1, 8)  # both pages
        pool.admit(2)
        with pytest.raises(OutOfPages):
            pool.append_tokens(2, 1)

    def test_per_request_cap(self):
        pool = PagePool(num_pages=10, page_size=4, max_pages_per_req=2)
        pool.admit(1)
        with pytest.raises(OutOfPages):
            pool.append_tokens(1, 9)

    def test_hbm_budget_maps_to_slice_capacity(self):
        pool = PagePool(num_pages=1024, page_size=16, max_pages_per_req=64)
        b = pool.hbm_bytes(kv_heads=8, head_dim=128, n_layers=36)
        # qwen3-8b-ish: 2*1024*16*8*128*36*2 bytes
        assert b == 2 * 1024 * 16 * 8 * 128 * 36 * 2

    @given(st.lists(st.integers(1, 30), min_size=1, max_size=12))
    @settings(max_examples=25, deadline=None)
    def test_no_page_leaks(self, growths):
        """Property: admit/grow/release conserves the page inventory."""
        pool = PagePool(num_pages=64, page_size=4, max_pages_per_req=16)
        rids = []
        for i, g in enumerate(growths):
            pool.admit(i)
            try:
                pool.append_tokens(i, g)
                rids.append(i)
            except OutOfPages:
                pool.release(i)
        for rid in rids:
            pool.release(rid)
        assert pool.free_pages == 64
