"""Paged attention kernel + page-pool manager."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.ref import paged_decode_attention_ref
from repro.serving.paged_cache import OutOfPages, PagePool


def rand(i, shape):
    return jax.random.normal(jax.random.PRNGKey(i), shape)


@pytest.mark.parametrize(
    "B,H,KV,D,num_pages,page_size,max_pages",
    [
        (2, 4, 2, 64, 8, 16, 3),
        (3, 8, 2, 64, 16, 32, 4),
        (1, 8, 1, 128, 8, 64, 2),  # MQA
        (2, 4, 4, 32, 12, 8, 6),   # MHA small pages
    ],
)
def test_paged_kernel_matches_ref(B, H, KV, D, num_pages, page_size, max_pages):
    rng = np.random.default_rng(0)
    q = rand(0, (B, H, D))
    pk = rand(1, (num_pages, page_size, KV, D))
    pv = rand(2, (num_pages, page_size, KV, D))
    pt = jnp.asarray(
        rng.integers(0, num_pages, size=(B, max_pages)), jnp.int32
    )
    lengths = jnp.asarray(
        rng.integers(1, max_pages * page_size + 1, size=(B,)), jnp.int32
    )
    out = paged_decode_attention(q, pk, pv, pt, lengths, interpret=True)
    ref = paged_decode_attention_ref(q, pk, pv, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


class TestPagePool:
    def test_alloc_grow_release_reuse(self):
        pool = PagePool(num_pages=4, page_size=8, max_pages_per_req=3)
        pool.admit(1)
        pool.append_tokens(1, 8)   # exactly one page
        assert pool.free_pages == 3
        pool.append_tokens(1, 1)   # crosses into page 2
        assert pool.free_pages == 2
        pt, lens = pool.tables([1])
        assert lens[0] == 9
        assert pt.shape == (1, 3)
        pool.release(1)
        assert pool.free_pages == 4

    def test_double_release_is_a_guarded_noop(self):
        """Releasing a rid twice (a preempt racing a finish, or a release
        after a crash swapped the pool) must not re-insert its pages into
        the free list — a double free would hand one page to two requests
        and silently corrupt both KV caches."""
        pool = PagePool(num_pages=4, page_size=8, max_pages_per_req=4)
        pool.admit(1)
        pool.append_tokens(1, 16)  # two pages
        assert pool.release(1) is True
        assert pool.free_pages == 4
        assert pool.release(1) is False  # second release: no-op
        assert pool.free_pages == 4  # and no free-list growth
        assert pool.release(99) is False  # never-admitted rid: same guard
        # the free list still hands out 4 distinct pages
        pool.admit(2)
        pool.append_tokens(2, 32)
        assert pool.free_pages == 0
        assert len(set(pool._requests[2].page_ids)) == 4

    def test_pool_exhaustion_signals_admission_control(self):
        pool = PagePool(num_pages=2, page_size=4, max_pages_per_req=4)
        pool.admit(1)
        pool.append_tokens(1, 8)  # both pages
        pool.admit(2)
        with pytest.raises(OutOfPages):
            pool.append_tokens(2, 1)

    def test_per_request_cap(self):
        pool = PagePool(num_pages=10, page_size=4, max_pages_per_req=2)
        pool.admit(1)
        with pytest.raises(OutOfPages):
            pool.append_tokens(1, 9)

    def test_hbm_budget_maps_to_slice_capacity(self):
        pool = PagePool(num_pages=1024, page_size=16, max_pages_per_req=64)
        b = pool.hbm_bytes(kv_heads=8, head_dim=128, n_layers=36)
        # qwen3-8b-ish: 2*1024*16*8*128*36*2 bytes
        assert b == 2 * 1024 * 16 * 8 * 128 * 36 * 2

    def test_tables_skip_idle_slots(self):
        """None entries (idle engine slots) produce the all-zero dummy row."""
        pool = PagePool(num_pages=8, page_size=4, max_pages_per_req=3)
        pool.admit(5)
        pool.append_tokens(5, 6)
        pt, lens = pool.tables([None, 5, None])
        assert lens.tolist() == [0, 6, 0]
        assert pt[0].tolist() == [0, 0, 0] and pt[2].tolist() == [0, 0, 0]
        assert pt[1, :2].tolist() == pool.request(5).page_ids

    def test_append_is_atomic_on_pool_exhaustion(self):
        """A failed grow must roll back mid-loop allocations: the request's
        record and the pool's free list are exactly as before the call."""
        pool = PagePool(num_pages=3, page_size=4, max_pages_per_req=8)
        pool.admit(1)
        pool.append_tokens(1, 4)  # 1 page
        pool.admit(2)
        pool.append_tokens(2, 1)  # 1 page
        free_before = list(pool._free)
        r = pool.request(1)
        pages_before, len_before = list(r.page_ids), r.length
        with pytest.raises(OutOfPages):
            pool.append_tokens(1, 12)  # needs 3 more pages, only 1 free
        assert pool._free == free_before
        assert r.page_ids == pages_before and r.length == len_before
        pool.append_tokens(1, 4)  # the single free page still works

    def test_append_is_atomic_on_per_request_cap(self):
        pool = PagePool(num_pages=16, page_size=4, max_pages_per_req=2)
        pool.admit(1)
        pool.append_tokens(1, 5)  # 2 pages
        free_before = pool.free_pages
        with pytest.raises(OutOfPages):
            pool.append_tokens(1, 8)
        assert pool.free_pages == free_before
        assert pool.request(1).length == 5

    @given(st.lists(st.integers(1, 30), min_size=1, max_size=12))
    @settings(max_examples=25, deadline=None)
    def test_no_page_leaks(self, growths):
        """Property: admit/grow/release conserves the page inventory."""
        pool = PagePool(num_pages=64, page_size=4, max_pages_per_req=16)
        rids = []
        for i, g in enumerate(growths):
            pool.admit(i)
            try:
                pool.append_tokens(i, g)
                rids.append(i)
            except OutOfPages:
                pool.release(i)
        for rid in rids:
            pool.release(rid)
        assert pool.free_pages == 64

    @given(st.lists(st.integers(1, 40), min_size=1, max_size=16),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_exhaustion_atomicity_property(self, growths, seed):
        """Property: every failed append leaves (free count, per-request
        lengths, per-request page counts) unchanged, and interleaved releases
        still conserve the inventory."""
        rng = np.random.default_rng(seed)
        pool = PagePool(num_pages=16, page_size=4, max_pages_per_req=6)
        live = {}
        for i, g in enumerate(growths):
            if live and rng.random() < 0.3:
                victim = sorted(live)[int(rng.integers(len(live)))]
                pool.release(victim)
                del live[victim]
            if i not in live:
                pool.admit(i)
                live[i] = True
            snapshot = (
                pool.free_pages,
                {r: (pool.request(r).length, len(pool.request(r).page_ids))
                 for r in live},
            )
            try:
                pool.append_tokens(i, g)
            except OutOfPages:
                after = (
                    pool.free_pages,
                    {r: (pool.request(r).length, len(pool.request(r).page_ids))
                     for r in live},
                )
                assert after == snapshot
        for r in list(live):
            pool.release(r)
        assert pool.free_pages == 16
