#!/usr/bin/env python
"""Contract checker CLI: static enforcement of the repo's invariants.

Runs the AST-based rules in ``tools/contracts/`` over ``src/`` and exits
non-zero on any violation that is neither inline-waived
(``# contract-ok: <rule-id> <reason>``) nor recorded in the committed
baseline (``tools/contracts/baseline.json``).  Stdlib-only: no PYTHONPATH,
no installs — CI runs it before anything else.

Usage::

    python tools/check_contracts.py                   # the shipped tree
    python tools/check_contracts.py --list-rules      # rule ids + scopes
    python tools/check_contracts.py --rule wall-clock # one rule only
    python tools/check_contracts.py --update-baseline # adopt current debt

Rule ids, the waiver grammar, and the baseline workflow are documented in
``docs/CONTRACTS.md``.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

TOOLS_DIR = Path(__file__).resolve().parent
REPO_ROOT = TOOLS_DIR.parent
sys.path.insert(0, str(TOOLS_DIR))

from contracts import run_checks, save_baseline  # noqa: E402
from contracts.rules import RULES  # noqa: E402

DEFAULT_ROOT = REPO_ROOT / "src"
DEFAULT_BASELINE = TOOLS_DIR / "contracts" / "baseline.json"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument(
        "--root",
        default=str(DEFAULT_ROOT),
        help="directory holding the top-level package(s) (default: src/)",
    )
    ap.add_argument(
        "--baseline",
        default=str(DEFAULT_BASELINE),
        help="baseline JSON (default: tools/contracts/baseline.json; a "
        "missing file means an empty baseline)",
    )
    ap.add_argument(
        "--rule",
        action="append",
        metavar="ID",
        help="run only this rule id (repeatable; default: all rules)",
    )
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite the baseline to adopt every currently-active "
        "violation, then exit 0",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print rule ids and exit"
    )
    ap.add_argument(
        "-q", "--quiet", action="store_true", help="summary line only"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid:22s} {RULES[rid].description}")
        return 0

    root = Path(args.root)
    if not root.is_dir():
        print(f"contract-check: no such root: {root}", file=sys.stderr)
        return 2
    baseline = Path(args.baseline) if args.baseline else None
    result = run_checks(root, baseline_path=baseline, rule_ids=args.rule)

    if args.update_baseline:
        if baseline is None:
            print("contract-check: --update-baseline needs --baseline",
                  file=sys.stderr)
            return 2
        save_baseline(baseline, result.active)
        print(
            f"contract-check: baseline rewritten with "
            f"{len(result.active)} entr{'y' if len(result.active) == 1 else 'ies'}"
            f" -> {baseline}"
        )
        return 0

    if not args.quiet:
        for f in result.active:
            print(f)
        for entry in result.stale_baseline:
            print(
                f"contract-check: stale baseline entry (fixed? regen with "
                f"--update-baseline): {entry['rule']} at "
                f"{entry['file']}:{entry['line']}"
            )
    print(
        f"contract-check: {len(result.active)} violation"
        f"{'' if len(result.active) == 1 else 's'} "
        f"({len(result.waived)} waived, {len(result.baselined)} baselined, "
        f"{len(result.stale_baseline)} stale baseline entr"
        f"{'y' if len(result.stale_baseline) == 1 else 'ies'}) "
        f"across {result.n_files} files"
    )
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
