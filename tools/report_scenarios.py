#!/usr/bin/env python
"""Scenario leaderboard report: BENCH_scenarios.json -> one HTML file.

Renders the scenario-matrix benchmark document
(``benchmarks/bench_scenarios.py``) as a self-contained HTML/SVG page:

* the **leaderboard** — per cell group (trace/scale/slo/fault[/serving
  [/priority]]), schedulers ranked exactly as the benchmark's stdout
  leaderboard (peak GPUs ascending, ties by mean attainment descending,
  then modeled power ascending), winner first;
* **per-axis breakdowns** — for each of the seven matrix axes, the mean
  attainment, mean GPUs saved, worst served fraction, and mean availability
  over every cell carrying each axis value;
* **cross-PR trend lines** — mean attainment, total GPUs saved, and cell
  count over the git history of ``BENCH_scenarios.json`` (each prior
  committed revision is read via ``git show``), so a regression in the
  headline numbers is visible at a glance.  ``--no-git`` (or a missing git
  history) skips this section — the rest of the report never depends on it.

The output is deterministic: same input document + same git history =>
byte-identical HTML.  No wall clock, no hostnames, no external assets.

Usage::

    PYTHONPATH=src python tools/report_scenarios.py                # repo doc
    PYTHONPATH=src python tools/report_scenarios.py \\
        --bench /tmp/BENCH_scenarios_smoke.json --out /tmp/report.html --no-git
    python tools/report_scenarios.py --compare old.json new.json \\
        --out /tmp/diff.html   # cell-by-cell diff of two benchmark documents
"""

from __future__ import annotations

import argparse
import html
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_BENCH = os.path.join(REPO_ROOT, "BENCH_scenarios.json")
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_report.html")

AXES = (
    ("trace", "Trace shape"),
    ("scheduler", "Scheduler"),
    ("scale", "Scale"),
    ("slo", "SLO policy"),
    ("fault", "Fault profile"),
    ("serving", "Serving model"),
    ("priority", "Priority mix"),
)

_CSS = """
body { font-family: system-ui, sans-serif; margin: 2em auto; max-width: 70em;
       color: #222; }
h1 { border-bottom: 2px solid #444; padding-bottom: .2em; }
h2 { margin-top: 2em; }
table { border-collapse: collapse; margin: 1em 0; font-size: .9em; }
th, td { border: 1px solid #bbb; padding: .25em .6em; text-align: right; }
th { background: #eee; }
td.name, th.name { text-align: left; font-family: monospace; }
td.win { font-weight: bold; background: #e8f4e8; }
.small { color: #666; font-size: .85em; }
svg { background: #fafafa; border: 1px solid #ddd; }
"""


def cell_group(cell: Dict) -> str:
    """The leaderboard grouping key — same shape as the benchmark's stdout
    leaderboard: scheduler is the ranked-within dimension, every other axis
    names the group."""
    key = "{trace}/{scale}/{slo}/{fault}".format(**cell)
    if cell.get("serving", "fluid") != "fluid":
        key += "/" + cell["serving"]
    if cell.get("priority", "none") != "none":
        key += "/" + cell["priority"]
    return key


def rank_key(c: Dict) -> Tuple:
    return (c["gpus_peak"], -c["mean_attainment"], c["power_w"])


def fmt(v: float, nd: int = 3) -> str:
    return f"{v:.{nd}f}"


# -- SVG helpers (hand-rolled: no plotting dependency, deterministic) --------
def svg_bars(
    labels: List[str], values: List[float], title: str, width: int = 640
) -> str:
    """A labeled horizontal bar chart as one inline SVG string."""
    if not labels:
        return ""
    bar_h, gap, left = 18, 6, 170
    height = 30 + len(labels) * (bar_h + gap)
    vmax = max(max(values), 1e-9)
    rows = [
        f'<svg width="{width}" height="{height}" role="img">',
        f'<text x="4" y="16" font-size="13" font-weight="bold">'
        f"{html.escape(title)}</text>",
    ]
    for i, (lab, val) in enumerate(zip(labels, values)):
        y = 26 + i * (bar_h + gap)
        w = max(1.0, (width - left - 90) * val / vmax)
        rows.append(
            f'<text x="{left - 6}" y="{y + 13}" font-size="11" '
            f'text-anchor="end" font-family="monospace">{html.escape(lab)}</text>'
        )
        rows.append(
            f'<rect x="{left}" y="{y}" width="{fmt(w, 1)}" height="{bar_h}" '
            f'fill="#4a7fb5"/>'
        )
        rows.append(
            f'<text x="{fmt(left + w + 4, 1)}" y="{y + 13}" '
            f'font-size="11">{fmt(val)}</text>'
        )
    rows.append("</svg>")
    return "\n".join(rows)


def svg_trend(
    points: List[Tuple[str, float]], title: str, width: int = 640, nd: int = 3
) -> str:
    """A labeled line chart over ordered (label, value) revision points."""
    if len(points) < 2:
        return '<p class="small">(fewer than two revisions — no trend)</p>'
    height, pad_l, pad_r, pad_t, pad_b = 180, 60, 20, 28, 38
    vals = [v for _, v in points]
    vmin, vmax = min(vals), max(vals)
    if vmax - vmin < 1e-12:
        vmin, vmax = vmin - 0.5, vmax + 0.5
    span_x = width - pad_l - pad_r
    span_y = height - pad_t - pad_b
    xs = [pad_l + span_x * i / (len(points) - 1) for i in range(len(points))]
    ys = [pad_t + span_y * (1.0 - (v - vmin) / (vmax - vmin)) for v in vals]
    poly = " ".join(f"{fmt(x, 1)},{fmt(y, 1)}" for x, y in zip(xs, ys))
    rows = [
        f'<svg width="{width}" height="{height}" role="img">',
        f'<text x="4" y="16" font-size="13" font-weight="bold">'
        f"{html.escape(title)}</text>",
        f'<text x="{pad_l - 6}" y="{pad_t + 4}" font-size="10" '
        f'text-anchor="end">{fmt(vmax, nd)}</text>',
        f'<text x="{pad_l - 6}" y="{pad_t + span_y + 4}" font-size="10" '
        f'text-anchor="end">{fmt(vmin, nd)}</text>',
        f'<polyline points="{poly}" fill="none" stroke="#b5574a" '
        f'stroke-width="2"/>',
    ]
    for (lab, v), x, y in zip(points, xs, ys):
        rows.append(f'<circle cx="{fmt(x, 1)}" cy="{fmt(y, 1)}" r="3" fill="#b5574a"/>')
        rows.append(
            f'<text x="{fmt(x, 1)}" y="{height - 20}" font-size="10" '
            f'text-anchor="middle" font-family="monospace">{html.escape(lab)}</text>'
        )
    rows.append("</svg>")
    return "\n".join(rows)


# -- git history --------------------------------------------------------------
def bench_history(
    bench_path: str, limit: int = 12
) -> List[Tuple[str, Dict]]:
    """Prior committed revisions of the benchmark doc, oldest first, as
    (short sha, parsed doc).  Empty on any git failure — the report must
    render identically with ``--no-git`` and without a history."""
    repo = os.path.dirname(os.path.abspath(bench_path)) or "."
    rel = os.path.basename(bench_path)
    try:
        out = subprocess.run(
            ["git", "-C", repo, "log", "--format=%H", "--", rel],
            capture_output=True, text=True, check=True,
        ).stdout.split()
    except (OSError, subprocess.CalledProcessError):
        return []
    revs = list(reversed(out))[-limit:]  # oldest first, bounded
    history: List[Tuple[str, Dict]] = []
    for rev in revs:
        try:
            blob = subprocess.run(
                ["git", "-C", repo, "show", f"{rev}:{rel}"],
                capture_output=True, text=True, check=True,
            ).stdout
            history.append((rev[:8], json.loads(blob)))
        except (OSError, subprocess.CalledProcessError, ValueError):
            continue  # a revision predating the doc, or unparsable
    return history


def doc_summary(doc: Dict) -> Dict[str, float]:
    cells = list(doc.get("cells", {}).values())
    n = max(len(cells), 1)
    return {
        "cells": float(len(cells)),
        "mean_attainment": sum(c["mean_attainment"] for c in cells) / n,
        "gpus_saved": float(sum(c["gpus_saved"] for c in cells)),
        "availability": sum(c.get("availability", 1.0) for c in cells) / n,
    }


# -- report body --------------------------------------------------------------
def leaderboard_section(cells: Dict[str, Dict]) -> List[str]:
    groups: Dict[str, List[Dict]] = {}
    for c in cells.values():
        groups.setdefault(cell_group(c["cell"]), []).append(c)
    parts = [
        "<h2>Leaderboard</h2>",
        '<p class="small">Schedulers ranked per cell group: peak GPUs '
        "ascending, ties by mean attainment (higher better), then modeled "
        "power (lower better).  Winner highlighted.</p>",
        "<table><tr><th class='name'>group</th><th>rank</th>"
        "<th class='name'>scheduler</th><th>gpus_peak</th><th>saved</th>"
        "<th>attainment</th><th>power_w</th><th>avail</th>"
        "<th>transparent</th></tr>",
    ]
    for key in sorted(groups):
        ranked = sorted(groups[key], key=rank_key)
        for i, c in enumerate(ranked):
            win = " class='win'" if i == 0 else ""
            parts.append(
                "<tr>"
                + (
                    f"<td class='name' rowspan='{len(ranked)}'>"
                    f"{html.escape(key)}</td>"
                    if i == 0
                    else ""
                )
                + f"<td{win}>{i + 1}</td>"
                f"<td class='name'>{html.escape(c['cell']['scheduler'])}</td>"
                f"<td>{c['gpus_peak']}</td><td>{c['gpus_saved']}</td>"
                f"<td>{fmt(c['mean_attainment'])}</td>"
                f"<td>{fmt(c['power_w'], 0)}</td>"
                f"<td>{fmt(c.get('availability', 1.0))}</td>"
                f"<td>{'yes' if c['transparent'] else 'NO'}</td></tr>"
            )
    parts.append("</table>")
    return parts


def axis_sections(cells: Dict[str, Dict]) -> List[str]:
    parts = ["<h2>Per-axis breakdowns</h2>"]
    for axis, label in AXES:
        by_value: Dict[str, List[Dict]] = {}
        for c in cells.values():
            by_value.setdefault(
                c["cell"].get(axis, "none"), []
            ).append(c)
        if len(by_value) < 2 and axis not in ("trace", "scheduler"):
            continue  # a degenerate axis (e.g. one-cell doc) adds no signal
        parts.append(f"<h3>{html.escape(label)}</h3>")
        parts.append(
            "<table><tr><th class='name'>value</th><th>cells</th>"
            "<th>mean attainment</th><th>mean saved</th>"
            "<th>worst served frac</th><th>mean avail</th></tr>"
        )
        for value in sorted(by_value):
            grp = by_value[value]
            parts.append(
                f"<tr><td class='name'>{html.escape(value)}</td>"
                f"<td>{len(grp)}</td>"
                f"<td>{fmt(sum(c['mean_attainment'] for c in grp) / len(grp))}</td>"
                f"<td>{fmt(sum(c['gpus_saved'] for c in grp) / len(grp), 1)}</td>"
                f"<td>{fmt(min(c['served_fraction'] for c in grp))}</td>"
                f"<td>{fmt(sum(c.get('availability', 1.0) for c in grp) / len(grp))}</td>"
                "</tr>"
            )
        parts.append("</table>")
        if axis == "scheduler":
            labels = sorted(by_value)
            parts.append(
                svg_bars(
                    labels,
                    [
                        sum(c["mean_attainment"] for c in by_value[v])
                        / len(by_value[v])
                        for v in labels
                    ],
                    "mean attainment by scheduler",
                )
            )
    return parts


def trend_section(
    history: List[Tuple[str, Dict]], current: Dict
) -> List[str]:
    points = [(sha, doc_summary(doc)) for sha, doc in history]
    cur = doc_summary(current)
    if not points or points[-1][1] != cur:
        points.append(("work", cur))
    parts = [
        "<h2>Cross-PR trends</h2>",
        '<p class="small">One point per committed revision of the benchmark '
        "document (oldest left; <code>work</code> = the file on disk when it "
        "differs from the newest commit).</p>",
    ]
    for metric, title, nd in (
        ("mean_attainment", "mean attainment over all cells", 3),
        ("gpus_saved", "total GPUs saved vs A100-as-is", 0),
        ("cells", "matrix size (cells)", 0),
    ):
        parts.append(
            svg_trend([(sha, s[metric]) for sha, s in points], title, nd=nd)
        )
    return parts


#: Metrics diffed per cell by ``--compare`` (name, display decimals).
COMPARE_METRICS: Tuple[Tuple[str, int], ...] = (
    ("gpus_peak", 0),
    ("gpus_saved", 0),
    ("mean_attainment", 3),
    ("served_fraction", 3),
    ("power_w", 0),
    ("availability", 3),
)


def compare_cells(doc_a: Dict, doc_b: Dict) -> Dict:
    """Cell-by-cell structural diff of two benchmark documents.

    Returns ``{"added": [...], "removed": [...], "changed": {key: {metric:
    (a, b)}}, "unchanged": [...]}`` — keys sorted, so downstream rendering
    is deterministic."""
    ca, cb = doc_a["cells"], doc_b["cells"]
    added = sorted(k for k in cb if k not in ca)
    removed = sorted(k for k in ca if k not in cb)
    changed: Dict[str, Dict[str, Tuple[float, float]]] = {}
    unchanged: List[str] = []
    for key in sorted(set(ca) & set(cb)):
        deltas: Dict[str, Tuple[float, float]] = {}
        for metric, _ in COMPARE_METRICS:
            va = ca[key].get(metric)
            vb = cb[key].get(metric)
            if va != vb:
                deltas[metric] = (va, vb)
        if ca[key].get("transparent") != cb[key].get("transparent"):
            deltas["transparent"] = (
                ca[key].get("transparent"),
                cb[key].get("transparent"),
            )
        if deltas:
            changed[key] = deltas
        else:
            unchanged.append(key)
    return {
        "added": added,
        "removed": removed,
        "changed": changed,
        "unchanged": unchanged,
    }


def compare_section(
    doc_a: Dict, doc_b: Dict, label_a: str, label_b: str
) -> List[str]:
    diff = compare_cells(doc_a, doc_b)
    nd = {m: d for m, d in COMPARE_METRICS}
    parts = [
        "<h2>Document comparison</h2>",
        f'<p class="small">A = <code>{html.escape(label_a)}</code> '
        f"({len(doc_a['cells'])} cells) &middot; "
        f"B = <code>{html.escape(label_b)}</code> "
        f"({len(doc_b['cells'])} cells) &middot; "
        f"{len(diff['changed'])} changed, {len(diff['unchanged'])} "
        f"unchanged, {len(diff['added'])} added, "
        f"{len(diff['removed'])} removed</p>",
    ]
    for title, keys in (("Added in B", diff["added"]),
                        ("Removed in B", diff["removed"])):
        if keys:
            parts.append(f"<h3>{title}</h3><ul>")
            parts.extend(
                f"<li><code>{html.escape(k)}</code></li>" for k in keys
            )
            parts.append("</ul>")
    if diff["changed"]:
        parts.append("<h3>Per-metric deltas</h3>")
        parts.append(
            "<table><tr><th class='name'>cell</th><th class='name'>metric"
            "</th><th>A</th><th>B</th><th>delta</th></tr>"
        )
        for key, deltas in diff["changed"].items():  # already key-sorted
            first = True
            for metric in sorted(deltas):
                va, vb = deltas[metric]
                if metric == "transparent":
                    a_s, b_s, d_s = str(va), str(vb), "flip"
                else:
                    d = nd.get(metric, 3)
                    a_s, b_s = fmt(float(va), d), fmt(float(vb), d)
                    d_s = f"{float(vb) - float(va):+.{d}f}"
                parts.append(
                    "<tr>"
                    + (
                        f"<td class='name' rowspan='{len(deltas)}'>"
                        f"{html.escape(key)}</td>"
                        if first
                        else ""
                    )
                    + f"<td class='name'>{html.escape(metric)}</td>"
                    f"<td>{a_s}</td><td>{b_s}</td><td>{d_s}</td></tr>"
                )
                first = False
        parts.append("</table>")
    else:
        parts.append(
            '<p class="small">No per-metric drift across common cells.</p>'
        )
    return parts


def render_compare(
    doc_a: Dict, doc_b: Dict, label_a: str, label_b: str
) -> str:
    parts = [
        "<!DOCTYPE html>",
        '<html lang="en"><head><meta charset="utf-8">',
        "<title>MIG-serving scenario comparison</title>",
        f"<style>{_CSS}</style></head><body>",
        "<h1>MIG-serving scenario comparison</h1>",
    ]
    parts += compare_section(doc_a, doc_b, label_a, label_b)
    parts.append("</body></html>")
    return "\n".join(parts) + "\n"


def render(doc: Dict, history: List[Tuple[str, Dict]]) -> str:
    cells = doc["cells"]
    parts = [
        "<!DOCTYPE html>",
        '<html lang="en"><head><meta charset="utf-8">',
        "<title>MIG-serving scenario leaderboard</title>",
        f"<style>{_CSS}</style></head><body>",
        "<h1>MIG-serving scenario leaderboard</h1>",
        f'<p class="small">schema {doc.get("schema")} &middot; '
        f'seed {doc.get("seed")} &middot; {len(cells)} cells</p>',
    ]
    parts += leaderboard_section(cells)
    parts += axis_sections(cells)
    parts += trend_section(history, doc)
    parts.append("</body></html>")
    return "\n".join(parts) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default=DEFAULT_BENCH,
                    help="scenario benchmark JSON (default: repo "
                         "BENCH_scenarios.json)")
    ap.add_argument("--out", default=None,
                    help="output HTML path (default: BENCH_report.html next "
                         "to --bench when that is the repo doc, else "
                         "<bench>.html)")
    ap.add_argument("--no-git", action="store_true",
                    help="skip the cross-PR trend section (hermetic runs)")
    ap.add_argument("--history", type=int, default=12, metavar="N",
                    help="max prior revisions in the trend (default 12)")
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"), default=None,
                    help="diff two benchmark documents cell by cell "
                         "(added/removed cells, per-metric deltas) instead "
                         "of rendering the leaderboard; --bench is ignored")
    args = ap.parse_args(argv)

    if args.compare is not None:
        path_a, path_b = args.compare
        docs = []
        for p in (path_a, path_b):
            with open(p) as f:
                d = json.load(f)
            if "cells" not in d or not d["cells"]:
                raise SystemExit(f"{p}: no cells — not a scenario benchmark doc")
            docs.append(d)
        out_path = args.out or (os.path.splitext(path_b)[0] + "_compare.html")
        text = render_compare(
            docs[0], docs[1], os.path.basename(path_a), os.path.basename(path_b)
        )
        with open(out_path, "w") as f:
            f.write(text)
        diff = compare_cells(docs[0], docs[1])
        print(
            f"wrote {out_path} ({len(diff['changed'])} changed, "
            f"{len(diff['unchanged'])} unchanged, {len(diff['added'])} added, "
            f"{len(diff['removed'])} removed)"
        )
        return 0

    with open(args.bench) as f:
        doc = json.load(f)
    if "cells" not in doc or not doc["cells"]:
        raise SystemExit(f"{args.bench}: no cells — not a scenario benchmark doc")
    out_path = args.out or (
        DEFAULT_OUT
        if os.path.abspath(args.bench) == DEFAULT_BENCH
        else os.path.splitext(args.bench)[0] + ".html"
    )
    history = [] if args.no_git else bench_history(args.bench, args.history)
    html_text = render(doc, history)
    with open(out_path, "w") as f:
        f.write(html_text)
    print(
        f"wrote {out_path} ({len(doc['cells'])} cells, "
        f"{len(history)} historical revisions)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
