#!/usr/bin/env python
"""Docs smoke: execute the README quickstart verbatim so it cannot rot.

Extracts the fenced code block tagged ``bash quickstart`` from the
top-level ``README.md`` and runs each command line (comments skipped) from
the repo root, failing on the first non-zero exit.  CI runs this in both
test jobs — if someone edits the quickstart into something that no longer
works, or renames a flag the quickstart uses, the build breaks instead of
the docs silently lying.

Usage::

    python tools/docs_smoke.py            # run the quickstart
    python tools/docs_smoke.py --print    # show the extracted commands only
"""

from __future__ import annotations

import os
import subprocess
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
README = os.path.join(REPO_ROOT, "README.md")
FENCE_TAG = "bash quickstart"


def extract_quickstart(readme_path: str = README) -> list[str]:
    """The command lines of the ``bash quickstart`` fenced block."""
    commands: list[str] = []
    in_block = False
    with open(readme_path) as f:
        for line in f:
            stripped = line.strip()
            if stripped == f"```{FENCE_TAG}":
                in_block = True
                continue
            if in_block and stripped == "```":
                break
            if in_block and stripped and not stripped.startswith("#"):
                commands.append(stripped)
    if not commands:
        raise SystemExit(
            f"no ```{FENCE_TAG} block with commands found in {readme_path}"
        )
    return commands


def main() -> int:
    commands = extract_quickstart()
    if "--print" in sys.argv:
        print("\n".join(commands))
        return 0
    for cmd in commands:
        print(f"[docs-smoke] $ {cmd}", flush=True)
        proc = subprocess.run(cmd, shell=True, cwd=REPO_ROOT)
        if proc.returncode != 0:
            print(
                f"[docs-smoke] FAILED (exit {proc.returncode}): {cmd}\n"
                "the README quickstart no longer works — fix the docs or "
                "the code",
                file=sys.stderr,
            )
            return proc.returncode
    print(f"[docs-smoke] all {len(commands)} quickstart commands passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
