#!/usr/bin/env python
"""Docs smoke: execute documented quickstart blocks verbatim so they cannot rot.

Extracts tagged fenced code blocks from the docs — the ``bash quickstart``
block in the top-level ``README.md``, the ``bash obs-quickstart`` block in
``docs/OBSERVABILITY.md``, and the ``bash contracts-quickstart`` block in
``docs/CONTRACTS.md`` — and runs each command line (comments skipped) from
the repo root, failing on the first non-zero exit.  CI runs
this in both test jobs — if someone edits a quickstart into something that
no longer works, or renames a flag a quickstart uses, the build breaks
instead of the docs silently lying.

Usage::

    python tools/docs_smoke.py            # run every quickstart block
    python tools/docs_smoke.py --print    # show the extracted commands only
"""

from __future__ import annotations

import os
import subprocess
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
README = os.path.join(REPO_ROOT, "README.md")
FENCE_TAG = "bash quickstart"

# every doc-embedded block CI executes: (path, fence tag).  Add a pair when
# a new doc grows a runnable quickstart.
SOURCES: list[tuple[str, str]] = [
    (README, FENCE_TAG),
    (os.path.join(REPO_ROOT, "docs", "OBSERVABILITY.md"), "bash obs-quickstart"),
    (os.path.join(REPO_ROOT, "docs", "CONTRACTS.md"), "bash contracts-quickstart"),
]


def extract_quickstart(
    readme_path: str = README, fence_tag: str = FENCE_TAG
) -> list[str]:
    """The command lines of the ``fence_tag`` fenced block in one doc."""
    commands: list[str] = []
    in_block = False
    with open(readme_path) as f:
        for line in f:
            stripped = line.strip()
            if stripped == f"```{fence_tag}":
                in_block = True
                continue
            if in_block and stripped == "```":
                break
            if in_block and stripped and not stripped.startswith("#"):
                commands.append(stripped)
    if not commands:
        raise SystemExit(
            f"no ```{fence_tag} block with commands found in {readme_path}"
        )
    return commands


def main() -> int:
    blocks = [
        (path, tag, extract_quickstart(path, tag)) for path, tag, in SOURCES
    ]
    if "--print" in sys.argv:
        for _path, _tag, commands in blocks:
            print("\n".join(commands))
        return 0
    total = 0
    for path, tag, commands in blocks:
        rel = os.path.relpath(path, REPO_ROOT)
        for cmd in commands:
            print(f"[docs-smoke:{rel}] $ {cmd}", flush=True)
            proc = subprocess.run(cmd, shell=True, cwd=REPO_ROOT)
            if proc.returncode != 0:
                print(
                    f"[docs-smoke] FAILED (exit {proc.returncode}): {cmd}\n"
                    f"the ```{tag} block in {rel} no longer works — fix the "
                    "docs or the code",
                    file=sys.stderr,
                )
                return proc.returncode
            total += 1
    print(f"[docs-smoke] all {total} quickstart commands passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
