"""The contract rules.  One class per rule id; register with ``@rule``.

Adding a rule (see ``docs/CONTRACTS.md``):

1. subclass :class:`Rule`, set ``id`` (kebab-case) and ``description``,
2. implement ``check(project) -> List[Finding]`` — pure ``ast`` walking,
   deterministic output order,
3. decorate with ``@rule`` so the registry picks it up,
4. add fixture-snippet unit tests in ``tests/test_contracts.py`` and a row
   to the rule table in ``docs/CONTRACTS.md``.

Scopes used below:

* **deterministic packages** — ``repro.core``, ``repro.sim``,
  ``repro.obs``, ``repro.controlplane``: the numpy-only, sim-time,
  seed-deterministic layers whose outputs are golden-pinned.
* **serialization modules** — ``repro.sim.report`` / ``.scenarios`` /
  ``.reoptimize`` and everything under ``repro.obs``: code whose iteration
  order can reach ``SimReport.to_json()`` bytes.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple, Type

from . import Finding, Project, SourceFile

#: The numpy-only / sim-time / seed-deterministic packages.
DETERMINISTIC_PACKAGES: Tuple[str, ...] = (
    "repro.core",
    "repro.sim",
    "repro.obs",
    "repro.controlplane",
)

#: Import roots that must never be reachable from the deterministic packages.
FORBIDDEN_IMPORT_ROOTS: Tuple[str, ...] = ("jax", "jaxlib")

#: Wall-clock modules banned inside the deterministic packages.
WALL_CLOCK_MODULES: Tuple[str, ...] = ("time", "datetime")

#: Modules whose iteration order feeds serialized report bytes.
SERIALIZATION_MODULES: Tuple[str, ...] = (
    "repro.sim.report",
    "repro.sim.scenarios",
    "repro.sim.reoptimize",
)
SERIALIZATION_PACKAGES: Tuple[str, ...] = ("repro.obs",)

RULES: Dict[str, Type["Rule"]] = {}


def rule(cls: Type["Rule"]) -> Type["Rule"]:
    if cls.id in RULES:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    RULES[cls.id] = cls
    return cls


class Rule:
    id: str = ""
    description: str = ""

    def check(self, project: Project) -> List[Finding]:
        raise NotImplementedError

    def finding(self, sf: SourceFile, line: int, message: str) -> Finding:
        return Finding(self.id, sf.rel, line, message)


def _in_package(module: str, packages: Sequence[str]) -> bool:
    return any(module == p or module.startswith(p + ".") for p in packages)


# -- import graph (shared by import-boundary) ------------------------------------


class ImportRecord:
    """One import statement: where it is and whether it is lazy."""

    __slots__ = ("target", "line", "local")

    def __init__(self, target: str, line: int, local: bool):
        self.target = target
        self.line = line
        self.local = local


class _ImportCollector(ast.NodeVisitor):
    """Collect every import in a module, tagging function-local (lazy) ones.

    Class bodies execute at import time, so only function bodies count as
    lazy scopes."""

    def __init__(self, package: str):
        self.package = package  # dotted package context for relative imports
        self.records: List[ImportRecord] = []
        self._depth = 0

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.records.append(
                ImportRecord(alias.name, node.lineno, self._depth > 0)
            )

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        base = self._resolve_base(node)
        if base is None:
            return
        local = self._depth > 0
        self.records.append(ImportRecord(base, node.lineno, local))
        for alias in node.names:
            if alias.name != "*":
                # ``from pkg import sub`` may bind a submodule: record the
                # candidate; resolution keeps it only if it is a real module
                self.records.append(
                    ImportRecord(f"{base}.{alias.name}", node.lineno, local)
                )

    def _resolve_base(self, node: ast.ImportFrom) -> Optional[str]:
        if node.level == 0:
            return node.module
        parts = self.package.split(".") if self.package else []
        if node.level - 1 > len(parts):
            return None  # beyond the root — unresolvable, skip
        parts = parts[: len(parts) - (node.level - 1)]
        if node.module:
            parts.append(node.module)
        return ".".join(parts) if parts else None


def collect_imports(sf: SourceFile) -> List[ImportRecord]:
    package = sf.module if sf.is_package_init else sf.module.rpartition(".")[0]
    c = _ImportCollector(package)
    c.visit(sf.tree)
    return c.records


@rule
class ImportBoundaryRule(Rule):
    """The deterministic packages must never reach jax — transitively.

    Builds the full import graph over the scanned tree (including
    function-local lazy imports, which the runtime jax-free pin cannot
    see), then walks the closure of every module in a deterministic
    package.  Edges are followed through *all* imports for modules inside
    the deterministic packages (a lazy ``import jax`` there is still a
    contract breach — it would fire on some code path), but only through
    *module-level* imports for modules outside them: a function-local
    import in an outside module (e.g. the PEP-562 ``__getattr__`` engine
    export in ``repro/serving/__init__.py``) is exactly the sanctioned
    lazy boundary, and it never executes during a deterministic-package
    import.

    The finding anchors at the import statement that directly pulls in the
    forbidden root, with one example chain from a deterministic module."""

    id = "import-boundary"
    description = (
        "repro.core/sim/obs/controlplane must never transitively import jax"
    )

    def check(self, project: Project) -> List[Finding]:
        imports: Dict[str, List[ImportRecord]] = {
            sf.module: collect_imports(sf) for sf in project.files
        }

        def resolve(target: str) -> Tuple[List[str], Optional[str]]:
            """(internal modules this import executes, forbidden root or None)."""
            root = target.split(".")[0]
            if root in FORBIDDEN_IMPORT_ROOTS:
                return [], root
            internal: List[str] = []
            # importing a.b.c executes a, a.b, and a.b.c (package __init__s)
            parts = target.split(".")
            for i in range(1, len(parts) + 1):
                prefix = ".".join(parts[:i])
                if prefix in project.modules:
                    internal.append(prefix)
            return internal, None

        def edges(module: str) -> List[ImportRecord]:
            recs = imports.get(module, [])
            if _in_package(module, DETERMINISTIC_PACKAGES):
                return recs  # lazy imports inside the contract scope count
            return [r for r in recs if not r.local]

        findings: List[Finding] = []
        seen_sites: Set[Tuple[str, int]] = set()
        roots = sorted(
            m
            for m in project.modules
            if _in_package(m, DETERMINISTIC_PACKAGES)
        )
        for start in roots:
            # BFS with parent pointers for the example chain
            parent: Dict[str, Tuple[Optional[str], int]] = {start: (None, 0)}
            queue = [start]
            while queue:
                mod = queue.pop(0)
                for rec in edges(mod):
                    internal, forbidden = resolve(rec.target)
                    if forbidden is not None:
                        site = (mod, rec.line)
                        if site in seen_sites:
                            continue
                        seen_sites.add(site)
                        chain = self._chain(parent, mod) + [forbidden]
                        sf = project.modules[mod]
                        findings.append(
                            self.finding(
                                sf,
                                rec.line,
                                f"import of {rec.target!r} puts {forbidden!r}"
                                " in the import closure of deterministic "
                                f"module {start!r} "
                                f"({' -> '.join(chain)})",
                            )
                        )
                        continue
                    for nxt in internal:
                        if nxt not in parent:
                            parent[nxt] = (mod, rec.line)
                            queue.append(nxt)
        findings.sort(key=lambda f: (f.file, f.line, f.message))
        return findings

    @staticmethod
    def _chain(parent: Dict[str, Tuple[Optional[str], int]], mod: str) -> List[str]:
        chain = [mod]
        while parent[chain[-1]][0] is not None:
            chain.append(parent[chain[-1]][0])  # type: ignore[arg-type]
        return list(reversed(chain))


@rule
class WallClockRule(Rule):
    """No ``time``/``datetime`` imports inside the deterministic packages.

    Everything in those layers runs on sim time; a wall-clock read is
    nondeterminism that ends up in golden-pinned bytes.  The anytime-budget
    deadline sites (greedy trim phase, GA round loop, optimizer timings)
    are the sanctioned exceptions — each carries an inline waiver saying
    why wall clock is allowed to *bound* work there but never to *steer*
    deterministic output."""

    id = "wall-clock"
    description = (
        "no time/datetime imports in sim-time packages "
        "(repro.core/sim/obs/controlplane)"
    )

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for sf in project.files:
            if not _in_package(sf.module, DETERMINISTIC_PACKAGES):
                continue
            seen: Set[Tuple[int, str]] = set()
            for rec in collect_imports(sf):
                root = rec.target.split(".")[0]
                if root in WALL_CLOCK_MODULES and (rec.line, root) not in seen:
                    seen.add((rec.line, root))
                    findings.append(
                        self.finding(
                            sf,
                            rec.line,
                            f"wall-clock module {root!r} imported inside "
                            f"sim-time package module {sf.module!r}",
                        )
                    )
        return findings


#: np.random attributes that are part of the seeded-Generator API.
_SAFE_NP_RANDOM = {
    "default_rng",
    "Generator",
    "SeedSequence",
    "BitGenerator",
    "PCG64",
    "Philox",
    "SFC64",
    "MT19937",
}


@rule
class SeededRngRule(Rule):
    """All randomness must flow from an explicit seed.

    Flags (anywhere under the scanned tree):

    * ``np.random.default_rng()`` with no arguments — OS-entropy seeding,
      unreproducible by construction;
    * legacy module-level draws (``np.random.<dist>(...)``,
      ``np.random.seed``, ``np.random.RandomState``) — global mutable
      stream shared across call sites."""

    id = "seeded-rng"
    description = (
        "no argless default_rng() and no legacy np.random module calls"
    )

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for sf in project.files:
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                chain = _dotted(node.func)
                if chain is None:
                    continue
                hit = self._classify(chain, node)
                if hit:
                    findings.append(self.finding(sf, node.lineno, hit))
        findings.sort(key=lambda f: (f.file, f.line))
        return findings

    @staticmethod
    def _classify(chain: Tuple[str, ...], node: ast.Call) -> Optional[str]:
        argless = not node.args and not node.keywords
        # np.random.X(...) / numpy.random.X(...)
        if len(chain) == 3 and chain[0] in ("np", "numpy") and chain[1] == "random":
            attr = chain[2]
            if attr == "default_rng":
                if argless:
                    return (
                        "argless np.random.default_rng() draws OS entropy — "
                        "derive the seed from the caller's config"
                    )
                return None
            if attr not in _SAFE_NP_RANDOM:
                return (
                    f"legacy np.random.{attr}() uses the global stream — "
                    "thread a seeded np.random.Generator instead"
                )
            return None
        # bare default_rng() via `from numpy.random import default_rng`
        if chain == ("default_rng",) and argless:
            return (
                "argless default_rng() draws OS entropy — "
                "derive the seed from the caller's config"
            )
        return None


def _dotted(node: ast.expr) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None for non-name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


@rule
class NoBareAssertRule(Rule):
    """Runtime code must not rely on ``assert`` for validation.

    ``python -O`` strips every assert, so an assert-guarded invariant
    silently vanishes in optimized runs.  Raise a typed exception with a
    message instead; trace-time shape preconditions in jit'd kernel/model
    code may carry a waiver (they fire during tracing, where -O stripping
    is an accepted trade)."""

    id = "no-bare-assert"
    description = "no assert statements in src/repro runtime code"

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for sf in project.files:
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Assert):
                    findings.append(
                        self.finding(
                            sf,
                            node.lineno,
                            "bare assert vanishes under python -O — raise "
                            "a typed exception (or waive with a reason)",
                        )
                    )
        findings.sort(key=lambda f: (f.file, f.line))
        return findings


@rule
class UnorderedIterationRule(Rule):
    """No hash-order iteration in modules that feed serialization.

    In ``repro.sim.report`` / ``.scenarios`` / ``.reoptimize`` and
    ``repro.obs``, iterating a set (literal, ``set()``/``frozenset()``
    call, set operator expression, or set-method result) without
    ``sorted()`` builds hash-order-dependent structures that can reach
    ``SimReport.to_json()`` bytes.  Python string hashing is randomized
    per process unless PYTHONHASHSEED pins it — this is drift waiting for
    an interpreter upgrade.  Membership tests are fine; only iteration is
    flagged."""

    id = "unordered-iteration"
    description = (
        "no unsorted set iteration in serialization-feeding modules "
        "(sim/report, sim/scenarios, sim/reoptimize, obs/*)"
    )

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for sf in project.files:
            if sf.module not in SERIALIZATION_MODULES and not _in_package(
                sf.module, SERIALIZATION_PACKAGES
            ):
                continue
            for node in ast.walk(sf.tree):
                iters: List[ast.expr] = []
                if isinstance(node, ast.For):
                    iters.append(node.iter)
                elif isinstance(
                    node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)
                ):
                    iters.extend(gen.iter for gen in node.generators)
                for it in iters:
                    if _set_like(it):
                        findings.append(
                            self.finding(
                                sf,
                                it.lineno,
                                "iterating a set in a serialization-feeding "
                                "module — wrap the iterable in sorted()",
                            )
                        )
        findings.sort(key=lambda f: (f.file, f.line))
        return findings


def _set_like(node: ast.expr) -> bool:
    """Syntactically-recognizable set expressions (conservative)."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node.func, ast.Attribute) and node.func.attr in (
            "union",
            "intersection",
            "difference",
            "symmetric_difference",
        ):
            # these four names are set-API-specific enough to flag even
            # when the receiver is a plain name
            return True
        return False
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)
    ):
        return _set_like(node.left) or _set_like(node.right)
    return False
