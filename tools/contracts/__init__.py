"""Contract checker: AST-based static analysis for this repo's invariants.

Every headline result in this repo — byte-identical ``SimReport.to_json()``
per seed, the golden-pinned ``BENCH_scenarios.json`` cell SHAs, the §6
transition-transparency story — rests on contracts that runtime tests can
only spot-check: the jax-free import pin sees just the modules it imports,
a grep cannot see a function-local lazy import, and goldens catch drift
only after it happened.  This package is the static side of those
contracts: a small, stdlib-only (``ast`` + ``pathlib``) analysis framework
with

* a **rule registry** (:mod:`contracts.rules` — one class per rule id),
* per-rule :class:`Finding`\\ s with ``file:line`` anchors,
* an **inline waiver grammar** — ``# contract-ok: <rule-id> <reason>`` on
  the flagged line or the line directly above waives exactly that rule
  there, and the reason is mandatory (a reason-free waiver is itself a
  violation), and
* a committed **baseline** (``tools/contracts/baseline.json``) so adoption
  is incremental: pre-existing debt is named, new debt fails the build.

``tools/check_contracts.py`` is the CLI; ``docs/CONTRACTS.md`` documents
every rule id and the workflow.  The framework deliberately has no
third-party dependencies so CI can run it before anything is installed.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: ``# contract-ok: <rule-id> <reason>`` — the reason is mandatory.
WAIVER_RE = re.compile(
    r"#\s*contract-ok:\s*(?P<rule>[A-Za-z0-9_-]+)(?:\s+(?P<reason>\S.*?))?\s*$"
)

#: Rule id reserved for malformed waiver comments (it cannot be waived).
WAIVER_SYNTAX_RULE = "waiver-syntax"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file and line."""

    rule: str
    file: str  # path relative to the scanned root's parent (e.g. src/...)
    line: int
    message: str

    @property
    def anchor(self) -> str:
        return f"{self.file}:{self.line}"

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"

    def key(self) -> Tuple[str, str, int]:
        return (self.rule, self.file, self.line)


@dataclasses.dataclass
class SourceFile:
    """One parsed source file: text, AST, and its dotted module name."""

    path: Path  # absolute
    rel: str  # posix path relative to the scanned root's parent
    module: str  # dotted name relative to the root (e.g. repro.core.ga)
    text: str
    tree: ast.Module

    @property
    def is_package_init(self) -> bool:
        return self.path.name == "__init__.py"


class Project:
    """Every parsed ``*.py`` under one root directory (e.g. ``src/``)."""

    def __init__(self, root: Path, files: Sequence[SourceFile]):
        self.root = root
        self.files: List[SourceFile] = sorted(files, key=lambda f: f.rel)
        self.modules: Dict[str, SourceFile] = {f.module: f for f in self.files}

    def file_of(self, module: str) -> Optional[SourceFile]:
        return self.modules.get(module)


def _module_name(py: Path, root: Path) -> str:
    parts = list(py.relative_to(root).with_suffix("").parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def load_project(root: Path) -> Project:
    """Parse every ``*.py`` under ``root`` (sorted, deterministic).

    A file that fails to parse is a hard error — the checker cannot vouch
    for code it cannot read.
    """
    root = root.resolve()
    base = root.parent
    files: List[SourceFile] = []
    for py in sorted(root.rglob("*.py")):
        if "__pycache__" in py.parts:
            continue
        text = py.read_text()
        try:
            tree = ast.parse(text, filename=str(py))
        except SyntaxError as exc:
            raise SyntaxError(f"{py}: {exc}") from exc
        files.append(
            SourceFile(
                path=py,
                rel=py.relative_to(base).as_posix(),
                module=_module_name(py, root),
                text=text,
                tree=tree,
            )
        )
    return Project(root, files)


# -- waivers ---------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Waiver:
    rule: str
    reason: str
    file: str
    line: int  # the line carrying the comment

    def covers(self, finding: Finding) -> bool:
        """A waiver covers its own line and the line directly below it
        (standalone comment-above style)."""
        return (
            finding.rule == self.rule
            and finding.file == self.file
            and finding.line in (self.line, self.line + 1)
        )


def parse_waivers(sf: SourceFile) -> Tuple[List[Waiver], List[Finding]]:
    """All waivers in one file, plus findings for malformed ones (a
    ``contract-ok`` with no reason is debt pretending to be a decision)."""
    waivers: List[Waiver] = []
    malformed: List[Finding] = []
    for i, line in enumerate(sf.text.splitlines(), start=1):
        if "contract-ok" not in line:
            continue
        m = WAIVER_RE.search(line)
        if m is None:
            malformed.append(
                Finding(
                    WAIVER_SYNTAX_RULE,
                    sf.rel,
                    i,
                    "unparsable contract-ok comment — expected "
                    "'# contract-ok: <rule-id> <reason>'",
                )
            )
            continue
        if not m.group("reason"):
            malformed.append(
                Finding(
                    WAIVER_SYNTAX_RULE,
                    sf.rel,
                    i,
                    f"waiver for {m.group('rule')!r} carries no reason — "
                    "every waiver must say why",
                )
            )
            continue
        waivers.append(Waiver(m.group("rule"), m.group("reason"), sf.rel, i))
    return waivers, malformed


# -- baseline --------------------------------------------------------------------


def load_baseline(path: Optional[Path]) -> List[Dict]:
    """Baseline entries (``[]`` when the file does not exist).  Each entry:
    ``{"rule": ..., "file": ..., "line": ..., "note": ...}``."""
    if path is None or not path.exists():
        return []
    doc = json.loads(path.read_text())
    entries = doc.get("entries", [])
    for e in entries:
        for field in ("rule", "file", "line"):
            if field not in e:
                raise ValueError(f"baseline entry missing {field!r}: {e}")
    return entries


def save_baseline(path: Path, findings: Sequence[Finding]) -> None:
    entries = [
        {
            "rule": f.rule,
            "file": f.file,
            "line": f.line,
            "note": f.message,
        }
        for f in sorted(findings, key=Finding.key)
    ]
    path.write_text(
        json.dumps({"comment": BASELINE_COMMENT, "entries": entries}, indent=2)
        + "\n"
    )


BASELINE_COMMENT = (
    "Adopted pre-existing contract debt. Entries match on (rule, file, line); "
    "shrink this list by fixing or waiving sites, never grow it silently "
    "(regen: python tools/check_contracts.py --update-baseline)."
)


# -- the check pipeline ----------------------------------------------------------


@dataclasses.dataclass
class CheckResult:
    """The outcome of one full run: what fails the build and what does not."""

    active: List[Finding]  # unwaived, unbaselined — these fail the build
    waived: List[Tuple[Finding, Waiver]]
    baselined: List[Finding]
    stale_baseline: List[Dict]  # entries no longer matching any finding
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.active


def run_checks(
    root: Path,
    baseline_path: Optional[Path] = None,
    rule_ids: Optional[Sequence[str]] = None,
) -> CheckResult:
    """Load the tree, run the (selected) rules, then subtract waivers and
    baseline entries.  Deterministic: findings sorted by (file, line, rule)."""
    from .rules import RULES  # local: avoids a cycle at package import

    project = load_project(root)
    ids = list(rule_ids) if rule_ids else sorted(RULES)
    unknown = [i for i in ids if i not in RULES]
    if unknown:
        raise ValueError(
            f"unknown rule id(s) {unknown}; known: {sorted(RULES)}"
        )

    findings: List[Finding] = []
    for rid in ids:
        findings.extend(RULES[rid]().check(project))

    waivers: List[Waiver] = []
    for sf in project.files:
        ws, malformed = parse_waivers(sf)
        waivers.extend(ws)
        findings.extend(malformed)  # malformed waivers are violations
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))

    kept: List[Finding] = []
    waived: List[Tuple[Finding, Waiver]] = []
    for f in findings:
        w = next((w for w in waivers if w.covers(f)), None)
        if w is not None and f.rule != WAIVER_SYNTAX_RULE:
            waived.append((f, w))
        else:
            kept.append(f)

    entries = load_baseline(baseline_path)
    keys = {(e["rule"], e["file"], int(e["line"])) for e in entries}
    active = [f for f in kept if f.key() not in keys]
    baselined = [f for f in kept if f.key() in keys]
    matched = {f.key() for f in baselined}
    stale = [
        e for e in entries if (e["rule"], e["file"], int(e["line"])) not in matched
    ]
    return CheckResult(
        active=active,
        waived=waived,
        baselined=baselined,
        stale_baseline=stale,
        n_files=len(project.files),
    )
