"""TPU v5e hardware constants (the roofline denominators)."""

PEAK_FLOPS_BF16 = 197e12  # per chip, FLOP/s
HBM_BW = 819e9  # per chip, bytes/s
HBM_BYTES = 16e9  # per chip
ICI_BW_PER_LINK = 50e9  # bytes/s per link
ICI_LINKS = 4  # torus links per chip (2D mesh)
