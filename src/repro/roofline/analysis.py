"""Roofline terms from a compiled dry-run artifact.

  compute_term    = HLO_FLOPs   / (chips · peak_FLOP/s)
  memory_term     = HLO_bytes   / (chips · HBM_bw)
  collective_term = coll_bytes  / (chips · ICI_link_bw)

``compiled.cost_analysis()`` supplies FLOPs and bytes accessed.
Collective bytes are **not** in cost_analysis: :func:`collective_bytes`
parses the post-SPMD HLO text and sums the output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
The partitioned module is per-device, so parsed sizes are per-device; the
spec's global formula multiplies back by chip count (the two cancel).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like ``bf16[128,4096]{1,0}`` (sums all
    array shapes found, so tuple shapes work too)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COLL_RE = re.compile(
    r"%?[\w.\-]+ = (.+?) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start)?\("
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(r"\bwhile\(.*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALL_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes from post-SPMD HLO text.

    Computation-graph aware: collectives inside a ``while`` body are
    multiplied by the loop's ``known_trip_count`` (scan-over-layers would
    otherwise be undercounted by the layer count); ``conditional`` branches
    contribute their max; fusion/reducer calls are traversed once.
    """
    # -- split into computations -------------------------------------------------
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in hlo_text.splitlines():
        s = raw.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)

    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {k: 0.0 for k in _COLLECTIVES}
        total = {k: 0.0 for k in _COLLECTIVES}
        for line in comps[name]:
            m = _COLL_RE.match(line)
            if m:
                total[m.group(2)] += _shape_bytes(m.group(1))
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                sub = walk(wm.group(1), stack + (name,))
                for k in total:
                    total[k] += trips * sub[k]
                continue
            cm = _COND_RE.search(line)
            if cm:
                branches = [b.strip().lstrip("%") for b in cm.group(1).split(",")]
                subs = [walk(b, stack + (name,)) for b in branches if b]
                if subs:
                    for k in total:
                        total[k] += max(s[k] for s in subs)
                continue
            am = _CALL_RE.search(line)
            if am and "while" not in line:
                sub = walk(am.group(1), stack + (name,))
                for k in total:
                    total[k] += sub[k]
        memo[name] = total
        return total

    root = entry or (next(iter(comps)) if comps else None)
    if root is None:
        return {k: 0 for k in _COLLECTIVES}
    return {k: int(v) for k, v in walk(root).items()}


_DOT_RE = re.compile(r"dot\(")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?)\s+(\w[\w\-]*)")
_OPERANDS_RE = re.compile(r"\w[\w\-]*\(([^)]*)\)")


def _split_operands(s: str) -> List[str]:
    """Split an HLO operand list at top level (shapes contain commas:
    ``f32[4,8,16]{2,1,0} %Arg_0.1, f32[4,16,32]{2,1,0} %Arg_1.2``)."""
    out: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def _operand_shape(entry: str, table: Dict[str, str]) -> str:
    """Shape of one operand entry: newer jax prints it inline
    (``f32[4,8]{1,0} %x``); older emits the bare name, resolved through the
    computation's symbol table."""
    head = entry.split("%")[0]
    if _SHAPE_RE.search(head):
        return head.strip()
    name = entry.strip().lstrip("%")
    return table.get(name, "")


def _parse_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def hlo_cost(hlo_text: str) -> Dict[str, float]:
    """Trip-count-aware FLOPs and bytes from post-SPMD HLO text.

    ``compiled.cost_analysis()`` visits a ``while`` body once, so
    scan-over-layers models are undercounted by the layer count; this parser
    walks the computation graph (while bodies × known_trip_count,
    conditional branches by max, fusion/reducer calls once per call site).

    FLOPs: 2·|out|·K for every ``dot`` (K = contracted extent from the lhs
    operand's definition); element-wise ops are not counted (they are <1% of
    matmul FLOPs at these sizes).  Bytes: per op, output bytes + operand
    bytes (operand shapes resolved through the per-computation symbol
    table) — the same operands+outputs convention cost_analysis uses, i.e.
    an upper bound on unique HBM traffic.
    """
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in hlo_text.splitlines():
        s = raw.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)

    # symbol tables: op name -> full shape string (per computation)
    tables: Dict[str, Dict[str, str]] = {}
    for name, lines in comps.items():
        t: Dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                t[dm.group(1)] = dm.group(2)
        tables[name] = t

    memo: Dict[str, Tuple[float, float]] = {}

    # bookkeeping opcodes: no real data movement of their own (tuples alias;
    # while/conditional/fusion bodies are walked separately; parameters are
    # read by their consumers)
    SKIP = {
        "tuple", "get-tuple-element", "parameter", "while", "conditional",
        "call", "fusion", "constant", "iota", "after-all", "bitcast",
        "bitcast-convert", "get-dimension-size",
        # convert is a CPU-lowering artifact (XLA CPU upcasts bf16 dots to
        # f32); on the TPU target the MXU consumes bf16 natively
        "convert",
    }

    def op_bytes(line: str, table: Dict[str, str]) -> float:
        """Output bytes of every compute op, plus operand bytes for dots
        (weight/cache streaming dominates and would otherwise be missed).
        dynamic-update-slice counts its *update* operand, not the aliased
        full buffer — inside a loop the buffer is updated in place and the
        full-shape output would otherwise be multiplied by the trip count."""
        dm = _DEF_RE.match(line)
        if not dm:
            return 0.0
        opcode = dm.group(3)
        if opcode in SKIP:
            return 0.0
        if opcode == "dynamic-update-slice":
            om = _OPERANDS_RE.search(line)
            if om:
                ops = _split_operands(om.group(1))
                if len(ops) >= 2:
                    shape = _operand_shape(ops[1], table)
                    if shape and not shape.startswith("("):
                        return 2.0 * _shape_bytes(shape)  # read+write of slice
            return 0.0
        total = float(_shape_bytes(dm.group(2)))
        if opcode == "dot":
            om = _OPERANDS_RE.search(line)
            if om:
                for operand in _split_operands(om.group(1)):
                    shape = _operand_shape(operand, table)
                    if shape and not shape.startswith("("):
                        total += _shape_bytes(shape)
        return total

    def dot_flops(line: str, table: Dict[str, str]) -> float:
        dm = _DEF_RE.match(line)
        om = _OPERANDS_RE.search(line)
        cm = _LHS_CONTRACT_RE.search(line)
        if not (dm and om and cm):
            return 0.0
        _, out_dims = _parse_dims(dm.group(2))
        operands = _split_operands(om.group(1))
        lhs_shape = _operand_shape(operands[0], table) if operands else ""
        _, lhs_dims = _parse_dims(lhs_shape)
        if not lhs_dims:
            return 0.0
        k = 1
        if cm.group(1):
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
        out_n = 1
        for d in out_dims:
            out_n *= d
        return 2.0 * out_n * k

    def walk(name: str, stack=()) -> Tuple[float, float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0)
        table = tables[name]
        flops = 0.0
        nbytes = 0.0
        for line in comps[name]:
            if _DOT_RE.search(line):
                flops += dot_flops(line, table)
            nbytes += op_bytes(line, table)
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                f, b = walk(wm.group(1), stack + (name,))
                flops += trips * f
                nbytes += trips * b
                continue
            cm2 = _COND_RE.search(line)
            if cm2:
                branches = [b.strip().lstrip("%") for b in cm2.group(1).split(",")]
                subs = [walk(b, stack + (name,)) for b in branches if b]
                if subs:
                    flops += max(s[0] for s in subs)
                    nbytes += max(s[1] for s in subs)
                continue
            am = _CALL_RE.search(line) or re.search(r"calls=%?([\w.\-]+)", line)
            if am and "while" not in line:
                f, b = walk(am.group(1), stack + (name,))
                flops += f
                nbytes += b
        memo[name] = (flops, nbytes)
        return memo[name]

    root = entry or (next(iter(comps)) if comps else None)
    if root is None:
        return {"flops": 0.0, "bytes": 0.0}
    f, b = walk(root)
    return {"flops": f, "bytes": b}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: Dict[str, int]
    model_flops: float  # 6·N·D (dense) / 6·N_active·D (MoE) for the step
    peak_memory_per_device: Optional[float] = None
    output_bytes_per_device: Optional[float] = None

    # -- the three terms (seconds) ------------------------------------------------
    @property
    def compute_s(self) -> float:
        return self.flops_per_device / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.collective_bytes_per_device.values()) / hw.ICI_BW_PER_LINK

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs — catches remat/redundancy."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops,
            "peak_memory_per_device": self.peak_memory_per_device,
            "output_bytes_per_device": self.output_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_step_flops(cfg, shape) -> float:
    """MODEL_FLOPS for one step: 6·N·D for training, 2·N·D for inference
    (prefill), 2·N_active·B for one decode token — N_active for MoE."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # one decode token


def load_report(path: str) -> RooflineReport:
    with open(path) as f:
        d = json.load(f)
    return RooflineReport(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], chips=d["chips"],
        flops_per_device=d["flops_per_device"],
        bytes_per_device=d["bytes_per_device"],
        collective_bytes_per_device=d["collective_bytes_per_device"],
        model_flops=d["model_flops"],
        peak_memory_per_device=d.get("peak_memory_per_device"),
        output_bytes_per_device=d.get("output_bytes_per_device"),
    )
