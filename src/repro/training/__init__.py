"""Training substrate: AdamW, synthetic data, train step, checkpoints."""

from repro.training import adamw, checkpoint, data
from repro.training.train_loop import cross_entropy, make_loss_fn, make_train_step

__all__ = [
    "adamw", "checkpoint", "cross_entropy", "data",
    "make_loss_fn", "make_train_step",
]
