"""AdamW with global-norm gradient clipping, in plain JAX pytrees."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def update(
    cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm
