"""Training step: loss, grads, AdamW update — all shardable under pjit.

Loss = causal cross-entropy (+ MoE load-balance aux, + the DeepSeek-V3 MTP
head when configured).  ``make_train_step`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings from :mod:`repro.launch.shardings`.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.models.common import rmsnorm
from repro.models.config import ModelConfig
from repro.training import adamw


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def _mtp_loss(model: Model, params: Any, h: jax.Array, batch: Dict) -> jax.Array:
    """DeepSeek-V3 multi-token prediction, depth-1: predict label_{t+1}
    (the token two ahead) from [h_t ; embed(label_t)] through the MTP
    projection and the shared output head."""
    cfg = model.cfg
    labels = batch["labels"]
    emb_next = jnp.take(params["embed"], labels, axis=0)  # label_t = token t+1
    feat = jnp.concatenate([h[:, :-1], emb_next[:, :-1]], axis=-1)
    h_mtp = rmsnorm(feat @ params["mtp"]["proj"], params["mtp"]["norm"], cfg.norm_eps)
    logits = model.logits(params, h_mtp)
    return cross_entropy(logits, labels[:, 1:])


def make_loss_fn(model: Model):
    cfg = model.cfg

    def loss_fn(params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        h, aux = model.hidden(
            params,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
        )
        logits = model.logits(params, h)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + cfg.router_aux_weight * aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp:
            mtp = _mtp_loss(model, params, h, batch)
            loss = loss + 0.3 * mtp
            metrics["mtp"] = mtp
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig):
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, gnorm = adamw.update(opt_cfg, grads, opt_state, params)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step
