"""Minimal sharded checkpointing: params/opt-state pytrees → .npz files.

Flattens the pytree with '/'-joined key paths; restores exactly.  Good enough
for the example drivers and deterministic tests (no orbax in this container).
"""

from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz cannot round-trip ml_dtypes
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, **_flatten(tree))


def restore(path: str, like: Any) -> Any:
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (path_keys, leaf) in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return treedef.unflatten(out)
