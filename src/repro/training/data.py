"""Synthetic deterministic data pipeline.

Serving/training of the paper's kind needs a stable token source, not a real
corpus: batches are produced by a counter-seeded PRNG so every step is
reproducible and shardable (each host could slice by ``process_index``
without coordination).  For stub-modality architectures the pipeline emits
frontend embeddings instead of tokens (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


def synthetic_batch(cfg: ModelConfig, data: DataConfig, step: int) -> Dict[str, jax.Array]:
    """One (tokens|embeds, labels) batch; pure function of (seed, step)."""
    rng = np.random.default_rng(data.seed * 1_000_003 + step)
    # learnable sequences: an affine next-token rule x_{t+1} = (a·x_t + c) mod V
    # with random starts — the loss measurably decreases within a few steps,
    # which the tests assert.
    V = cfg.vocab_size
    a, c = 31, 17
    start = rng.integers(0, V, size=(data.batch, 1), dtype=np.int64)
    seq = np.zeros((data.batch, data.seq_len + 1), np.int64)
    seq[:, 0:1] = start
    for t in range(data.seq_len):
        seq[:, t + 1] = (a * seq[:, t] + c) % V
    tokens = seq[:, :-1].astype(np.int32)
    labels = seq[:, 1:].astype(np.int32)
    out: Dict[str, jax.Array] = {"labels": jnp.asarray(labels)}
    if cfg.modality == "text":
        out["tokens"] = jnp.asarray(tokens)
    else:
        # stub frontend: embeddings are a fixed (seeded) table lookup of the
        # underlying tokens so the mapping stays learnable
        trng = np.random.default_rng(data.seed + 7)
        tab = trng.standard_normal(size=(min(V, 1024), cfg.d_model)).astype(np.float32)
        emb = tab[tokens % tab.shape[0]]
        out["embeds"] = jnp.asarray(emb, jnp.bfloat16)
    return out


def batches(cfg: ModelConfig, data: DataConfig, steps: int) -> Iterator[Dict[str, jax.Array]]:
    for step in range(steps):
        yield synthetic_batch(cfg, data, step)
