"""MIG-Serving reproduction: the Reconfigurable Machine Scheduling Problem.

Subpackages:

  * :mod:`repro.core`    — rule-sets, profiles, optimizer pipeline, controller
  * :mod:`repro.serving` — per-instance engines and the service-level router
  * :mod:`repro.sim`     — closed-loop trace-driven cluster serving simulator
  * :mod:`repro.controlplane` — declarative reconciler, fault injection,
    degraded-mode admission control (the §6-§7 control plane)
  * :mod:`repro.models`, :mod:`repro.kernels`, :mod:`repro.launch`, ... —
    the jax/pallas serving stack

The simulator subsystem is re-exported here lazily (PEP 562, same pattern
as :mod:`repro.serving`), so ``import repro`` — and every
``import repro.<subpackage>`` that runs through it — stays free of any
import cost beyond the bare package.
"""

__all__ = [
    "ClusterSimulator", "ReoptimizeDriver", "SimConfig", "SimReport",
    "Trace", "diurnal_trace", "flash_crowd_trace", "poisson_burst_trace",
    "replay_trace",
]


def __getattr__(name):
    if name in __all__:
        from repro import sim

        return getattr(sim, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
