"""Level-triggered reconciliation: drive observed state to desired state.

The paper's §6 controller plans one transition and assumes every action
lands.  This reconciler wraps it in the loop a production control plane
(§7: a Kubernetes controller) actually runs:

  1. **observe** the cluster and :func:`~repro.controlplane.spec.diff` it
     against the :class:`DesiredState`;
  2. **plan + execute** one exchange-and-compact transition through the
     existing §6 :class:`Controller` — per-device action DAGs, disjoint-GPU
     actions parallel, bounded by the profile's ``max_inflight`` executor
     slots;
  3. on an injected :class:`ActionFault`, **back off exponentially and
     re-plan from the new observed state** — the cluster itself is the
     partial-progress checkpoint, so completed actions are never redone and
     a crashed pass resumes instead of thrashing.  Re-planning re-runs the
     full §6 algorithm, so create-first-delete-second (and with it the
     transparency guarantee) is preserved under retry.

With no injector the loop degenerates to exactly one direct
``Controller.transition`` call and returns its report unchanged — the
``none`` fault profile is bit-for-bit identical to the pre-control-plane
path, which the tests pin byte-for-byte.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.cluster import ActionFault, SimulatedCluster, parallel_makespan
from repro.core.controller import Controller, TransitionReport

from repro.controlplane.degraded import AdmissionController
from repro.controlplane.faults import FAULT_PROFILES, FaultInjector, FaultProfile
from repro.controlplane.spec import DesiredState, ObservedState, diff


@dataclasses.dataclass
class ReconcileStats:
    """What one reconcile pass did (feeds the scenario-cell metrics)."""

    iterations: int = 0  # transition attempts (1 = clean single pass)
    retried: int = 0  # attempts that died on an injected ActionFault
    abandoned: int = 0  # diff items still outstanding when we gave up
    converged: bool = True
    backoff_s: float = 0.0  # exponential-backoff wall clock charged
    wasted_s: float = 0.0  # failed-attempt wall clock charged
    faults: List[str] = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "iterations": self.iterations,
            "retried": self.retried,
            "abandoned": self.abandoned,
            "converged": self.converged,
            "backoff_s": self.backoff_s,
            "wasted_s": self.wasted_s,
            "faults": list(self.faults),
        }


class Reconciler:
    """Reconcile a :class:`SimulatedCluster` toward a :class:`DesiredState`."""

    def __init__(
        self,
        controller: Controller,
        injector: Optional[FaultInjector] = None,
        max_iterations: Optional[int] = None,
    ):
        self.controller = controller
        self.injector = injector
        profile = injector.profile if injector is not None else None
        self.max_iterations = max_iterations or (
            profile.max_iterations if profile is not None else 2
        )
        self.max_inflight = profile.max_inflight if profile is not None else None

    def diverged(self, cluster: SimulatedCluster, desired: DesiredState) -> bool:
        """The level trigger: does observed state differ from desired?"""
        return not diff(ObservedState.observe(cluster), desired).converged

    def reconcile(
        self, cluster: SimulatedCluster, desired: DesiredState
    ) -> Tuple[TransitionReport, ReconcileStats]:
        """Run the reconcile loop; returns the merged transition report over
        every attempt plus the pass's stats.

        The report's serial/parallel seconds include straggler-stretched
        action charges, wasted failed-attempt time, and backoff waits
        (failures and backoffs are barriers between re-plans)."""
        start = len(cluster.actions_applied)
        stats = ReconcileStats()
        inner: Optional[TransitionReport] = None
        peak = cluster.gpus_in_use()
        hook = (
            self.injector.action_hook
            if self.injector is not None and self.injector.profile.injects_actions
            else None
        )
        for attempt in range(1, self.max_iterations + 1):
            stats.iterations = attempt
            n_before = len(cluster.actions_applied)
            cluster.fault_hook = hook
            try:
                inner = self.controller.transition(cluster, desired.deployment)
            except ActionFault as fault:
                stats.retried += 1
                stats.faults.append(
                    f"{fault.action.kind}@gpu{fault.action.gpu}: {fault.reason}"
                )
                stats.wasted_s += fault.wasted_s
                if self.injector is None:  # hooks only exist with one
                    raise RuntimeError(
                        "ActionFault raised without an injector — only the "
                        "fault injector's hooks may raise ActionFault"
                    )
                stats.backoff_s += self.injector.backoff_s(attempt)
                peak = max(peak, cluster.gpus_in_use())
                inner = None
                continue
            finally:
                cluster.fault_hook = None
            peak = max(peak, inner.peak_gpus_busy)
            d = diff(ObservedState.observe(cluster), desired)
            if d.converged:
                break
            if len(cluster.actions_applied) == n_before:
                # zero actions applied and still diverged: another identical
                # plan would thrash, not converge — give up this pass
                stats.converged = False
                stats.abandoned = (
                    sum(d.missing.values())
                    + sum(d.surplus.values())
                    + len(d.misplaced)
                )
                break
        else:
            d = diff(ObservedState.observe(cluster), desired)
            stats.converged = d.converged
            if not d.converged:
                stats.abandoned = (
                    sum(d.missing.values())
                    + sum(d.surplus.values())
                    + len(d.misplaced)
                )

        extra_s = stats.wasted_s + stats.backoff_s
        if (
            inner is not None
            and stats.iterations == 1
            and extra_s == 0.0
            and self.max_inflight is None
        ):
            # clean single pass, unbounded concurrency: the §6 report IS the
            # answer — returned unchanged so the `none` profile stays
            # bit-for-bit identical to the direct-transition path
            return inner, stats
        actions = cluster.actions_applied[start:]
        secs = cluster.applied_seconds[start:]
        report = TransitionReport(
            actions=actions,
            serial_seconds=float(sum(secs)) + extra_s,
            parallel_seconds=parallel_makespan(
                actions, seconds=secs, max_concurrent=self.max_inflight
            )
            + extra_s,
            peak_gpus_busy=peak,
            final_gpus_busy=cluster.gpus_in_use(),
        )
        return report, stats


@dataclasses.dataclass
class ControlPlane:
    """The bundle the closed-loop simulator wires in: reconciler + fault
    injector + degraded-mode admission control, under one profile."""

    reconciler: Reconciler
    profile: FaultProfile
    injector: Optional[FaultInjector] = None
    admission: Optional[AdmissionController] = None

    @property
    def fault_mode(self) -> bool:
        """Faults active?  Gates every report-schema extension, so the
        ``none`` profile's reports keep their exact pre-control-plane bytes."""
        return self.profile.name != "none"


def build_control_plane(
    controller: Controller, profile_name: str, seed: int, duration_s: float
) -> ControlPlane:
    """Wire a control plane for one run of one fault profile."""
    profile = FAULT_PROFILES[profile_name]
    injector = (
        FaultInjector(profile, seed, duration_s)
        if profile.name != "none"
        else None
    )
    return ControlPlane(
        reconciler=Reconciler(controller, injector=injector),
        profile=profile,
        injector=injector,
        admission=AdmissionController() if injector is not None else None,
    )
