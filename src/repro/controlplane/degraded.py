"""Degraded-mode serving: admission control when capacity < demand.

After a device failure the cluster may simply not have the capacity the
SLOs require until the reconciler restores it (creates pay their 62 s
Figure-13c latency).  Queueing everything during that window would let the
backlog grow without bound and then report a rosy served-fraction once
capacity returns; production systems shed instead.  The
:class:`AdmissionController` admits load up to current capacity and sheds
the excess, and the shed requests are charged honestly to the
:class:`~repro.sim.report.SimReport` — they count as arrivals that were
never served, so SLO attainment and served-fraction reflect the outage.

Shedding is proportional: every service sheds the same *fraction* of its
over-capacity excess (here applied per service, whose capacity is its own
instance pool, so "proportional" degenerates to per-service clipping).
Only active while the cluster is in an outage the control plane can see —
observed state diverged from the desired state, or a fault-triggered
repair is still paying its Figure-13c latencies — AND the service's
capacity sits below its required rate.  Ordinary traffic bursts, before
or after an outage, keep the fluid-queue backlog semantics.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass
class AdmissionController:
    """Clip per-service admitted load to capacity while degraded.

    ``min_admit_frac`` guarantees a floor (even a shedding frontend lets
    some traffic through to keep health signals alive)."""

    min_admit_frac: float = 0.0

    def admit(self, demand: float, capacity: float) -> Tuple[float, float]:
        """Split ``demand`` (requests this bin) into (admitted, shed) given
        ``capacity`` (requests the service's instances can absorb)."""
        if demand <= 0.0:
            return 0.0, 0.0
        if capacity >= demand:
            return demand, 0.0
        admitted = max(capacity, demand * self.min_admit_frac)
        return admitted, demand - admitted

    def admit_by_class(
        self,
        demands: Sequence[Tuple[int, float, float]],
        capacity: float,
    ) -> List[Tuple[float, float]]:
        """Priority-aware shedding: split each ``(priority_class, weight,
        demand)`` entry into ``(admitted, shed)`` under a shared capacity.

        Classes are served in priority order (class index 0 first): a class
        is shed only after every higher class is fully admitted, so the
        excess lands lowest-class-first.  The one *marginal* class that the
        remaining capacity only partially covers splits it across its
        entries by weighted max-min fairness (water-filling: each entry's
        share grows in proportion to its weight until its demand is met,
        surplus re-flows to the still-hungry), never by who asked loudest.
        ``min_admit_frac`` keeps its per-entry floor.  Deterministic, order
        preserving: the result aligns with the input sequence, and
        ``admitted + shed == demand`` holds exactly per entry."""
        out: List[Tuple[float, float]] = [(0.0, 0.0)] * len(demands)
        remaining = max(float(capacity), 0.0)
        for cls in sorted({c for c, _, _ in demands}):
            idx = [
                i
                for i, (c, _, d) in enumerate(demands)
                if c == cls and d > 0.0
            ]
            total = sum(demands[i][2] for i in idx)
            if total <= remaining:
                for i in idx:
                    out[i] = (demands[i][2], 0.0)
                remaining -= total
                continue
            # marginal class: weighted water-filling of what's left
            alloc = {i: 0.0 for i in idx}
            budget = remaining
            hungry = list(idx)
            while budget > 1e-12 and hungry:
                wsum = sum(max(demands[i][1], 0.0) for i in hungry)
                if wsum <= 0.0:
                    # all-zero weights degenerate to equal split
                    share = {i: budget / len(hungry) for i in hungry}
                else:
                    share = {
                        i: budget * max(demands[i][1], 0.0) / wsum
                        for i in hungry
                    }
                budget = 0.0
                nxt = []
                for i in hungry:
                    room = demands[i][2] - alloc[i]
                    take = min(share[i], room)
                    alloc[i] += take
                    budget += share[i] - take
                    if alloc[i] < demands[i][2] - 1e-12:
                        nxt.append(i)
                if len(nxt) == len(hungry) and budget <= 1e-12:
                    break
                hungry = nxt
            for i in idx:
                d = demands[i][2]
                admitted = min(
                    max(alloc[i], d * self.min_admit_frac), d
                )
                out[i] = (admitted, d - admitted)
            remaining = 0.0
        return out
