"""Degraded-mode serving: admission control when capacity < demand.

After a device failure the cluster may simply not have the capacity the
SLOs require until the reconciler restores it (creates pay their 62 s
Figure-13c latency).  Queueing everything during that window would let the
backlog grow without bound and then report a rosy served-fraction once
capacity returns; production systems shed instead.  The
:class:`AdmissionController` admits load up to current capacity and sheds
the excess, and the shed requests are charged honestly to the
:class:`~repro.sim.report.SimReport` — they count as arrivals that were
never served, so SLO attainment and served-fraction reflect the outage.

Shedding is proportional: every service sheds the same *fraction* of its
over-capacity excess (here applied per service, whose capacity is its own
instance pool, so "proportional" degenerates to per-service clipping).
Only active while the cluster is in an outage the control plane can see —
observed state diverged from the desired state, or a fault-triggered
repair is still paying its Figure-13c latencies — AND the service's
capacity sits below its required rate.  Ordinary traffic bursts, before
or after an outage, keep the fluid-queue backlog semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass
class AdmissionController:
    """Clip per-service admitted load to capacity while degraded.

    ``min_admit_frac`` guarantees a floor (even a shedding frontend lets
    some traffic through to keep health signals alive)."""

    min_admit_frac: float = 0.0

    def admit(self, demand: float, capacity: float) -> Tuple[float, float]:
        """Split ``demand`` (requests this bin) into (admitted, shed) given
        ``capacity`` (requests the service's instances can absorb)."""
        if demand <= 0.0:
            return 0.0, 0.0
        if capacity >= demand:
            return demand, 0.0
        admitted = max(capacity, demand * self.min_admit_frac)
        return admitted, demand - admitted
