"""Fault-tolerant control plane: declarative reconciler + failure injection.

The paper runs MIG-serving as a Kubernetes controller (§6-§7) that
continuously drives the cluster from observed state to the optimizer's
target state.  This package is that control plane for the simulated
cluster: declarative specs (``spec``), a level-triggered reconcile loop
through the §6 exchange-and-compact controller (``reconciler``), seeded
fault injection (``faults``), and degraded-mode admission control
(``degraded``).

Numpy-only and seed-deterministic — the ``repro.core`` / ``repro.sim``
jax-free and byte-identical-report contracts extend to this package
(pinned by ``tests/test_optimizer_vectorized.py``).
"""

from repro.controlplane.degraded import AdmissionController
from repro.controlplane.faults import (
    FAULT_PROFILES,
    DeviceFault,
    FaultInjector,
    FaultProfile,
    register_fault_profile,
)
from repro.controlplane.reconciler import (
    ControlPlane,
    Reconciler,
    ReconcileStats,
    build_control_plane,
)
from repro.controlplane.spec import (
    ClusterSpec,
    DesiredState,
    NodeSpec,
    ObservedState,
    StateDiff,
    diff,
)

__all__ = [
    "AdmissionController",
    "ClusterSpec",
    "ControlPlane",
    "DesiredState",
    "DeviceFault",
    "FAULT_PROFILES",
    "FaultInjector",
    "FaultProfile",
    "NodeSpec",
    "ObservedState",
    "Reconciler",
    "ReconcileStats",
    "StateDiff",
    "build_control_plane",
    "diff",
    "register_fault_profile",
]
