"""Seeded fault injection: device failures, drains, botched actions.

Placement-under-failure work (arXiv:2502.01909 — multi-objective MIG VM
placement across cloud fault domains; arXiv:2508.18556 — MIG instance
management for high throughput) treats failures as a first-class scheduling
input.  This module makes them a first-class *scenario axis*: a
:class:`FaultProfile` declares what can go wrong, and a
:class:`FaultInjector` draws every occurrence from a seed, so the same
``SimConfig.seed`` + the same profile yields a byte-identical run.

Two injection surfaces:

  * **device faults** — whole-GPU failures and node drains, scheduled as
    simulator events at seeded times inside a window of the trace;
  * **action faults** — hooks on :meth:`SimulatedCluster.apply`: a MIG
    repartition attempt errors with some probability (the reconciler
    retries under exponential backoff), and any action can straggle at a
    latency multiplier (charged to the transition makespan).

Register new profiles with :func:`register_fault_profile`; the scenario
matrix (``repro.sim.scenarios``) exposes the registry as its fifth axis.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import ACTION_SECONDS, Action, ActionFault


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """A declarative bundle of failure modes (all seeded, all optional)."""

    name: str
    # whole-GPU failures: how many, uniformly drawn inside the window
    # (fractions of the trace duration)
    gpu_failures: int = 0
    failure_window: Tuple[float, float] = (0.3, 0.6)
    # node drains (cordon a whole machine; instances migrate off)
    node_drains: int = 0
    drain_window: Tuple[float, float] = (0.3, 0.6)
    # serving-path faults: an instance's *process* dies mid-decode — the
    # device stays healthy (no repair transition), but every in-flight
    # request on it loses its KV cache and spills for retry.  Only the
    # token serving model can represent this; under the fluid model the
    # instance's backlog re-spills to the service level instead.
    instance_crashes: int = 0
    crash_window: Tuple[float, float] = (0.25, 0.7)
    # MIG repartition attempts error with this probability; the reconciler
    # retries under exponential backoff.  Creates carve a MIG slice — the
    # same GI/CI reconfiguration — so they get their own error knob.
    repartition_error_prob: float = 0.0
    create_error_prob: float = 0.0
    backoff_base_s: float = 5.0
    backoff_mult: float = 2.0
    # stragglers: any action runs at straggler_mult x its Fig.-13c latency
    # with probability straggler_prob
    straggler_prob: float = 0.0
    straggler_mult: float = 1.0
    # how long until the control plane notices a device fault and reacts
    detection_delay_s: float = 30.0
    # bounded executor concurrency during reconcile (None = unbounded,
    # matching the direct-transition makespan model)
    max_inflight: Optional[int] = None
    # reconcile attempts before the control plane gives up on a pass
    max_iterations: int = 8

    @property
    def injects_actions(self) -> bool:
        return (
            self.repartition_error_prob > 0.0
            or self.create_error_prob > 0.0
            or self.straggler_prob > 0.0
        )

    @property
    def injects_devices(self) -> bool:
        return (
            self.gpu_failures > 0
            or self.node_drains > 0
            or self.instance_crashes > 0
        )


FAULT_PROFILES: Dict[str, FaultProfile] = {}


def register_fault_profile(profile: FaultProfile) -> FaultProfile:
    # a real exception, not an assert: registration clashes must surface
    # even under ``python -O``, where asserts are compiled away
    if profile.name in FAULT_PROFILES:
        raise ValueError(f"fault profile {profile.name!r} already registered")
    FAULT_PROFILES[profile.name] = profile
    return profile


register_fault_profile(FaultProfile("none"))
register_fault_profile(FaultProfile("gpu_loss", gpu_failures=1))
register_fault_profile(
    FaultProfile("drain", node_drains=1, drain_window=(0.35, 0.55))
)
register_fault_profile(
    FaultProfile(
        "flaky_mig",
        repartition_error_prob=0.35,
        create_error_prob=0.08,
        max_inflight=8,
    )
)
register_fault_profile(
    FaultProfile(
        "stragglers", straggler_prob=0.3, straggler_mult=4.0, max_inflight=8
    )
)
register_fault_profile(
    FaultProfile("instance_crash", instance_crashes=2)
)
register_fault_profile(
    FaultProfile(
        "chaos",
        gpu_failures=2,
        failure_window=(0.25, 0.7),
        repartition_error_prob=0.2,
        create_error_prob=0.05,
        straggler_prob=0.15,
        straggler_mult=3.0,
        max_inflight=8,
    )
)


def _stable_u32(name: str) -> int:
    """A numpy-seedable stable hash (Python's hash() is salted per process)."""
    return zlib.crc32(name.encode("utf-8")) & 0xFFFFFFFF


@dataclasses.dataclass
class DeviceFault:
    """One scheduled device-level fault (target picked at fire time)."""

    time_s: float
    kind: str  # "gpu_failure" | "node_drain" | "instance_crash"


class FaultInjector:
    """Draws every fault occurrence from ``(seed, profile name)``.

    One injector lives for one simulation run.  Its RNG is consumed in a
    deterministic order — device-fault times at construction, then targets
    and action verdicts in event order — so same seed => same faults.
    """

    def __init__(self, profile: FaultProfile, seed: int, duration_s: float):
        self.profile = profile
        self.rng = np.random.default_rng((int(seed), _stable_u32(profile.name)))
        self.duration_s = float(duration_s)
        self.action_log: List[Dict] = []  # injected action faults/stragglers
        self._schedule = self._draw_schedule()

    def _draw_schedule(self) -> List[DeviceFault]:
        p = self.profile
        faults: List[DeviceFault] = []
        lo, hi = p.failure_window
        for _ in range(p.gpu_failures):
            t = float(self.rng.uniform(lo, hi)) * self.duration_s
            faults.append(DeviceFault(t, "gpu_failure"))
        lo, hi = p.drain_window
        for _ in range(p.node_drains):
            t = float(self.rng.uniform(lo, hi)) * self.duration_s
            faults.append(DeviceFault(t, "node_drain"))
        # crash draws come AFTER the historical ones: pre-existing profiles
        # consume the rng in the same order, so their schedules (and every
        # golden pinned on them) stay byte-identical
        lo, hi = p.crash_window
        for _ in range(p.instance_crashes):
            t = float(self.rng.uniform(lo, hi)) * self.duration_s
            faults.append(DeviceFault(t, "instance_crash"))
        faults.sort(key=lambda f: f.time_s)
        return faults

    def device_faults(self) -> List[DeviceFault]:
        """The run's scheduled device faults, ascending in time."""
        return list(self._schedule)

    # -- fire-time target selection (deterministic: sorted candidates + rng) --
    def pick_gpu(self, busy_gids: List[int]) -> Optional[int]:
        cands = sorted(busy_gids)
        if not cands:
            return None
        return cands[int(self.rng.integers(len(cands)))]

    def pick_machine(self, machines: List[int]) -> Optional[int]:
        cands = sorted(machines)
        if not cands:
            return None
        return cands[int(self.rng.integers(len(cands)))]

    def pick_instance(self, busy_uids: List[int]) -> Optional[int]:
        cands = sorted(busy_uids)
        if not cands:
            return None
        return cands[int(self.rng.integers(len(cands)))]

    # -- the SimulatedCluster.apply hook --------------------------------------
    def action_hook(self, action: Action) -> float:
        """Latency multiplier for this action; raises :class:`ActionFault`
        when the attempt is vetoed (state untouched, wall clock wasted)."""
        p = self.profile
        if (
            action.kind == "repartition"
            and p.repartition_error_prob > 0.0
            and float(self.rng.random()) < p.repartition_error_prob
        ):
            self.action_log.append(
                {"kind": "repartition_error", "gpu": action.gpu}
            )
            raise ActionFault(
                action,
                "MIG repartition error",
                wasted_s=ACTION_SECONDS["repartition"],
            )
        if (
            action.kind == "create"
            and p.create_error_prob > 0.0
            and float(self.rng.random()) < p.create_error_prob
        ):
            self.action_log.append({"kind": "create_error", "gpu": action.gpu})
            raise ActionFault(
                action,
                "MIG slice-carve error on create",
                wasted_s=ACTION_SECONDS["create"],
            )
        if p.straggler_prob > 0.0 and float(self.rng.random()) < p.straggler_prob:
            self.action_log.append(
                {
                    "kind": "straggler",
                    "action": action.kind,
                    "gpu": action.gpu,
                    "mult": p.straggler_mult,
                }
            )
            return p.straggler_mult
        return 1.0

    def backoff_s(self, attempt: int) -> float:
        """Exponential backoff before re-planning after a failed attempt
        (attempt counts from 1)."""
        p = self.profile
        return p.backoff_base_s * p.backoff_mult ** max(attempt - 1, 0)
