"""Declarative control-plane state: inventory, desired state, observed state.

The paper deploys MIG-serving as a Kubernetes controller (§6-§7) that
continuously drives the cluster from *observed* state toward the
optimizer's *target* state.  This module is that controller's vocabulary:

  * :class:`ClusterSpec` — the per-node inventory (machines, device counts,
    fault domains), the static shape failures are drawn against;
  * :class:`DesiredState` — the optimizer's target: a :class:`Deployment`
    (optionally its array-native :class:`IndexedDeployment` twin) plus the
    per-service required throughput it was sized for;
  * :class:`ObservedState` — a point-in-time snapshot of the simulated
    cluster (instances, partitions, failed/draining devices);
  * :func:`diff` — the level-trigger: what the reconciler compares each
    pass to decide whether the cluster has converged.

Everything here is numpy-only and deterministic — the ``repro.core`` /
``repro.sim`` jax-free and byte-identical-report contracts extend to the
whole ``repro.controlplane`` package.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Optional, Tuple

from repro.core.cluster import GPUS_PER_MACHINE, SimulatedCluster
from repro.core.deployment import Deployment, IndexedDeployment
from repro.core.rms import Partition


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One machine of the inventory: its devices and its fault domain."""

    machine: int
    n_gpus: int = GPUS_PER_MACHINE
    fault_domain: str = "rack0"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Per-node fault-domain inventory (the static half of the spec)."""

    nodes: Tuple[NodeSpec, ...]

    @staticmethod
    def from_cluster(
        cluster: SimulatedCluster, domain_of: Optional[Dict[int, str]] = None
    ) -> "ClusterSpec":
        """Derive the inventory from a live cluster; machines default to one
        fault domain per rack (``rack<machine>``) unless mapped explicitly."""
        machines: Dict[int, int] = {}
        for g in cluster.gpus.values():
            machines[g.machine] = machines.get(g.machine, 0) + 1
        domain_of = domain_of or {}
        return ClusterSpec(
            tuple(
                NodeSpec(m, n, domain_of.get(m, f"rack{m}"))
                for m, n in sorted(machines.items())
            )
        )

    @property
    def machines(self) -> Tuple[int, ...]:
        return tuple(n.machine for n in self.nodes)

    def fault_domain_of(self, machine: int) -> str:
        for n in self.nodes:
            if n.machine == machine:
                return n.fault_domain
        return f"rack{machine}"


@dataclasses.dataclass
class DesiredState:
    """The optimizer's target the reconciler drives the cluster toward."""

    deployment: Deployment  # config order matters to the §6 controller
    required: Dict[str, float]  # per-service SLO throughput it was sized for
    indexed: Optional[IndexedDeployment] = None  # array-native twin
    cluster_spec: Optional[ClusterSpec] = None

    def content(self) -> Counter:
        """Target instance multiset {(size, service): count}."""
        return Counter(
            (a.size, a.service)
            for cfg in self.deployment.configs
            for a in cfg.assignments
            if a.service
        )

    @property
    def num_gpus(self) -> int:
        return self.deployment.num_gpus


@dataclasses.dataclass
class ObservedState:
    """A point-in-time snapshot of the cluster (what a metrics backend and
    the k8s API would report)."""

    time_s: float
    instances: Dict[int, Tuple[str, int, float]]  # uid -> (svc, size, req/s)
    partitions: Dict[int, Partition]  # gpu id -> current partition
    instance_gpu: Dict[int, int]  # uid -> gpu id
    failed: frozenset  # gpu ids lost to whole-device failures
    draining: frozenset  # gpu ids being drained

    @staticmethod
    def observe(cluster: SimulatedCluster, now: float = 0.0) -> "ObservedState":
        instances: Dict[int, Tuple[str, int, float]] = {}
        instance_gpu: Dict[int, int] = {}
        partitions: Dict[int, Partition] = {}
        for gid, g in cluster.gpus.items():
            partitions[gid] = g.partition()
            for r in g.instances.values():
                if r.service:
                    instances[r.uid] = (r.service, r.size, r.throughput)
                    instance_gpu[r.uid] = gid
        return ObservedState(
            time_s=now,
            instances=instances,
            partitions=partitions,
            instance_gpu=instance_gpu,
            failed=frozenset(cluster.failed),
            draining=frozenset(cluster.draining),
        )

    def content(self) -> Counter:
        """Observed instance multiset {(size, service): count}."""
        return Counter((size, svc) for svc, size, _ in self.instances.values())

    def provided(self) -> Dict[str, float]:
        """Per-service aggregate throughput currently serving."""
        out: Dict[str, float] = {}
        for svc, _size, tput in self.instances.values():
            out[svc] = out.get(svc, 0.0) + tput
        return out

    def misplaced_uids(self) -> Tuple[int, ...]:
        """Instances stranded on draining devices (they serve, but the
        level-trigger must keep firing until they are migrated off)."""
        return tuple(
            sorted(
                uid for uid, gid in self.instance_gpu.items()
                if gid in self.draining
            )
        )


@dataclasses.dataclass
class StateDiff:
    """Observed-vs-desired divergence — the reconciler's level trigger."""

    missing: Counter  # (size, svc) -> count the cluster lacks
    surplus: Counter  # (size, svc) -> count beyond the target
    misplaced: Tuple[int, ...]  # uids stranded on draining devices
    shortfall: Dict[str, float]  # svc -> required - provided (when > 0)

    @property
    def converged(self) -> bool:
        return not self.missing and not self.surplus and not self.misplaced

    def summary(self) -> str:
        if self.converged:
            return "converged"
        bits = []
        if self.missing:
            bits.append(f"missing={dict(sorted(self.missing.items()))}")
        if self.surplus:
            bits.append(f"surplus={dict(sorted(self.surplus.items()))}")
        if self.misplaced:
            bits.append(f"misplaced={len(self.misplaced)}")
        return " ".join(bits)


def diff(observed: ObservedState, desired: DesiredState) -> StateDiff:
    """What separates the observed cluster from the desired state."""
    want = desired.content()
    have = observed.content()
    provided = observed.provided()
    shortfall = {
        svc: req - provided.get(svc, 0.0)
        for svc, req in sorted(desired.required.items())
        if req - provided.get(svc, 0.0) > 1e-9
    }
    return StateDiff(
        missing=want - have,
        surplus=have - want,
        misplaced=observed.misplaced_uids(),
        shortfall=shortfall,
    )
