"""Public jit'd wrappers around the Pallas kernels.

These adapt the model layout (B, S, H, D) to the kernel layouts, pick
interpret mode automatically on CPU (kernels are TPU-targeted; interpret mode
executes the kernel body in Python for validation), and expose the same
signatures :mod:`repro.models.kernels_bridge` expects.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssm_scan as _ssd


def _interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "scale", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,  # (B, S, H, D) — model layout
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    out = _fa.flash_attention_bhsd(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        scale=scale,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=_interpret(),
    )
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("scale", "block_k"))
def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    valid: jax.Array,  # (S,) or (B, S) bool — per-request ragged validity
    scale: Optional[float] = None,
    block_k: int = 512,
) -> jax.Array:
    B, _, H, D = q.shape
    S = k.shape[1]
    vmask = jnp.broadcast_to(valid.astype(jnp.int32), (B, S))
    out = _dec.decode_attention_bhd(
        q[:, 0], k, v, vmask, scale=scale, block_k=block_k, interpret=_interpret()
    )
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("scale",))
def paged_decode_attention(
    q: jax.Array,  # (B, 1, H, D) — model layout
    pool_k: jax.Array,  # (num_pages, page_size, KV, D)
    pool_v: jax.Array,
    page_tables: jax.Array,  # (B, max_pages) int32
    lengths: jax.Array,  # (B,) int32 — valid tokens per request
    scale: Optional[float] = None,
) -> jax.Array:
    from repro.kernels import paged_attention as _paged

    out = _paged.paged_decode_attention(
        q[:, 0], pool_k, pool_v, page_tables, lengths,
        scale=scale, interpret=_interpret(),
    )
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssm_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    B_: jax.Array,  # (B, S, N)
    C_: jax.Array,  # (B, S, N)
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssm_scan_bshp(x, dt, A, B_, C_, chunk=chunk, interpret=_interpret())
