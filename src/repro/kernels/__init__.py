"""Pallas TPU kernels for the serving hot-spots, with pure-jnp oracles.

The paper's contribution is the scheduling layer, not a kernel — but the
services it schedules are dominated by three compute hot-spots, implemented
here as TPU-native Pallas kernels (validated in interpret mode on CPU):

  * flash_attention — block-tiled causal prefill attention
  * decode_attention — single-token attention over a KV cache
  * ssm_scan — chunked SSD (Mamba2) scan with VMEM-carried state
  * paged_attention — paged-KV decode with a scalar-prefetched page table

``ops`` holds the jit'd public wrappers; ``ref`` the oracles.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
