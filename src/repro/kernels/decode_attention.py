"""Pallas TPU decode attention (single-token serving hot-spot).

One query token per request attends over the full KV cache.  Decode is
HBM-bandwidth-bound (the cache is streamed once), so the kernel's job is to
keep the streaming dense and the softmax state in VMEM: the kv-sequence loop
is the innermost grid dimension, carrying (m, l, acc) scratch across blocks
exactly like the prefill kernel, with all H = KV·G heads of one request
processed per program so the q tile is loaded once.

Layouts: q (B, H, D); k/v (B, S, KV, D); valid (B, S) int32 mask (ring-cache
or prefix validity decided by the caller).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, valid_ref,
    o_ref,
    m_ref, l_ref, acc_ref,
    *, scale: float, groups: int,
):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (H, D)
    k = k_ref[0].astype(jnp.float32)  # (BK, KV, D)
    v = v_ref[0].astype(jnp.float32)
    ok = valid_ref[0] != 0  # (BK,)
    H, D = q.shape
    BK, KV, _ = k.shape
    qg = q.reshape(KV, groups, D)
    # scores (KV, G, BK)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
    ) * scale
    s = jnp.where(ok[None, None, :], s, NEG_INF)

    m_prev = m_ref[...]  # (KV, G)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=2))
    p = jnp.exp(s - m_cur[:, :, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2)
    # pv: (KV, G, D)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha[:, :, None] + pv
    m_ref[...] = m_cur

    @pl.when(j == nj - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, :, None]).reshape(H, D).astype(o_ref.dtype)


def decode_attention_bhd(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    valid: jax.Array,  # (B, S) int32
    *,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    # contract-ok: no-bare-assert trace-time shape precondition inside jit
    assert S % block_k == 0, (S, block_k)
    grid = (B, S // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, groups=G)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, KV, D), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, KV, D), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
