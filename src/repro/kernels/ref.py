"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: deliberately simple (no blocking, no
online softmax, sequential SSM recurrence) so the tests' assert_allclose has
an unambiguous reference.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, S, D)
    v: jax.Array,
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jax.Array:
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    q5 = q.reshape(B, KV, G, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", q5, kf) * scale
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    valid: jax.Array,  # (S,) bool
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    q5 = q.reshape(B, KV, G, D).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", q5, k.astype(jnp.float32)) * scale
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def ssm_scan_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (already softplus'd)
    A: jax.Array,  # (H,)       (negative)
    B_: jax.Array,  # (B, S, N)
    C_: jax.Array,  # (B, S, N)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence — the unambiguous oracle.

    h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T ;  y_t = C_t · h_t
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t * A[None, :])  # (B,H)
        h = h * dA[:, :, None, None] + jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, b_t)
        y = jnp.einsum("bn,bhpn->bhp", c_t, h)
        return h, y

    init = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = (
        x.astype(jnp.float32).transpose(1, 0, 2, 3),
        dt.astype(jnp.float32).transpose(1, 0, 2),
        B_.astype(jnp.float32).transpose(1, 0, 2),
        C_.astype(jnp.float32).transpose(1, 0, 2),
    )
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final


def paged_decode_attention_ref(
    q: jax.Array,  # (B, H, D)
    pool_k: jax.Array,  # (num_pages, page_size, KV, D)
    pool_v: jax.Array,
    page_tables: jax.Array,  # (B, max_pages) int32
    lengths: jax.Array,  # (B,) int32
    scale: Optional[float] = None,
) -> jax.Array:
    """Gather each request's pages into a flat cache, then flat decode."""
    B, H, D = q.shape
    _, page_size, KV, _ = pool_k.shape
    max_pages = page_tables.shape[1]
    S = max_pages * page_size
    k = pool_k[page_tables].reshape(B, S, KV, D)
    v = pool_v[page_tables].reshape(B, S, KV, D)
    pos = jnp.arange(S)[None, :]
    out = []
    for b in range(B):  # oracle clarity over speed
        valid = pos[0] < lengths[b]
        out.append(decode_attention_ref(q[b:b+1], k[b:b+1], v[b:b+1], valid, scale))
    return jnp.concatenate(out, axis=0)
