"""Pallas TPU chunked SSD scan (Mamba2 hot-spot).

TPU adaptation of the SSD algorithm (DESIGN.md §2): the chunk loop is the
innermost grid dimension and the running inter-chunk state (H, P, N) lives in
VMEM scratch across grid steps — the TPU's sequential grid replaces the GPU
implementation's persistent-CTA carry.  Within a chunk the quadratic
C·B^T ⊙ decay matmuls map onto the MXU with (L × L) tiles.

Layouts: x (B, S, H, P); dt (B, S, H) pre-softplus'd; A (1, H) negative;
B_/C_ (B, S, N).  Returns y (B, S, H, P) and the final state (B, H, P, N).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,
    y_ref, final_ref,
    state_ref,  # scratch: (H, P, N) f32
    *, chunk: int,
):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (L, H, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L, H)
    A = a_ref[0].astype(jnp.float32)  # (H,)
    Bm = b_ref[0].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0].astype(jnp.float32)  # (L, N)
    L = x.shape[0]

    dA = dt * A[None, :]  # (L, H) negative
    dA_cs = jnp.cumsum(dA, axis=0)  # inclusive

    # intra-chunk
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    li = dA_cs[:, None, :]  # (L,1,H)
    lj = dA_cs[None, :, :]  # (1,L,H)
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))  # (L,L,H)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
        <= jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    )
    m = jnp.where(tri[:, :, None], cb[:, :, None] * decay * dt[None, :, :], 0.0)
    y_intra = jnp.einsum("ijh,jhp->ihp", m, x)

    # inter-chunk: contribution of the state entering this chunk
    entering = state_ref[...]  # (H, P, N)
    y_inter = jnp.einsum("in,hpn->ihp", Cm, entering) * jnp.exp(
        jnp.clip(dA_cs, -60.0, 0.0)
    )[:, :, None]
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    last = dA_cs[-1:, :]  # (1,H)
    seg = jnp.exp(jnp.clip(last - dA_cs, -60.0, 0.0))  # (L,H)
    new_contrib = jnp.einsum("jh,jn,jhp->hpn", seg * dt, Bm, x)
    chunk_decay = jnp.exp(jnp.clip(last[0], -60.0, 0.0))  # (H,)
    state_ref[...] = entering * chunk_decay[:, None, None] + new_contrib

    @pl.when(ci == nc - 1)
    def _emit_final():
        final_ref[0] = state_ref[...].astype(final_ref.dtype)


def ssm_scan_bshp(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    B_: jax.Array,  # (B, S, N)
    C_: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    chunk = min(chunk, S)
    # contract-ok: no-bare-assert trace-time shape precondition inside jit
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (Bb, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, H), lambda b, c: (0, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(1, H), B_, C_)
    return y, final
