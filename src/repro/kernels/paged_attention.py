"""Pallas TPU paged decode attention (production serving memory layout).

Real serving engines store KV in fixed-size *pages* from a shared pool so
requests of different lengths share HBM without per-request max-length
buffers (vLLM-style).  TPU adaptation: the page table is *scalar-prefetched*
(``pltpu.PrefetchScalarGridSpec``) so each grid step's BlockSpec index_map
can pick the right page out of the pool — the TPU analogue of a GPU kernel
chasing the page table through shared memory.

Layouts:
  pool_k / pool_v : (num_pages, page_size, KV, D)
  page_tables     : (B, max_pages) int32 — page ids per request, row-major
  lengths         : (B,) int32 — valid tokens per request
  q               : (B, H, D)

Grid: (B, max_pages) with the page loop innermost, carrying (m, l, acc)
scratch exactly like the flat decode kernel.  Pages past a request's length
contribute nothing (masked); page id 0 is a legal dummy for unused slots.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    scalars_ref,  # (B, max_pages+1) int32: [page ids..., length]
    q_ref, k_ref, v_ref,
    o_ref,
    m_ref, l_ref, acc_ref,
    *, scale: float, groups: int, page_size: int,
):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = scalars_ref[b, -1]
    page_start = j * page_size
    live = page_start < length

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (H, D)
        k = k_ref[0].astype(jnp.float32)  # (page_size, KV, D)
        v = v_ref[0].astype(jnp.float32)
        H, D = q.shape
        P, KV, _ = k.shape
        qg = q.reshape(KV, groups, D)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
        ) * scale  # (KV, G, P)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, (P,), 0)
        ok = pos < length
        s = jnp.where(ok[None, None, :], s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.exp(s - m_cur[:, :, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha[:, :, None] + pv
        m_ref[...] = m_cur

    @pl.when(j == nj - 1)
    def _finalize():
        H, D = q_ref.shape[1], q_ref.shape[2]
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, :, None]).reshape(H, D).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,  # (B, H, D)
    pool_k: jax.Array,  # (num_pages, page_size, KV, D)
    pool_v: jax.Array,
    page_tables: jax.Array,  # (B, max_pages) int32
    lengths: jax.Array,  # (B,) int32
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    num_pages, page_size, KV, _ = pool_k.shape
    max_pages = page_tables.shape[1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    scalars = jnp.concatenate(
        [page_tables.astype(jnp.int32), lengths.astype(jnp.int32)[:, None]], axis=1
    )  # (B, max_pages+1)

    def q_map(b, j, scalars):
        return (b, 0, 0)

    def kv_map(b, j, scalars):
        return (scalars[b, j], 0, 0, 0)

    kernel = functools.partial(
        _paged_kernel, scale=scale, groups=G, page_size=page_size
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), q_map),
            pl.BlockSpec((1, page_size, KV, D), kv_map),
            pl.BlockSpec((1, page_size, KV, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, H, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(scalars, q, pool_k, pool_v)
