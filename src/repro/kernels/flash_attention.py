"""Pallas TPU flash attention (prefill hot-spot).

Block-tiled causal attention with online softmax.  TPU adaptation notes
(DESIGN.md §2): the kv-block loop lives in the *grid* (TPU grid steps execute
sequentially, so the running (m, l, acc) state is carried in VMEM scratch),
block shapes are MXU-aligned (q/kv tiles of 128 × head_dim 128), and
causally-dead kv blocks are skipped with ``pl.when`` rather than thread-level
predication — there is no warp-level masking on a systolic array.

Layouts: q (B, H, S, D); k/v (B, KV, S, D); GQA via ``h // group`` in the kv
index maps.  Supports an optional sliding window.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,
    m_ref, l_ref, acc_ref,  # scratch
    *, scale: float, block_q: int, block_k: int, window: Optional[int],
    kv_len: int,
):
    i = pl.program_id(2)  # query block
    j = pl.program_id(3)  # kv block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k
    # causal pruning: kv block strictly after the last query of this tile
    live = k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_k - 1 >= q_start - window + 1 - (block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = kj <= qi
        if window is not None:
            ok &= kj > qi - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(j == nj - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, S, D)
    v: jax.Array,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    # contract-ok: no-bare-assert trace-time shape precondition inside jit
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (B, H, S // block_q, S // block_k)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, block_q=block_q, block_k=block_k, window=window, kv_len=S,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
