"""JAX model zoo for the assigned architectures."""

from repro.models.config import ModelConfig
from repro.models.transformer import Model

__all__ = ["Model", "ModelConfig"]
