"""JAX model zoo for the assigned architectures.

:class:`ModelConfig` is a plain dataclass schema (stdlib only) and is
exported eagerly — the config registry (:mod:`repro.configs`) and the
scheduler-side arch bridge (:mod:`repro.core.arch_bridge`) consume it
without needing jax.  :class:`Model` pulls in the whole jax stack, so it is
exported lazily (PEP 562, same pattern as :mod:`repro.serving`): the
import-boundary contract (``tools/check_contracts.py``) holds because
``import repro.models`` alone no longer reaches jax.
"""

from repro.models.config import ModelConfig

__all__ = ["Model", "ModelConfig"]


def __getattr__(name):
    if name == "Model":
        from repro.models.transformer import Model

        return Model
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
