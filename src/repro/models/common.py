"""Shared model components: norms, RoPE, initializers, the ParamFactory.

All models are functional: parameters are pytrees of ``jnp`` arrays created by
a :class:`ParamFactory`, which records a matching pytree of
``PartitionSpec``s as it goes — so every architecture automatically ships
its sharding plan (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


class ParamFactory:
    """Creates parameters and records their PartitionSpecs in one pass.

    ``stack_depth`` > 0 prepends a layer axis of that length (for
    scan-over-layers parameter stacks) and a leading ``None`` spec dim.
    """

    def __init__(
        self,
        key: Optional[jax.Array],
        dtype: Any,
        stack_depth: int = 0,
        abstract: bool = False,
    ):
        self._key = key
        self.dtype = dtype
        self.stack_depth = stack_depth
        self.abstract = abstract  # emit ShapeDtypeStructs (dry-run lowering)
        self.params: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def subfactory(self, name: str, stack_depth: Optional[int] = None) -> "ParamFactory":
        f = ParamFactory(
            None if self.abstract else self._next_key(),
            self.dtype,
            self.stack_depth if stack_depth is None else stack_depth,
            abstract=self.abstract,
        )
        self.params[name] = f.params
        self.specs[name] = f.specs
        return f

    def add(
        self,
        name: str,
        shape: Sequence[int],
        spec: Sequence[Optional[str]],
        init: str = "normal",
        scale: Optional[float] = None,
    ) -> None:
        shape = tuple(shape)
        assert len(spec) == len(shape), (name, shape, spec)
        if self.stack_depth:
            shape = (self.stack_depth,) + shape
            spec = (None,) + tuple(spec)
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(shape, self.dtype)
            self.specs[name] = P(*spec)
            return
        if init == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            arr = jnp.ones(shape, self.dtype)
        elif init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = scale if scale is not None else 1.0 / np.sqrt(max(1, fan_in))
            arr = (
                jax.random.normal(self._next_key(), shape, jnp.float32) * std
            ).astype(self.dtype)
        elif init == "constant":
            arr = jnp.full(shape, scale, self.dtype)
        else:
            raise ValueError(init)
        self.params[name] = arr
        self.specs[name] = P(*spec)


# -- norms ---------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


# -- rotary embeddings ------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- misc --------------------------------------------------------------------------


def causal_mask(q_len: int, kv_len: int, q_offset: int = 0) -> jax.Array:
    """(q_len, kv_len) additive mask; query i may see kv j <= i + q_offset."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    return jnp.where(kj <= qi, 0.0, -1e30).astype(jnp.float32)


def batch_spec(mesh_axes: Tuple[str, ...]) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod','data') on a multi-pod mesh, ('data',)
    on a single pod."""
    return tuple(a for a in mesh_axes if a in ("pod", "data"))
