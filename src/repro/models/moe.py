"""Mixture-of-Experts layer (DeepSeek-style: shared + routed experts, top-k).

Dispatch is capacity-based scatter/gather: tokens are placed into an
(E, C, d) expert buffer (position = arrival order within the expert, tokens
beyond capacity dropped), expert SwiGLU runs as a batched matmul sharded over
the ``model`` axis (expert parallelism), and outputs are gathered back and
combined with the router weights.  Under pjit this lowers to the
all-to-all-shaped collectives the roofline analysis wants to see
(DESIGN.md §5); §Perf iterates on this dispatch.

The router runs in fp32; an aux load-balance loss (Switch-style) is returned
alongside the output.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory
from repro.models.config import ModelConfig
from repro.models.mlp import mlp_forward, mlp_init


def moe_init(f: ParamFactory, cfg: ModelConfig) -> None:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    f.add("router", (d, E), (None, None), scale=0.02)
    f.add("we_gate", (E, d, ff), ("model", None, None))
    f.add("we_up", (E, d, ff), ("model", None, None))
    f.add("we_down", (E, ff, d), ("model", None, None))
    if cfg.num_shared_experts:
        sf = f.subfactory("shared")
        mlp_init(sf, cfg, d_ff=ff * cfg.num_shared_experts)


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.num_experts * cfg.capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))


def moe_forward(
    p: Dict[str, Any], cfg: ModelConfig, x: jax.Array, buf_spec=None
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    ``buf_spec`` (§Perf): PartitionSpec for the (E, C, d) expert buffer.
    Without it the SPMD partitioner shards E over "model" but *replicates*
    the capacity dim across the data axis — every data shard redundantly
    computes the full expert GEMM (16× wasted MXU time on a 16×16 mesh).
    ``P("model", "data", None)`` splits capacity rows across data shards."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)  # (T,k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)  # DeepSeek renormalises top-k

    C = capacity(T, cfg)
    idx_f = idx.reshape(T * k)
    w_f = w.reshape(T * k).astype(x.dtype)
    onehot = jax.nn.one_hot(idx_f, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_f = jnp.sum(pos * onehot, axis=-1)  # (T*k,) slot within expert
    keep = (pos_f < C).astype(x.dtype)
    safe_pos = jnp.minimum(pos_f, C - 1)

    xk = jnp.broadcast_to(xf[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[idx_f, safe_pos].add(xk * keep[:, None])
    if buf_spec is not None:
        buf = jax.lax.with_sharding_constraint(buf, buf_spec)

    # expert SwiGLU, batched over E (sharded over the model axis)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["we_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["we_up"]
    )
    hout = jnp.einsum("ecf,efd->ecd", h, p["we_down"])  # (E,C,d)
    if buf_spec is not None:
        hout = jax.lax.with_sharding_constraint(hout, buf_spec)

    gathered = hout[idx_f, safe_pos] * (keep * w_f)[:, None]  # (T*k, d)
    out = gathered.reshape(T, k, d).sum(axis=1)

    if cfg.num_shared_experts:
        out = out + mlp_forward(p["shared"], xf)

    # Switch-style load-balance aux
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p)
    return out.reshape(B, S, d), aux


# =============================================================================
# shard_map expert-parallel dispatch (beyond-paper, EXPERIMENTS.md §Perf H4)
# =============================================================================


def moe_forward_shard_map(
    p: Dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,
    mesh,
    dp_axes: Tuple[str, ...] = ("data",),
    ep_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE with explicit per-device dispatch.

    The pjit scatter dispatch either replicates the expert GEMM across the
    data axis (16× wasted compute) or, when capacity is sharded, emits
    pessimal collectives (§Perf H4).  Here each (data, model) device runs
    the router on its *local* tokens (activations are already replicated
    over the model axis), keeps only the tokens routed to its own expert
    range, runs its expert shard's GEMM at local capacity, and psums partial
    outputs over the expert axis — the same all-reduce a dense TP MLP pays.
    Dispatch itself moves **zero** bytes.
    """
    import inspect

    try:
        from jax import shard_map  # newer jax re-exports at top level
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    # replication checking kwarg was renamed check_rep -> check_vma
    if "check_vma" in inspect.signature(shard_map).parameters:
        no_rep_check = {"check_vma": False}
    else:
        no_rep_check = {"check_rep": False}

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    ep = mesh.shape[ep_axis]
    # contract-ok: no-bare-assert trace-time shape precondition inside jit
    assert E % ep == 0, (E, ep)
    e_loc = E // ep
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    t_loc = (B // dp_size if B % dp_size == 0 else B) * S
    c_loc = capacity(t_loc, cfg)

    def body(x_loc, router, we_gate, we_up, we_down, shared):
        # x_loc: (B_loc, S, d) ; we_*: (e_loc, d, f) local expert shard
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        xf = x_loc.reshape(T, d)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = (w / jnp.sum(w, axis=-1, keepdims=True)).astype(x_loc.dtype)

        my_lo = jax.lax.axis_index(ep_axis) * e_loc
        idx_f = idx.reshape(T * k)
        w_f = w.reshape(T * k)
        local_e = idx_f - my_lo  # in [0, e_loc) if mine
        mine = (local_e >= 0) & (local_e < e_loc)
        safe_e = jnp.clip(local_e, 0, e_loc - 1)
        onehot = jax.nn.one_hot(safe_e, e_loc, dtype=jnp.int32) * mine[:, None].astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos_f = jnp.sum(pos * onehot, axis=-1)
        keep = (mine & (pos_f < c_loc)).astype(x_loc.dtype)
        safe_pos = jnp.minimum(pos_f, c_loc - 1)

        xk = jnp.broadcast_to(xf[:, None, :], (T, k, d)).reshape(T * k, d)
        buf = jnp.zeros((e_loc, c_loc, d), x_loc.dtype)
        buf = buf.at[safe_e, safe_pos].add(xk * keep[:, None])
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we_gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, we_up
        )
        hout = jnp.einsum("ecf,efd->ecd", h, we_down)
        gathered = hout[safe_e, safe_pos] * (keep * w_f)[:, None]
        out = gathered.reshape(T, k, d).sum(axis=1)
        out = jax.lax.psum(out, ep_axis)  # partial expert outputs combine

        if shared is not None:
            # shared experts are model-sharded like a dense TP MLP
            hs = jax.nn.silu(xf @ shared["w_gate"]) * (xf @ shared["w_up"])
            out = out + jax.lax.psum(hs @ shared["w_down"], ep_axis)

        frac = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
        aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux
        return out.reshape(Bl, Sl, d), aux

    dp = dp_axes if B % dp_size == 0 and B >= dp_size else ()
    shared = p.get("shared")
    shared_specs = (
        {"w_gate": P(None, ep_axis), "w_up": P(None, ep_axis), "w_down": P(ep_axis, None)}
        if shared is not None
        else None
    )
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),
            P(None, None),
            P(ep_axis, None, None),
            P(ep_axis, None, None),
            P(ep_axis, None, None),
            shared_specs,
        ),
        out_specs=(P(dp, None, None), P()),
        **no_rep_check,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared)
    return out, aux
