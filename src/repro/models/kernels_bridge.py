"""Bridge between model code and the attention compute layer.

Models call :func:`causal_attention` / :func:`decode_attention`; the bridge
routes to the Pallas TPU kernels (``repro.kernels.ops``) when
``use_kernels=True`` (real TPU, or interpret mode in kernel tests) and to a
pure-jnp implementation otherwise.  The jnp prefill path is *blocked* over
query tiles (lax.scan) so its HLO memory profile resembles the flash kernel
rather than materialising the full S×S score matrix.

GQA grouping (H = KV·G) is handled here so both backends see the same
contract.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _grouped(q: jax.Array, kv_heads: int):
    B, S, H, hd = q.shape
    G = H // kv_heads
    return q.reshape(B, S, kv_heads, G, hd)


def _naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: Optional[int],
    scale: float,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    Skv = k.shape[1]
    q5 = _grouped(q, KV)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q5, k).astype(jnp.float32) * scale
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(Skv)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return o.reshape(B, Sq, H, v.shape[-1])


def causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: Optional[int] = None,
    use_kernels: bool = False,
    scale: Optional[float] = None,
    q_block: int = 1024,
) -> jax.Array:
    """Causal (optionally sliding-window) attention, (B,S,H,hd) layout."""
    B, S, H, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if use_kernels and q.shape[-1] == v.shape[-1] and S % 128 == 0:
        # (MLA's q head dim != v head dim and non-tile-aligned S fall back
        # to the jnp path; the kernel covers the GQA serving hot path)
        from repro.kernels import ops  # lazy: kernels are optional at import

        return ops.flash_attention(q, k, v, window=window, scale=scale)
    if S <= q_block:
        return _naive_attention(q, k, v, window, scale)
    # blocked over query tiles: score tile is (B,KV,G,q_block,S), never S×S
    n_blk = S // q_block
    # contract-ok: no-bare-assert trace-time shape precondition inside jit
    assert S % q_block == 0, f"seq {S} not divisible by q_block {q_block}"
    q_tiles = q.reshape(B, n_blk, q_block, H, hd).transpose(1, 0, 2, 3, 4)
    offsets = jnp.arange(n_blk) * q_block

    def body(_, inp):
        q_tile, off = inp
        o = _naive_attention_dyn(q_tile, k, v, window, scale, off)
        return None, o

    _, o_tiles = jax.lax.scan(body, None, (q_tiles, offsets))
    return o_tiles.transpose(1, 0, 2, 3, 4).reshape(B, S, H, v.shape[-1])


def _naive_attention_dyn(
    q: jax.Array, k: jax.Array, v: jax.Array,
    window: Optional[int], scale: float, q_offset: jax.Array,
) -> jax.Array:
    """Like _naive_attention but with a traced query offset (scan tile)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    Skv = k.shape[1]
    q5 = _grouped(q, KV)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q5, k).astype(jnp.float32) * scale
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(Skv)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return o.reshape(B, Sq, H, v.shape[-1])


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    valid: jax.Array,  # (S,) or (B, S) bool — per-request ragged validity
    use_kernels: bool = False,
    scale: Optional[float] = None,
) -> jax.Array:
    B, _, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None, :], (B, S))
    if use_kernels:
        from repro.kernels import ops

        return ops.decode_attention(q, k, v, valid, scale=scale)
    q5 = _grouped(q, KV)  # (B,1,KV,G,hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q5, k).astype(jnp.float32) * scale
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return o.reshape(B, 1, H, v.shape[-1])
