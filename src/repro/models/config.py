"""Architecture configuration schema.

One :class:`ModelConfig` instance fully describes any of the ten assigned
architectures (dense / MoE / SSM / hybrid / VLM / audio).  Configs live in
:mod:`repro.configs` (one module per architecture, exact numbers cited from
the source papers) and are consumed by :mod:`repro.models.transformer`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # -- attention ------------------------------------------------------------
    attention_kind: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None  # ring-cache window (long-context)

    # -- MLA (DeepSeek multi-head latent attention) -----------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    mlp_gated: bool = True  # SwiGLU when True; GELU 2-matrix MLP when False

    # -- MoE --------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # -- SSM (Mamba2 / SSD) --------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssm_chunk: int = 128

    # -- hybrid (Zamba2-style shared attention) --------------------------------------
    shared_attn_every: int = 0  # apply one shared GQA block every k SSM layers

    # -- multimodal stub -----------------------------------------------------------
    modality: str = "text"  # text | vision_stub | audio_stub
    frontend_tokens: int = 256  # stub prefix length supplied by input_specs

    # -- training extras --------------------------------------------------------------
    mtp: bool = False  # DeepSeek-V3 multi-token prediction head
    tie_embeddings: bool = False

    # -- numerics ----------------------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    vocab_pad: int = 256  # embed/head padded so the vocab dim shards cleanly

    # -- citation (source paper / model card for the exact numbers) ----------------------
    citation: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.arch_type not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(
                f"{self.name}: unknown arch_type {self.arch_type!r} "
                "(expected dense|moe|ssm|hybrid|vlm|audio)"
            )
        if self.arch_type == "ssm" and self.attention_kind != "none":
            raise ValueError(
                f"{self.name}: pure-SSM configs take attention_kind='none', "
                f"got {self.attention_kind!r}"
            )
        if self.attention_kind == "mla" and self.kv_lora_rank <= 0:
            raise ValueError(
                f"{self.name}: MLA attention needs kv_lora_rank > 0, "
                f"got {self.kv_lora_rank}"
            )

    # -- derived quantities used by profiles / roofline ------------------------------
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def is_attention_layer(self, layer: int) -> bool:
        if self.arch_type == "ssm":
            return False
        if self.arch_type == "hybrid":
            k = max(self.shared_attn_every, 1)
            return (layer + 1) % k == 0
        return True

    def is_moe_layer(self, layer: int) -> bool:
        return self.num_experts > 0 and layer >= self.first_dense_layers

    def param_count(self) -> float:
        """Approximate total parameter count (used by analytic profiles)."""
        d, v = self.d_model, self.vocab_size
        total = 2.0 * v * d if not self.tie_embeddings else 1.0 * v * d
        for layer in range(self.num_layers):
            total += self._layer_params(layer)
        return total

    def active_param_count(self) -> float:
        """Parameters touched per token (MoE: shared + top-k experts only)."""
        d, v = self.d_model, self.vocab_size
        total = 2.0 * v * d if not self.tie_embeddings else 1.0 * v * d
        for layer in range(self.num_layers):
            total += self._layer_params(layer, active_only=True)
        return total

    def _attention_params(self) -> float:
        d = self.d_model
        if self.attention_kind == "mla":
            qd = self.q_lora_rank or d
            p = 0.0
            if self.q_lora_rank:
                p += d * self.q_lora_rank
            p += qd * self.num_heads * (self.nope_head_dim + self.rope_head_dim)
            p += d * (self.kv_lora_rank + self.rope_head_dim)
            p += self.kv_lora_rank * self.num_heads * (
                self.nope_head_dim + self.v_head_dim
            )
            p += self.num_heads * self.v_head_dim * d
            return p
        hd = self.head_dim
        return d * hd * (self.num_heads + 2 * self.num_kv_heads) + (
            self.num_heads * hd * d
        )

    def _mlp_params(self, layer: int, active_only: bool = False) -> float:
        d = self.d_model
        if self.is_moe_layer(layer):
            n_routed = self.experts_per_token if active_only else self.num_experts
            experts = (n_routed + self.num_shared_experts) * 3 * d * self.moe_d_ff
            router = d * self.num_experts
            return experts + router
        return (3.0 if self.mlp_gated else 2.0) * d * self.d_ff

    def _ssm_params(self) -> float:
        d, di = self.d_model, self.d_inner
        n = self.ssm_state
        # in_proj -> (z, x, B, C, dt), conv, A/D, norm, out_proj
        in_proj = d * (2 * di + 2 * n * 1 + self.ssm_heads)
        conv = (di + 2 * n) * self.conv_width
        out = di * d
        return in_proj + conv + out + 2 * self.ssm_heads + di

    def _layer_params(self, layer: int, active_only: bool = False) -> float:
        p = 2.0 * self.d_model  # norms
        if self.arch_type == "ssm":
            return p + self._ssm_params()
        if self.arch_type == "hybrid":
            p += self._ssm_params()
            if self.is_attention_layer(layer):
                # shared weights: count once over the whole stack
                k = max(self.shared_attn_every, 1)
                p += self._attention_params() / max(1, self.num_layers // k)
            return p
        p += self._attention_params()
        p += self._mlp_params(layer, active_only)
        return p

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> float:
        """Decode-cache bytes appended per generated token per request."""
        if self.arch_type == "ssm":
            return 0.0
        if self.attention_kind == "mla":
            per_layer = self.kv_lora_rank + self.rope_head_dim
        else:
            per_layer = 2 * self.num_kv_heads * self.head_dim
        if self.arch_type == "hybrid":
            k = max(self.shared_attn_every, 1)
            n_attn = self.num_layers // k
        else:
            n_attn = self.num_layers
        return float(n_attn * per_layer * dtype_bytes)
