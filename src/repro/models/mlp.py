"""Dense feed-forward (SwiGLU) block."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory
from repro.models.config import ModelConfig


def mlp_init(f: ParamFactory, cfg: ModelConfig, d_ff: int = 0) -> None:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    if cfg.mlp_gated:
        f.add("w_gate", (d, ff), (None, "model"))
    f.add("w_up", (d, ff), (None, "model"))
    f.add("w_down", (ff, d), ("model", None))


def mlp_forward(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:  # non-gated (GPT-BigCode style, e.g. granite-20b)
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]
