"""Attention variants: GQA (with qk-norm, RoPE), MLA (DeepSeek latent
attention with weight absorption at decode), and sliding-window GQA with a
ring KV cache (the long-context variant for dense architectures,
DESIGN.md §4).

Each variant provides:
  init(factory, cfg)                          — parameters + specs
  forward(params, cfg, x, positions)          — full-sequence (train/prefill)
  decode(params, cfg, x, cache, pos, live)    — one token against a KV cache
  init_cache / cache_specs                    — cache pytree + shardings

Decode is *ragged*: ``pos`` is a per-request ``(B,)`` vector of positions
(continuous batching serves requests at different offsets in one batch) and
``live`` masks cache writes so idle/padding slots never touch the cache.
GQA additionally provides a *paged* decode (``gqa_decode_paged`` /
``gqa_init_paged_cache``) over a shared page pool — the serving engine's
production KV layout, consumed by ``repro.kernels.paged_attention``.

Caches carry no layer axis here; the transformer stacks them for scan.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import kernels_bridge
from repro.models.common import ParamFactory, apply_rope, causal_mask, rmsnorm
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# =============================================================================
# GQA
# =============================================================================


def gqa_init(f: ParamFactory, cfg: ModelConfig) -> None:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    f.add("wq", (d, H * hd), (None, "model"))
    f.add("wk", (d, KV * hd), (None, "model"))
    f.add("wv", (d, KV * hd), (None, "model"))
    f.add("wo", (H * hd, d), ("model", None))
    if cfg.qk_norm:
        f.add("q_norm", (hd,), (None,), init="ones")
        f.add("k_norm", (hd,), (None,), init="ones")


def _gqa_qkv(
    p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    use_kernels: bool = False,
    kv_hint: Optional[P] = None,
) -> jax.Array:
    """Full-sequence causal attention (train / prefill).

    ``kv_hint`` (§Perf): a PartitionSpec applied to k/v once, above the
    blocked-attention tile loop — without it the SPMD partitioner may
    re-gather k/v on every query tile when kv_heads < model-axis size."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    if kv_hint is not None:
        k = jax.lax.with_sharding_constraint(k, kv_hint)
        v = jax.lax.with_sharding_constraint(v, kv_hint)
    window = cfg.sliding_window
    o = kernels_bridge.causal_attention(
        q, k, v, window=window, use_kernels=use_kernels
    )
    return o.reshape(B, S, H * hd) @ p["wo"]


def gqa_prefill(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    use_kernels: bool = False,
    kv_hint: Optional[P] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence attention that also emits the decode cache."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    if kv_hint is not None:
        k = jax.lax.with_sharding_constraint(k, kv_hint)
        v = jax.lax.with_sharding_constraint(v, kv_hint)
    o = kernels_bridge.causal_attention(
        q, k, v, window=cfg.sliding_window, use_kernels=use_kernels
    )
    out = o.reshape(B, S, H * hd) @ p["wo"]
    if cfg.sliding_window and cfg.sliding_window < S:
        W = cfg.sliding_window
        # contract-ok: no-bare-assert trace-time shape precondition inside jit
        assert S % W == 0, "prefill length must align with the ring window"
        cache = {
            "k": k[:, S - W :],
            "v": v[:, S - W :],
            "slot_pos": jnp.broadcast_to(
                jnp.arange(S - W, S, dtype=jnp.int32), (B, W)
            ),
        }
    else:
        cache = {"k": k, "v": v}
    return out, cache


def gqa_init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any
) -> Dict[str, jax.Array]:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.sliding_window and cfg.sliding_window < max_len:
        W = cfg.sliding_window
        return {
            "k": jnp.zeros((batch, W, KV, hd), dtype),
            "v": jnp.zeros((batch, W, KV, hd), dtype),
            "slot_pos": jnp.full((batch, W), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((batch, max_len, KV, hd), dtype),
    }


def gqa_cache_specs(cfg: ModelConfig, dp: Tuple[str, ...], seq_axis: Optional[str]):
    spec = P(dp, seq_axis, None, None)
    out = {"k": spec, "v": spec}
    if cfg.sliding_window:
        out["slot_pos"] = P(dp, None)
    return out


def normalize_pos(pos: jax.Array, batch: int) -> Tuple[jax.Array, jax.Array]:
    """Broadcast a scalar-or-(B,) position to ``(B,)`` and derive liveness.

    Negative positions mark idle/padding slots: their logits are still
    computed (the batch shape is static) but their cache writes are masked.
    Returns ``(clamped_pos (B,), live (B,) bool)``."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))
    return jnp.maximum(pos, 0), pos >= 0


def _masked_row_update(
    cache: jax.Array,  # (B, S, ...)
    new: jax.Array,  # (B, 1, ...)
    idx: jax.Array,  # (B,) int32 — row to write, per batch element
    live: jax.Array,  # (B,) bool — rows of dead slots stay untouched
) -> jax.Array:
    upd = jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
    )(cache, new, idx)
    mask = live.reshape((-1,) + (1,) * (cache.ndim - 1))
    return jnp.where(mask, upd, cache)


def gqa_decode(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, d)
    cache: Dict[str, jax.Array],
    pos: jax.Array,  # (B,) int32 per-slot position of the new token (or scalar)
    live: Optional[jax.Array] = None,  # (B,) bool; None => all live
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cpos, derived_live = normalize_pos(pos, B)
    live = derived_live if live is None else live
    q, k_new, v_new = _gqa_qkv(p, cfg, x, cpos[:, None])
    if "slot_pos" in cache:  # ring buffer (sliding window), slot_pos (B, W)
        W = cache["k"].shape[1]
        slot = cpos % W
        k = _masked_row_update(cache["k"], k_new, slot, live)
        v = _masked_row_update(cache["v"], v_new, slot, live)
        onehot = jnp.arange(W)[None, :] == slot[:, None]
        slot_pos = jnp.where(
            onehot & live[:, None], cpos[:, None], cache["slot_pos"]
        )
        valid = (
            (slot_pos >= 0)
            & (slot_pos > cpos[:, None] - W)
            & (slot_pos <= cpos[:, None])
        )  # (B, W)
        new_cache = {"k": k, "v": v, "slot_pos": slot_pos}
    else:
        k = _masked_row_update(cache["k"], k_new, cpos, live)
        v = _masked_row_update(cache["v"], v_new, cpos, live)
        S = k.shape[1]
        valid = jnp.arange(S)[None, :] <= cpos[:, None]  # (B, S)
        new_cache = {"k": k, "v": v}
    o = kernels_bridge.decode_attention(q, k, v, valid)
    return o.reshape(B, 1, H * hd) @ p["wo"], new_cache


# -- paged KV (shared page pool; the serving engine's production layout) ------


def gqa_init_paged_cache(
    cfg: ModelConfig, num_pages: int, page_size: int, dtype: Any
) -> Dict[str, jax.Array]:
    """Per-layer page pools.  One logical page id addresses a slab across all
    layers (the transformer stacks these along the scan axis), so a single
    host-side :class:`~repro.serving.paged_cache.PagePool` table drives every
    layer's kernel."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "pool_k": jnp.zeros((num_pages, page_size, KV, hd), dtype),
        "pool_v": jnp.zeros((num_pages, page_size, KV, hd), dtype),
    }


def gqa_decode_paged(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, d)
    cache: Dict[str, jax.Array],  # {"pool_k","pool_v"} (P, ps, KV, hd)
    page_tables: jax.Array,  # (B, max_pages) int32
    pos: jax.Array,  # (B,) int32 per-slot position of the new token
    live: jax.Array,  # (B,) bool
    use_kernels: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Ragged decode against the paged pool: the new token's k/v is scattered
    into its slot's current page (idle slots are routed to an out-of-bounds
    page id, so jax drops their write), then attention runs over the pages —
    the Pallas paged kernel when ``use_kernels``, a gather + flat-decode
    reference otherwise."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cpos, _ = normalize_pos(pos, B)
    q, k_new, v_new = _gqa_qkv(p, cfg, x, cpos[:, None])
    pool_k, pool_v = cache["pool_k"], cache["pool_v"]
    num_pages, ps = pool_k.shape[0], pool_k.shape[1]
    page = page_tables[jnp.arange(B), cpos // ps]
    page = jnp.where(live, page, num_pages)  # OOB => scatter dropped
    off = cpos % ps
    pool_k = pool_k.at[page, off].set(k_new[:, 0], mode="drop")
    pool_v = pool_v.at[page, off].set(v_new[:, 0], mode="drop")
    lengths = jnp.where(live, cpos + 1, 0)
    if use_kernels:
        from repro.kernels import ops  # lazy: kernels are optional at import

        o = ops.paged_decode_attention(q, pool_k, pool_v, page_tables, lengths)
    else:
        S = page_tables.shape[1] * ps
        k = pool_k[page_tables].reshape(B, S, KV, hd)
        v = pool_v[page_tables].reshape(B, S, KV, hd)
        valid = jnp.arange(S)[None, :] < lengths[:, None]
        o = kernels_bridge.decode_attention(q, k, v, valid)
    new_cache = {"pool_k": pool_k, "pool_v": pool_v}
    return o.reshape(B, 1, H * hd) @ p["wo"], new_cache


# =============================================================================
# MLA — DeepSeek multi-head latent attention
# =============================================================================


def mla_init(f: ParamFactory, cfg: ModelConfig) -> None:
    d, H = cfg.d_model, cfg.num_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    if qr:
        f.add("w_dq", (d, qr), (None, None))
        f.add("q_norm", (qr,), (None,), init="ones")
        f.add("w_uq", (qr, H * (nd + rd)), (None, "model"))
    else:
        f.add("w_uq", (d, H * (nd + rd)), (None, "model"))
    f.add("w_dkv", (d, r + rd), (None, None))
    f.add("kv_norm", (r,), (None,), init="ones")
    f.add("w_uk", (r, H * nd), (None, "model"))
    f.add("w_uv", (r, H * vd), (None, "model"))
    f.add("wo", (H * vd, d), ("model", None))


def _mla_q(p: Params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nd, rd = cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    else:
        cq = x
    q = (cq @ p["w_uq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: Params, cfg: ModelConfig, x, positions):
    """Compressed KV: c_kv (B,S,r) and the shared rotary key (B,S,rd)."""
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    dkv = x @ p["w_dkv"]  # (B,S,r+rd)
    ckv = rmsnorm(dkv[..., :r], p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(dkv[..., None, r:], positions, cfg.rope_theta)[..., 0, :]
    return ckv, krope


def mla_forward(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    use_kernels: bool = False,
    kv_hint: Optional[P] = None,
) -> jax.Array:
    """Prefill/train path: expand the latent into per-head K/V."""
    B, S, _ = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv, krope = _mla_latent(p, cfg, x, positions)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, nd)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, rd))], axis=-1)
    if kv_hint is not None:
        k = jax.lax.with_sharding_constraint(k, kv_hint)
        v = jax.lax.with_sharding_constraint(v, kv_hint)
    o = kernels_bridge.causal_attention(
        q, k, v, window=cfg.sliding_window, use_kernels=use_kernels,
        scale=1.0 / math.sqrt(nd + rd),
    )
    return o.reshape(B, S, H * vd) @ p["wo"]


def mla_prefill(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    use_kernels: bool = False,
    kv_hint: Optional[P] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill that also emits the latent decode cache (c_kv + rotary key)."""
    B, S, _ = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv, krope = _mla_latent(p, cfg, x, positions)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, nd)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, rd))], axis=-1
    )
    if kv_hint is not None:
        k = jax.lax.with_sharding_constraint(k, kv_hint)
        v = jax.lax.with_sharding_constraint(v, kv_hint)
    o = kernels_bridge.causal_attention(
        q, k, v, window=cfg.sliding_window, use_kernels=use_kernels,
        scale=1.0 / math.sqrt(nd + rd),
    )
    out = o.reshape(B, S, H * vd) @ p["wo"]
    if cfg.sliding_window and cfg.sliding_window < S:
        W = cfg.sliding_window
        # contract-ok: no-bare-assert trace-time shape precondition inside jit
        assert S % W == 0
        cache = {
            "ckv": ckv[:, S - W :],
            "krope": krope[:, S - W :],
            "slot_pos": jnp.broadcast_to(
                jnp.arange(S - W, S, dtype=jnp.int32), (B, W)
            ),
        }
    else:
        cache = {"ckv": ckv, "krope": krope}
    return out, cache


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype: Any):
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    if cfg.sliding_window and cfg.sliding_window < max_len:
        W = cfg.sliding_window
        return {
            "ckv": jnp.zeros((batch, W, r), dtype),
            "krope": jnp.zeros((batch, W, rd), dtype),
            "slot_pos": jnp.full((batch, W), -1, jnp.int32),
        }
    return {
        "ckv": jnp.zeros((batch, max_len, r), dtype),
        "krope": jnp.zeros((batch, max_len, rd), dtype),
    }


def mla_cache_specs(cfg: ModelConfig, dp: Tuple[str, ...], seq_axis: Optional[str]):
    out = {"ckv": P(dp, seq_axis, None), "krope": P(dp, seq_axis, None)}
    if cfg.sliding_window:
        out["slot_pos"] = P(dp, None)
    return out


def mla_decode(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    pos: jax.Array,  # (B,) int32 per-slot position of the new token (or scalar)
    live: Optional[jax.Array] = None,  # (B,) bool; None => all live
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Weight-absorbed decode: score and read directly in the latent space —
    the cache stays (B, S, r + rd) instead of (B, S, H, nd + vd)."""
    B = x.shape[0]
    H = cfg.num_heads
    nd, rd, vd, r = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    cpos, derived_live = normalize_pos(pos, B)
    live = derived_live if live is None else live
    positions = cpos[:, None]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)  # (B,1,H,nd),(B,1,H,rd)
    ckv_new, krope_new = _mla_latent(p, cfg, x, positions)

    if "slot_pos" in cache:  # ring buffer, slot_pos (B, W)
        W = cache["ckv"].shape[1]
        slot = cpos % W
        ckv = _masked_row_update(cache["ckv"], ckv_new, slot, live)
        krope = _masked_row_update(cache["krope"], krope_new, slot, live)
        onehot = jnp.arange(W)[None, :] == slot[:, None]
        slot_pos = jnp.where(
            onehot & live[:, None], cpos[:, None], cache["slot_pos"]
        )
        valid = (
            (slot_pos >= 0)
            & (slot_pos > cpos[:, None] - W)
            & (slot_pos <= cpos[:, None])
        )  # (B, W)
        new_cache = {"ckv": ckv, "krope": krope, "slot_pos": slot_pos}
    else:
        ckv = _masked_row_update(cache["ckv"], ckv_new, cpos, live)
        krope = _masked_row_update(cache["krope"], krope_new, cpos, live)
        valid = jnp.arange(ckv.shape[1])[None, :] <= cpos[:, None]  # (B, S)
        new_cache = {"ckv": ckv, "krope": krope}

    # absorb W_uk into the query: q_abs (B,1,H,r)
    w_uk = p["w_uk"].reshape(r, H, nd)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    scores = jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv) + jnp.einsum(
        "bqhd,bsd->bhqs", q_rope, krope
    )
    scores = scores.astype(jnp.float32) / math.sqrt(nd + rd)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    o_latent = jnp.einsum("bhqs,bsr->bqhr", probs, ckv)  # (B,1,H,r)
    w_uv = p["w_uv"].reshape(r, H, vd)
    o = jnp.einsum("bqhr,rhv->bqhv", o_latent, w_uv)
    return o.reshape(B, 1, H * vd) @ p["wo"], new_cache
