"""Mamba2 (SSD — state-space duality) block. [arXiv:2405.21060]

Prefill/train uses the chunked SSD algorithm: quadratic attention-like
computation inside chunks of length Q, linear recurrence across chunks.
Decode maintains the (B, H, P, N) state plus a depthwise-conv tail.

The scan itself is routed through :mod:`repro.models.kernels_bridge` so the
Pallas ``ssm_scan`` kernel can take over on TPU; the pure-jnp chunked path
below is the oracle.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory, rmsnorm
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def ssm_init(f: ParamFactory, cfg: ModelConfig) -> None:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    H = cfg.ssm_heads
    conv_ch = di + 2 * n
    # separate projections so each output dim shards cleanly on "model"
    # (a packed w_in would be sliced across shard boundaries)
    f.add("w_z", (d, di), (None, "model"))
    f.add("w_xbc", (d, conv_ch), (None, "model"))
    f.add("w_dt", (d, H), (None, "model"))
    f.add("conv_w", (cfg.conv_width, conv_ch), (None, "model"))
    f.add("conv_b", (conv_ch,), ("model",), init="zeros")
    f.add("A_log", (H,), (None,), init="zeros")
    f.add("dt_bias", (H,), (None,), init="zeros")
    f.add("D", (H,), (None,), init="ones")
    f.add("ssm_norm", (di,), ("model",), init="ones")
    f.add("w_out", (di, d), ("model", None))


def _project(p: Params, x: jax.Array):
    """(z, xBC, dt) input projections."""
    return x @ p["w_z"], x @ p["w_xbc"], x @ p["w_dt"]


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    B_: jax.Array,  # (B, S, N)
    C_: jax.Array,  # (B, S, N)
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD; returns (y, final_state (B,H,P,N))."""
    Bb, S, H, Pd = x.shape
    N = B_.shape[-1]
    # contract-ok: no-bare-assert trace-time shape precondition inside jit
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xr = x.reshape(Bb, nc, chunk, H, Pd)
    dtr = dt.reshape(Bb, nc, chunk, H)
    Br = B_.reshape(Bb, nc, chunk, N)
    Cr = C_.reshape(Bb, nc, chunk, N)

    dA = dtr * A[None, None, None, :]  # (B,nc,L,H), negative
    dA_cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum over L

    # -- intra-chunk (quadratic within the chunk) ------------------------------
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # (B,nc,L,L)
    li = dA_cs[:, :, :, None, :]  # i
    lj = dA_cs[:, :, None, :, :]  # j
    decay = jnp.exp(
        jnp.clip(li - lj, -60.0, 0.0)
    )  # (B,nc,L,L,H); j<=i => nonpositive exponent
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = cb[..., None] * decay * dtr[:, :, None, :, :]
    m = jnp.where(mask[None, None, :, :, None], m, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xr)

    # -- per-chunk summary state -------------------------------------------------
    last = dA_cs[:, :, -1:, :]  # (B,nc,1,H)
    seg = jnp.exp(jnp.clip(last - dA_cs, -60.0, 0.0))  # decay from j to chunk end
    states = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchpn", seg * dtr, Br, xr
    )  # (B,nc,H,P,N)

    # -- inter-chunk recurrence ----------------------------------------------------
    chunk_decay = jnp.exp(jnp.clip(last[:, :, 0, :], -60.0, 0.0))  # (B,nc,H)

    def body(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* this chunk

    init = jnp.zeros((Bb, H, Pd, N), x.dtype)
    final, prev_states = jax.lax.scan(
        body,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    y_inter = jnp.einsum(
        "bcin,bchpn->bcihp", Cr, prev_states
    ) * jnp.exp(jnp.clip(dA_cs, -60.0, 0.0))[..., None]
    y = (y_intra + y_inter).reshape(Bb, S, H, Pd)
    return y, final


def ssd_step(
    state: jax.Array,  # (B, H, P, N)
    x_t: jax.Array,  # (B, H, P)
    dt_t: jax.Array,  # (B, H)
    A: jax.Array,  # (H,)
    B_t: jax.Array,  # (B, N)
    C_t: jax.Array,  # (B, N)
) -> Tuple[jax.Array, jax.Array]:
    """One recurrence step; returns (y_t (B,H,P), new_state)."""
    dA = jnp.exp(jnp.clip(dt_t * A[None, :], -60.0, 0.0))  # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, B_t)
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C_t, new_state)
    return y, new_state


# =============================================================================
# Block-level forward / decode
# =============================================================================


def ssm_forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    di, n, H, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _project(p, x)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, H, hd)
    B_ = xBC[..., di : di + n]
    C_ = xBC[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs.astype(jnp.float32), dt, A, B_.astype(jnp.float32),
                       C_.astype(jnp.float32), cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    return y @ p["w_out"]


def ssm_prefill(
    p: Params, cfg: ModelConfig, x: jax.Array,
    lengths: Optional[jax.Array] = None,  # (B,) true lengths of padded rows
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Like :func:`ssm_forward` but also emits the decode cache
    (final SSD state + raw conv tail).

    ``lengths`` supports right-padded ragged prefill (the serving engine pads
    prompts up to ``ssm_chunk``): padded steps get ``dt = 0``, which makes the
    SSD recurrence an exact identity (``h = h·exp(0) + 0``), so the final
    state equals the state after ``lengths`` real tokens; the conv tail is
    sliced per-row at ``lengths`` (zero-left-padded, matching the zero conv
    init for prompts shorter than the kernel)."""
    B, S, d = x.shape
    di, n, H, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC_raw, dt = _project(p, x)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, H, hd)
    B_ = xBC[..., di : di + n]
    C_ = xBC[..., di + n :]
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if lengths is not None:
        pad_mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
        dt_ = dt_ * pad_mask[:, :, None]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final = ssd_chunked(xs.astype(jnp.float32), dt_, A, B_.astype(jnp.float32),
                           C_.astype(jnp.float32), cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    W1 = cfg.conv_width - 1
    if lengths is None:
        conv = xBC_raw[:, S - W1 :]
    else:
        padded = jnp.pad(xBC_raw, ((0, 0), (W1, 0), (0, 0)))
        conv = jax.vmap(
            lambda a, l: jax.lax.dynamic_slice_in_dim(a, l, W1, axis=0)
        )(padded, lengths)
    cache = {"conv": conv, "state": final}
    return y @ p["w_out"], cache


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype: Any) -> Dict[str, jax.Array]:
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
    }


def ssm_cache_specs(cfg: ModelConfig, dp):
    from jax.sharding import PartitionSpec as P

    # state (B, H, P, N): shard heads over "model" (matches w_xbc sharding)
    return {"conv": P(dp, None, "model"), "state": P(dp, "model", None, None)}


def ssm_decode(
    p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict[str, jax.Array],
    live: Optional[jax.Array] = None,  # (B,) bool — dead slots keep their state
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, d)."""
    B = x.shape[0]
    di, n, H, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _project(p, x)  # (B,1,·)
    hist = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B,W,C)
    w = p["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"]
    xBC1 = jax.nn.silu(conv_out)  # (B,C)
    new_conv = hist[:, 1:, :]
    xs = xBC1[:, :di].reshape(B, H, hd)
    B_ = xBC1[:, di : di + n]
    C_ = xBC1[:, di + n :]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = ssd_step(
        cache["state"], xs.astype(jnp.float32), dt1, A,
        B_.astype(jnp.float32), C_.astype(jnp.float32),
    )
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    if live is not None:
        new_conv = jnp.where(live[:, None, None], new_conv, cache["conv"])
        new_state = jnp.where(live[:, None, None, None], new_state, cache["state"])
    return y @ p["w_out"], {"conv": new_conv, "state": new_state}
