"""Config-driven decoder models for all assigned architecture families.

Layer stacks are *scanned* (``jax.lax.scan`` over stacked parameters) so HLO
size — and therefore dry-run compile time — is O(1) in depth even for the
126-layer llama3-405b (DESIGN.md §5).  Heterogeneous stacks are handled as:

  * dense / vlm / audio : one scanned stack of (attn + SwiGLU) blocks
  * moe                 : ``first_dense_layers`` unrolled dense blocks, then a
                          scanned stack of (attn + MoE) blocks
  * ssm                 : one scanned stack of Mamba2 blocks
  * hybrid (Zamba2)     : scanned *superblocks* of ``shared_attn_every``
                          Mamba2 sublayers + one invocation of a single
                          weight-shared GQA block (closed over, not scanned)

Public surface: :class:`Model` with ``init`` / ``forward`` / ``init_cache`` /
``decode_step``.  ``forward`` accepts token ids or — for the stub-modality
architectures (vlm/audio) — precomputed frontend embeddings.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import DTYPES, ParamFactory, batch_spec, rmsnorm
from repro.models.config import ModelConfig
from repro.models.mlp import mlp_forward, mlp_init

Params = Dict[str, Any]


def _attn_init(f: ParamFactory, cfg: ModelConfig) -> None:
    if cfg.attention_kind == "mla":
        attn.mla_init(f, cfg)
    else:
        attn.gqa_init(f, cfg)


def _attn_forward(p, cfg, x, positions, use_kernels, kv_hint=None):
    if cfg.attention_kind == "mla":
        return attn.mla_forward(p, cfg, x, positions, use_kernels, kv_hint=kv_hint)
    return attn.gqa_forward(p, cfg, x, positions, use_kernels, kv_hint=kv_hint)


def _attn_decode(p, cfg, x, cache, pos, live=None):
    if cfg.attention_kind == "mla":
        return attn.mla_decode(p, cfg, x, cache, pos, live)
    return attn.gqa_decode(p, cfg, x, cache, pos, live)


def _attn_init_cache(cfg, batch, max_len, dtype):
    if cfg.attention_kind == "mla":
        return attn.mla_init_cache(cfg, batch, max_len, dtype)
    return attn.gqa_init_cache(cfg, batch, max_len, dtype)


def _attn_cache_specs(cfg, dp, seq_axis):
    if cfg.attention_kind == "mla":
        return attn.mla_cache_specs(cfg, dp, seq_axis)
    return attn.gqa_cache_specs(cfg, dp, seq_axis)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    use_kernels: bool = False
    remat: bool = True
    mesh_axes: Tuple[str, ...] = ("data", "model")
    # §Perf knob: constrain the residual stream's feature dim to the model
    # axis between blocks — XLA SPMD then lowers TP all-reduces into
    # reduce-scatter + all-gather pairs (sequence-parallel-style savings).
    act_tp: bool = False
    # §Perf knob: PartitionSpec pinned onto full-sequence k/v above the
    # blocked-attention tile loop (prevents per-tile re-gathers).
    kv_hint: object = None
    # §Perf knob: PartitionSpec for the MoE (E, C, d) expert buffer —
    # shards capacity over "data" so expert GEMMs are not replicated.
    moe_buf_spec: object = None
    # §Perf knob (H4 resolution): explicit shard_map expert dispatch —
    # requires the mesh object; zero-byte dispatch, no replicated GEMMs.
    moe_shard_map_mesh: object = None

    def _constrain(self, x: jax.Array) -> jax.Array:
        if not self.act_tp:
            return x
        dp = batch_spec(self.mesh_axes)
        return jax.lax.with_sharding_constraint(x, P(dp, None, "model"))

    # ------------------------------------------------------------------ init --
    def init(
        self, key: Optional[jax.Array], abstract: bool = False
    ) -> Tuple[Params, Params]:
        """Returns (params, partition-spec tree).  ``abstract=True`` emits
        ShapeDtypeStructs instead of arrays — the dry-run's no-allocation
        path (DESIGN.md §5)."""
        cfg = self.cfg
        dtype = DTYPES[cfg.dtype]
        f = ParamFactory(key, dtype, abstract=abstract)
        f.add("embed", (cfg.padded_vocab, cfg.d_model), ("model", None), scale=0.02)
        if not cfg.tie_embeddings:
            f.add("head", (cfg.d_model, cfg.padded_vocab), (None, "model"))
        f.add("final_norm", (cfg.d_model,), (None,), init="ones")

        if cfg.arch_type in ("dense", "vlm", "audio"):
            lf = f.subfactory("layers", stack_depth=cfg.num_layers)
            self._dense_block_init(lf, cfg)
        elif cfg.arch_type == "moe":
            for i in range(cfg.first_dense_layers):
                df = f.subfactory(f"dense_{i}")
                self._dense_block_init(df, cfg)
            n_moe = cfg.num_layers - cfg.first_dense_layers
            lf = f.subfactory("layers", stack_depth=n_moe)
            self._moe_block_init(lf, cfg)
        elif cfg.arch_type == "ssm":
            lf = f.subfactory("layers", stack_depth=cfg.num_layers)
            lf.add("ln", (cfg.d_model,), (None,), init="ones")
            ssm_mod.ssm_init(lf, cfg)
        elif cfg.arch_type == "hybrid":
            k = cfg.shared_attn_every
            # contract-ok: no-bare-assert trace-time shape precondition inside jit
            assert cfg.num_layers % k == 0, "hybrid depth must divide superblock"
            sf = f.subfactory("shared_attn")
            sf.add("ln", (cfg.d_model,), (None,), init="ones")
            _attn_init(sf, cfg)
            lf = f.subfactory("layers", stack_depth=cfg.num_layers // k)
            for i in range(k):
                mf = lf.subfactory(f"mamba_{i}")
                mf.add("ln", (cfg.d_model,), (None,), init="ones")
                ssm_mod.ssm_init(mf, cfg)
        else:
            raise ValueError(cfg.arch_type)
        if cfg.mtp:
            mf = f.subfactory("mtp")
            mf.add("proj", (2 * cfg.d_model, cfg.d_model), (None, "model"))
            mf.add("norm", (cfg.d_model,), (None,), init="ones")
        return f.params, f.specs

    def _dense_block_init(self, f: ParamFactory, cfg: ModelConfig) -> None:
        f.add("ln1", (cfg.d_model,), (None,), init="ones")
        af = f.subfactory("attn")
        _attn_init(af, cfg)
        f.add("ln2", (cfg.d_model,), (None,), init="ones")
        mf = f.subfactory("mlp")
        mlp_init(mf, cfg)

    def _moe_block_init(self, f: ParamFactory, cfg: ModelConfig) -> None:
        f.add("ln1", (cfg.d_model,), (None,), init="ones")
        af = f.subfactory("attn")
        _attn_init(af, cfg)
        f.add("ln2", (cfg.d_model,), (None,), init="ones")
        mf = f.subfactory("moe")
        moe_mod.moe_init(mf, cfg)

    # --------------------------------------------------------------- forward --
    def embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        return jnp.take(params["embed"], tokens, axis=0)

    def logits(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        out = h @ head
        if cfg.padded_vocab != cfg.vocab_size:
            # mask the padding ids so sampling/softmax never sees them
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            out = jnp.where(pad_mask, jnp.asarray(-1e30, out.dtype), out)
        return out

    def _dense_block(self, p, cfg, x, positions):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = self._constrain(x + _attn_forward(p["attn"], cfg, h, positions, self.use_kernels, self.kv_hint))
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return self._constrain(x + mlp_forward(p["mlp"], h))

    def _moe_fn(self, p, cfg, h):
        if self.moe_shard_map_mesh is not None:
            mesh = self.moe_shard_map_mesh
            dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            return moe_mod.moe_forward_shard_map(p, cfg, h, mesh, dp_axes=dp)
        return moe_mod.moe_forward(p, cfg, h, self.moe_buf_spec)

    def _moe_block(self, p, cfg, x, positions):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = self._constrain(x + _attn_forward(p["attn"], cfg, h, positions, self.use_kernels, self.kv_hint))
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        out, aux = self._moe_fn(p["moe"], cfg, h)
        return self._constrain(x + out), aux

    def _hybrid_superblock(self, p, shared, cfg, x, positions):
        for i in range(cfg.shared_attn_every):
            mp = p[f"mamba_{i}"]
            h = rmsnorm(x, mp["ln"], cfg.norm_eps)
            x = x + ssm_mod.ssm_forward(mp, cfg, h)
        h = rmsnorm(x, shared["ln"], cfg.norm_eps)
        return x + _attn_forward(shared, cfg, h, positions, self.use_kernels)

    def forward(
        self,
        params: Params,
        tokens: Optional[jax.Array] = None,
        embeds: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward.  Returns (logits, aux_loss)."""
        h, aux = self.hidden(params, tokens=tokens, embeds=embeds)
        return self.logits(params, h), aux

    def hidden(
        self,
        params: Params,
        tokens: Optional[jax.Array] = None,
        embeds: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward up to (pre-final-norm) hidden states."""
        cfg = self.cfg
        if embeds is None:
            x = self.embed(params, tokens)
        else:
            x = embeds.astype(DTYPES[cfg.dtype])
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        aux_total = jnp.zeros((), jnp.float32)

        maybe_remat = jax.checkpoint if self.remat else (lambda fn: fn)

        if cfg.arch_type in ("dense", "vlm", "audio"):
            @maybe_remat
            def body(x, lp):
                return self._dense_block(lp, cfg, x, positions), None

            x, _ = jax.lax.scan(body, x, params["layers"])
        elif cfg.arch_type == "moe":
            for i in range(cfg.first_dense_layers):
                x = self._dense_block(params[f"dense_{i}"], cfg, x, positions)

            @maybe_remat
            def body(x, lp):
                x, aux = self._moe_block(lp, cfg, x, positions)
                return x, aux

            x, auxs = jax.lax.scan(body, x, params["layers"])
            aux_total = aux_total + jnp.sum(auxs)
        elif cfg.arch_type == "ssm":
            @maybe_remat
            def body(x, lp):
                h = rmsnorm(x, lp["ln"], cfg.norm_eps)
                return x + ssm_mod.ssm_forward(lp, cfg, h), None

            x, _ = jax.lax.scan(body, x, params["layers"])
        elif cfg.arch_type == "hybrid":
            shared = params["shared_attn"]

            @maybe_remat
            def body(x, lp):
                return self._hybrid_superblock(lp, shared, cfg, x, positions), None

            x, _ = jax.lax.scan(body, x, params["layers"])
        return x, aux_total

    # ----------------------------------------------------------------- cache --
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        dtype = DTYPES[cfg.dtype]

        def stack(n, make):
            return jax.tree.map(lambda *xs: jnp.stack(xs), *[make() for _ in range(n)])

        if cfg.arch_type in ("dense", "vlm", "audio"):
            return {
                "layers": stack(
                    cfg.num_layers, lambda: _attn_init_cache(cfg, batch, max_len, dtype)
                )
            }
        if cfg.arch_type == "moe":
            out: Params = {}
            for i in range(cfg.first_dense_layers):
                out[f"dense_{i}"] = _attn_init_cache(cfg, batch, max_len, dtype)
            out["layers"] = stack(
                cfg.num_layers - cfg.first_dense_layers,
                lambda: _attn_init_cache(cfg, batch, max_len, dtype),
            )
            return out
        if cfg.arch_type == "ssm":
            return {
                "layers": stack(
                    cfg.num_layers, lambda: ssm_mod.ssm_init_cache(cfg, batch, dtype)
                )
            }
        if cfg.arch_type == "hybrid":
            def superblock():
                c = {
                    f"mamba_{i}": ssm_mod.ssm_init_cache(cfg, batch, dtype)
                    for i in range(cfg.shared_attn_every)
                }
                c["attn"] = _attn_init_cache(cfg, batch, max_len, dtype)
                return c

            return {
                "layers": stack(cfg.num_layers // cfg.shared_attn_every, superblock)
            }
        raise ValueError(cfg.arch_type)

    def cache_specs(
        self, seq_axis: Optional[str] = None, dp: Optional[Tuple[str, ...]] = None
    ) -> Params:
        cfg = self.cfg
        dp = batch_spec(self.mesh_axes) if dp is None else dp

        def with_layer(spec_tree):
            return jax.tree.map(
                lambda s: P(*((None,) + tuple(s))), spec_tree,
                is_leaf=lambda x: isinstance(x, P),
            )

        a_specs = _attn_cache_specs(cfg, dp, seq_axis)
        if cfg.arch_type in ("dense", "vlm", "audio"):
            return {"layers": with_layer(a_specs)}
        if cfg.arch_type == "moe":
            out: Params = {}
            for i in range(cfg.first_dense_layers):
                out[f"dense_{i}"] = a_specs
            out["layers"] = with_layer(a_specs)
            return out
        if cfg.arch_type == "ssm":
            return {"layers": with_layer(ssm_mod.ssm_cache_specs(cfg, dp))}
        if cfg.arch_type == "hybrid":
            sb = {
                f"mamba_{i}": ssm_mod.ssm_cache_specs(cfg, dp)
                for i in range(cfg.shared_attn_every)
            }
            sb["attn"] = a_specs
            return {"layers": with_layer(sb)}
        raise ValueError(cfg.arch_type)

    # ---------------------------------------------------------------- prefill --
    def prefill(
        self,
        params: Params,
        tokens: Optional[jax.Array] = None,
        embeds: Optional[jax.Array] = None,
        lengths: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Params]:
        """Full-sequence serving prefill: last-token logits + the decode cache
        for every layer (stacked along the scan axis).

        ``lengths`` (B,) marks right-padded ragged rows (the serving engine
        pads prompts up to ``ssm_chunk`` alignment): logits come from each
        row's true last token, SSM states are exact via dt-masking (identity
        recurrence on padded steps), and attention cache rows past a row's
        length hold garbage the decode-side validity mask never reads."""
        cfg = self.cfg
        if embeds is None:
            x = self.embed(params, tokens)
        else:
            x = embeds.astype(DTYPES[cfg.dtype])
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        cache: Params = {}

        def attn_prefill(p, h):
            if cfg.attention_kind == "mla":
                return attn.mla_prefill(p, cfg, h, positions, self.use_kernels,
                                        kv_hint=self.kv_hint)
            return attn.gqa_prefill(p, cfg, h, positions, self.use_kernels,
                                    kv_hint=self.kv_hint)

        if cfg.arch_type in ("dense", "vlm", "audio"):
            def body(x, lp):
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, c = attn_prefill(lp["attn"], h)
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                return x + mlp_forward(lp["mlp"], h), c

            x, cs = jax.lax.scan(body, x, params["layers"])
            cache["layers"] = cs
        elif cfg.arch_type == "moe":
            for i in range(cfg.first_dense_layers):
                lp = params[f"dense_{i}"]
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, c = attn_prefill(lp["attn"], h)
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                x = x + mlp_forward(lp["mlp"], h)
                cache[f"dense_{i}"] = c

            def body(x, lp):
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, c = attn_prefill(lp["attn"], h)
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                out, _ = self._moe_fn(lp["moe"], cfg, h)
                return x + out, c

            x, cs = jax.lax.scan(body, x, params["layers"])
            cache["layers"] = cs
        elif cfg.arch_type == "ssm":
            def body(x, lp):
                h = rmsnorm(x, lp["ln"], cfg.norm_eps)
                y, c = ssm_mod.ssm_prefill(lp, cfg, h, lengths)
                return x + y, c

            x, cs = jax.lax.scan(body, x, params["layers"])
            cache["layers"] = cs
        elif cfg.arch_type == "hybrid":
            shared = params["shared_attn"]

            def body(x, lp):
                c = {}
                for i in range(cfg.shared_attn_every):
                    mp = lp[f"mamba_{i}"]
                    h = rmsnorm(x, mp["ln"], cfg.norm_eps)
                    y, ci = ssm_mod.ssm_prefill(mp, cfg, h, lengths)
                    x = x + y
                    c[f"mamba_{i}"] = ci
                h = rmsnorm(x, shared["ln"], cfg.norm_eps)
                a, ca = attn_prefill(shared, h)
                c["attn"] = ca
                return x + a, c

            x, cs = jax.lax.scan(body, x, params["layers"])
            cache["layers"] = cs
        else:
            raise ValueError(cfg.arch_type)
        if lengths is None:
            last = x[:, -1:]
        else:
            last = x[jnp.arange(B), lengths - 1][:, None, :]
        return self.logits(params, last), cache

    # ----------------------------------------------------------------- decode --
    def decode_step(
        self, params: Params, cache: Params, token: jax.Array, pos: jax.Array
    ) -> Tuple[jax.Array, Params]:
        """One ragged decode step.

        token: (B, 1) int32; pos: (B,) int32 per-slot positions — each slot's
        next cache index (== its current context length) — or a scalar, which
        broadcasts (the aligned-batch special case).  ``pos[b] < 0`` marks an
        idle/padding slot: its logits are still computed (batch shape is
        static) but every cache write for it is masked, so live slots can
        never corrupt an idle slot under continuous batching.
        Returns (logits, cache)."""
        cfg = self.cfg
        B = token.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        live = pos >= 0
        x = self.embed(params, token)
        new_cache: Params = {}

        if cfg.arch_type in ("dense", "vlm", "audio"):
            def body(x, xs):
                lp, lc = xs
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nc = _attn_decode(lp["attn"], cfg, h, lc, pos, live)
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                return x + mlp_forward(lp["mlp"], h), nc

            x, ncs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncs
        elif cfg.arch_type == "moe":
            for i in range(cfg.first_dense_layers):
                lp = params[f"dense_{i}"]
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nc = _attn_decode(
                    lp["attn"], cfg, h, cache[f"dense_{i}"], pos, live
                )
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                x = x + mlp_forward(lp["mlp"], h)
                new_cache[f"dense_{i}"] = nc

            def body(x, xs):
                lp, lc = xs
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nc = _attn_decode(lp["attn"], cfg, h, lc, pos, live)
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                out, _ = self._moe_fn(lp["moe"], cfg, h)
                return x + out, nc

            x, ncs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncs
        elif cfg.arch_type == "ssm":
            def body(x, xs):
                lp, lc = xs
                h = rmsnorm(x, lp["ln"], cfg.norm_eps)
                y, nc = ssm_mod.ssm_decode(lp, cfg, h, lc, live)
                return x + y, nc

            x, ncs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncs
        elif cfg.arch_type == "hybrid":
            shared = params["shared_attn"]

            def body(x, xs):
                lp, lc = xs
                nc = {}
                for i in range(cfg.shared_attn_every):
                    mp = lp[f"mamba_{i}"]
                    h = rmsnorm(x, mp["ln"], cfg.norm_eps)
                    y, c = ssm_mod.ssm_decode(mp, cfg, h, lc[f"mamba_{i}"], live)
                    x = x + y
                    nc[f"mamba_{i}"] = c
                h = rmsnorm(x, shared["ln"], cfg.norm_eps)
                a, c = _attn_decode(shared, cfg, h, lc["attn"], pos, live)
                nc["attn"] = c
                return x + a, nc

            x, ncs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncs
        else:
            raise ValueError(cfg.arch_type)
        return self.logits(params, x), new_cache

    # ------------------------------------------------------------- paged KV --
    @property
    def supports_paged_kv(self) -> bool:
        """Paged decode covers the GQA serving hot path: architectures with
        full-attention GQA layers.  MLA's latent cache and the sliding-window
        ring keep the flat layout (reference fallback); pure-SSM models have
        no growing KV to page at all."""
        cfg = self.cfg
        return (
            cfg.arch_type != "ssm"
            and cfg.attention_kind == "gqa"
            and not cfg.sliding_window
        )

    def init_paged_cache(
        self, batch: int, num_pages: int, page_size: int, max_pages: int
    ) -> Params:
        """Cache pytree for :meth:`decode_step_paged`: per-layer page pools
        (one page id addresses a slab across all layers) plus the batch's
        page tables, which the engine refreshes host-side from its
        :class:`~repro.serving.paged_cache.PagePool` before each step."""
        cfg = self.cfg
        if not self.supports_paged_kv:
            raise ValueError(
                f"paged KV unsupported for arch_type={cfg.arch_type!r} / "
                f"attention_kind={cfg.attention_kind!r} / "
                f"sliding_window={cfg.sliding_window!r}"
            )
        dtype = DTYPES[cfg.dtype]

        def pools():
            return attn.gqa_init_paged_cache(cfg, num_pages, page_size, dtype)

        def stack(n, make):
            return jax.tree.map(lambda *xs: jnp.stack(xs), *[make() for _ in range(n)])

        out: Params = {"page_tables": jnp.zeros((batch, max_pages), jnp.int32)}
        if cfg.arch_type in ("dense", "vlm", "audio"):
            out["layers"] = stack(cfg.num_layers, pools)
        elif cfg.arch_type == "moe":
            for i in range(cfg.first_dense_layers):
                out[f"dense_{i}"] = pools()
            out["layers"] = stack(cfg.num_layers - cfg.first_dense_layers, pools)
        elif cfg.arch_type == "hybrid":
            def superblock():
                c = {
                    f"mamba_{i}": ssm_mod.ssm_init_cache(cfg, batch, dtype)
                    for i in range(cfg.shared_attn_every)
                }
                c["attn"] = pools()
                return c

            out["layers"] = stack(cfg.num_layers // cfg.shared_attn_every, superblock)
        else:
            raise ValueError(cfg.arch_type)
        return out

    def decode_step_paged(
        self, params: Params, cache: Params, token: jax.Array, pos: jax.Array
    ) -> Tuple[jax.Array, Params]:
        """Like :meth:`decode_step` but with attention KV in page pools
        (``cache`` from :meth:`init_paged_cache`).  Same ragged contract:
        per-slot ``pos``, idle slots (``pos < 0``) never touch any cache."""
        cfg = self.cfg
        B = token.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        live = pos >= 0
        pt = cache["page_tables"]
        uk = self.use_kernels
        x = self.embed(params, token)
        new_cache: Params = {"page_tables": pt}

        if cfg.arch_type in ("dense", "vlm", "audio"):
            def body(x, xs):
                lp, lc = xs
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nc = attn.gqa_decode_paged(
                    lp["attn"], cfg, h, lc, pt, pos, live, uk
                )
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                return x + mlp_forward(lp["mlp"], h), nc

            x, ncs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncs
        elif cfg.arch_type == "moe":
            for i in range(cfg.first_dense_layers):
                lp = params[f"dense_{i}"]
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nc = attn.gqa_decode_paged(
                    lp["attn"], cfg, h, cache[f"dense_{i}"], pt, pos, live, uk
                )
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                x = x + mlp_forward(lp["mlp"], h)
                new_cache[f"dense_{i}"] = nc

            def body(x, xs):
                lp, lc = xs
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nc = attn.gqa_decode_paged(
                    lp["attn"], cfg, h, lc, pt, pos, live, uk
                )
                x = x + a
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                out, _ = self._moe_fn(lp["moe"], cfg, h)
                return x + out, nc

            x, ncs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncs
        elif cfg.arch_type == "hybrid":
            shared = params["shared_attn"]

            def body(x, xs):
                lp, lc = xs
                nc = {}
                for i in range(cfg.shared_attn_every):
                    mp = lp[f"mamba_{i}"]
                    h = rmsnorm(x, mp["ln"], cfg.norm_eps)
                    y, c = ssm_mod.ssm_decode(mp, cfg, h, lc[f"mamba_{i}"], live)
                    x = x + y
                    nc[f"mamba_{i}"] = c
                h = rmsnorm(x, shared["ln"], cfg.norm_eps)
                a, c = attn.gqa_decode_paged(
                    shared, cfg, h, lc["attn"], pt, pos, live, uk
                )
                nc["attn"] = c
                return x + a, nc

            x, ncs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncs
        else:
            raise ValueError(cfg.arch_type)
        return self.logits(params, x), new_cache

    # ------------------------------------------------------ prefill scatter --
    def scatter_prefill(
        self,
        cache: Params,
        prefill_cache: Params,
        slot: int,
        length: int,
        page_ids: Optional[Sequence[int]] = None,
    ) -> Params:
        """Scatter a batch-1 :meth:`prefill` cache into slot ``slot`` of an
        engine batch cache (flat :meth:`init_cache` layout, or paged
        :meth:`init_paged_cache` layout when ``page_ids`` — the slot's
        allocated pages, covering ≥ ``length`` tokens — is given).

        ``length`` is the true prompt length; prefill rows past it (chunk
        padding) are never copied.  Runs eagerly on the host path: admit-time
        work, no jit."""
        return _scatter_node(
            cache, prefill_cache, slot, length, False, page_ids
        )


# -- prefill-scatter helpers (host-side admit path) ---------------------------


def _scatter_leaf(eng, pre, slot, length, stacked):
    """Copy one batch-1 prefill leaf into an engine cache leaf at ``slot``.

    Leaves with a sequence axis (k/v/ckv/krope; engine seq length differs
    from the prefill's padded length) copy only the first ``length`` rows;
    fixed-shape state leaves (SSM conv/state) copy whole."""
    b = 1 if stacked else 0
    s = b + 1
    if eng.ndim > s and eng.shape[s] != pre.shape[s]:
        if stacked:
            return eng.at[:, slot, :length].set(pre[:, 0, :length])
        return eng.at[slot, :length].set(pre[0, :length])
    if stacked:
        return eng.at[:, slot].set(pre[:, 0])
    return eng.at[slot].set(pre[0])


def _scatter_pages(pool, pre, page_ids, length, stacked):
    """Scatter the first ``length`` prefill k/v rows into the slot's pages:
    token t lands in (page_ids[t // page_size], t % page_size)."""
    ps = pool.shape[2 if stacked else 1]
    t = jnp.arange(length)
    pi = jnp.asarray(list(page_ids), jnp.int32)[t // ps]
    off = t % ps
    if stacked:
        return pool.at[:, pi, off].set(pre[:, 0, :length])
    return pool.at[pi, off].set(pre[0, :length])


def _scatter_node(eng, pre, slot, length, stacked, page_ids):
    if isinstance(eng, dict):
        out = {}
        for key, sub in eng.items():
            if key == "page_tables":
                out[key] = sub  # refreshed host-side by the engine
            elif key == "pool_k":
                out[key] = _scatter_pages(sub, pre["k"], page_ids, length, stacked)
            elif key == "pool_v":
                out[key] = _scatter_pages(sub, pre["v"], page_ids, length, stacked)
            else:
                out[key] = _scatter_node(
                    sub, pre[key], slot, length, stacked or key == "layers",
                    page_ids,
                )
        return out
    return _scatter_leaf(eng, pre, slot, length, stacked)
