"""Bridge: assigned architectures → scheduler performance profiles.

This is the beyond-paper closed loop (DESIGN.md §7.1): the same architecture
configs the dry-run compiles are turned into :class:`ArchPerfSpec`s, so
:class:`RooflineProfiles` can hand the MIG-Serving optimizer analytically-
derived (throughput, latency) numbers per (arch × TPU slice size).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.configs import ARCH_IDS, get_config
from repro.core.profiles import ArchPerfSpec, RooflineProfiles


def arch_perf_specs(
    arch_ids: Optional[Sequence[str]] = None, context: int = 4096
) -> List[ArchPerfSpec]:
    out = []
    for aid in arch_ids or ARCH_IDS:
        cfg = get_config(aid)
        out.append(
            ArchPerfSpec(
                name=aid,
                params=cfg.param_count(),
                active_params=cfg.active_param_count(),
                kv_bytes_per_token=cfg.kv_bytes_per_token(),
                context=context,
            )
        )
    return out


def tpu_arch_profiles(
    arch_ids: Optional[Sequence[str]] = None,
    context: int = 4096,
    sizes: Sequence[int] = (16, 32, 64, 128, 256),
) -> RooflineProfiles:
    """Default slice sizes are pod-granularity (PodSliceRules) — the only
    granularity on which every assigned arch fits (DESIGN.md §4)."""
    return RooflineProfiles(arch_perf_specs(arch_ids, context), sizes=sizes)
