"""The scheduler zoo: alternative RMS scheduling policies (§3, §7).

The paper frames MIG serving as one instance of the Reconfigurable Machine
Scheduling Problem and its pipeline as one point in a family of algorithms
("MIG-SERVING is designed to be able to switch algorithms easily", §7).
This module adds two competitors from the retrieved MIG-scheduling
literature, both plugging into :data:`repro.core.optimizer.FAST_ALGORITHMS`
/ ``SLOW_ALGORITHMS`` so the closed-loop simulator benchmarks them without
modification:

  * :class:`FragAwarePacker` — an online fragmentation-aware packer in the
    spirit of arXiv:2512.16099: candidate GPU configs are scored by the
    greedy need-weighted utility *discounted by residual-slice
    fragmentation* — slices a pick would strand, either statically (idle
    instances / unpartitionable slack no allocatable size can reuse) or
    dynamically (slices whose throughput overshoots the residual need of
    an almost-satisfied service).

  * :class:`EnergyAwareRepartitioner` — energy-efficient dynamic
    repartitioning in the spirit of arXiv:2606.25082: candidates are scored
    by SLO progress *per watt* under a per-GPU-slice :class:`PowerModel`
    with a per-instance overhead term, so at equal throughput the policy
    prefers fewer/larger instances (and the periodic reoptimize loop
    repartitions toward them as demand moves).

Both are array-native per the PR 2 performance contract: per-config factor
vectors are precomputed once from :class:`ConfigSpace`, each round is one
``argmax`` over an incrementally-maintained score vector (only configs
touching the services a pick changed are re-scored), and
``produce_indexed`` emits an :class:`IndexedDeployment` count vector
directly.  Both are deterministic: score ties break by ascending config
index (``np.argmax`` takes the first maximum), and the ``seed`` argument
exists only for registry-API symmetry with the stochastic algorithms.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.core.deployment import (
    ConfigSpace,
    GPUConfig,
    IndexedDeployment,
    OptimizerProcedure,
)
from repro.core.rms import ReconfigRules


class WeightedScoreGreedy(OptimizerProcedure):
    """Greedy over a re-weighted pair-space score, maintained incrementally.

    Subclasses shape the per-config score through :meth:`_scores` (default:
    the greedy need-weighted utility times a fixed positive ``weights``
    vector).  The hook must preserve score *positivity* — zero only where
    the base score is zero — so this loop terminates exactly when the plain
    greedy does.  Unlike :class:`repro.core.greedy.GreedyFast` there is no
    packed multi-service candidate: the zoo policies choose from the
    enumerated pair space only, which keeps every pick an enumerated config
    index (the count vector never needs ``extras``).
    """

    def __init__(
        self,
        space: ConfigSpace,
        weights: Optional[np.ndarray] = None,
        seed: int = 0,
    ):
        super().__init__(space)
        if weights is None:
            weights = np.ones(len(space))
        weights = np.asarray(weights, dtype=np.float64)
        if weights.shape != (len(space),):
            raise ValueError(
                f"one weight per config: got shape {weights.shape}, "
                f"expected ({len(space)},)"
            )
        if not np.all(weights > 0.0):
            raise ValueError("weights must be positive")
        self.weights = weights
        self.seed = seed  # deterministic policy; kept for registry symmetry

    def _scores(self, need: np.ndarray, idx: Optional[np.ndarray] = None) -> np.ndarray:
        """Scores of all configs (``idx is None``) or of the subset ``idx``
        against the residual ``need`` vector."""
        space = self.space
        if idx is None:
            return (need[space.ia] * space.ua + need[space.ib] * space.ub) * self.weights
        return (
            need[space.ia[idx]] * space.ua[idx] + need[space.ib[idx]] * space.ub[idx]
        ) * self.weights[idx]

    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        configs, _ = self._produce(completion)
        return configs

    def produce_indexed(self, completion: np.ndarray) -> IndexedDeployment:
        """``produce`` in the array-native representation."""
        _, counts = self._produce(completion)
        return IndexedDeployment(self.space, counts)

    def _produce(
        self, completion: np.ndarray
    ) -> Tuple[List[GPUConfig], np.ndarray]:
        space = self.space
        ia, ib, ua, ub = space.ia, space.ib, space.ua, space.ub
        c = completion.astype(np.float64).copy()
        need = np.clip(1.0 - c, 0.0, None)
        scores = self._scores(need)
        out: List[GPUConfig] = []
        counts = np.zeros(len(space), dtype=np.int64)
        guard = 0
        while np.any(c < 1.0 - 1e-9):
            guard += 1
            if guard > 100_000:
                raise RuntimeError(f"{type(self).__name__} failed to converge")
            idx = int(np.argmax(scores)) if len(scores) else 0
            if not len(scores) or scores[idx] <= 0.0:
                raise RuntimeError(
                    "no config has positive score but SLOs unmet — "
                    "some service is infeasible on every instance size"
                )
            out.append(space.configs[idx])
            counts[idx] += 1
            i, j = int(ia[idx]), int(ib[idx])
            c[i] += ua[idx]
            c[j] += ub[idx]
            changed = (i,) if i == j else (i, j)
            for k in changed:
                need[k] = max(0.0, 1.0 - c[k])
            upd = (
                space.service_configs[changed[0]]
                if len(changed) == 1
                else np.concatenate([space.service_configs[k] for k in changed])
            )
            scores[upd] = self._scores(need, upd)
        return out, counts


# ---------------------------------------------------------------------------
# Fragmentation-aware online packing (arXiv:2512.16099)
# ---------------------------------------------------------------------------


def stranded_slices_of(cfg: GPUConfig, rules: ReconfigRules) -> float:
    """Statically stranded residual slices of one GPU config.

    Free capacity is every slice not serving a request: idle instances plus
    unpartitioned slack.  The *stranded* part is what remains after the
    largest allocatable instance size that fits in the largest free chunk is
    carved back out — free capacity no future service could be handed as one
    instance, the fragmentation the online scheduler in arXiv:2512.16099
    packs around.  ``0`` for a fully busy device.
    """
    idle_sizes = [a.size for a in cfg.assignments if a.service is None]
    slack = rules.device_size - sum(a.size for a in cfg.assignments)
    free = sum(idle_sizes) + slack
    if free == 0:
        return 0.0
    chunks = idle_sizes + ([slack] if slack > 0 else [])
    largest_chunk = max(chunks)
    usable = max((s for s in rules.instance_sizes if s <= largest_chunk), default=0)
    return float(free - usable + 0.5 * usable)  # reusable free still costs half


class FragAwarePacker(WeightedScoreGreedy):
    """Fragmentation-aware online packer.

    score(config) = base greedy score / (1 + frag_weight * frag(config, need))

    where ``frag`` counts the device's residual-slice fragmentation as a
    fraction of the device, from two sources:

      * **static** — idle instances and dead slack
        (:func:`stranded_slices_of`), fixed per config;
      * **dynamic** — the share of the config's busy slices whose throughput
        overshoots the residual need (capacity stranded past an
        almost-satisfied service's SLO), recomputed as completion moves.

    A config that exactly covers the remaining need on a full device keeps
    the plain greedy score; one that strands slices is dispreferred in
    proportion — the packer trades immediate utility for partitions whose
    capacity stays useful.
    """

    def __init__(self, space: ConfigSpace, frag_weight: float = 4.0, seed: int = 0):
        super().__init__(space, seed=seed)
        self.frag_weight = frag_weight
        dsize = float(space.rules.device_size)
        self.static_frag = np.array(
            [stranded_slices_of(cfg, space.rules) / dsize for cfg in space.configs],
            dtype=np.float64,
        )
        self.busy_frac = np.array(
            [
                sum(a.size for a in cfg.assignments if a.service is not None) / dsize
                for cfg in space.configs
            ],
            dtype=np.float64,
        )

    def _scores(self, need: np.ndarray, idx: Optional[np.ndarray] = None) -> np.ndarray:
        space = self.space
        if idx is None:
            na, nb = need[space.ia], need[space.ib]
            ua, ub = space.ua, space.ub
            static, busy = self.static_frag, self.busy_frac
        else:
            na, nb = need[space.ia[idx]], need[space.ib[idx]]
            ua, ub = space.ua[idx], space.ub[idx]
            static, busy = self.static_frag[idx], self.busy_frac[idx]
        base = na * ua + nb * ub
        # single-service configs carry ub == 0, so the b-side overshoot is 0
        over = np.maximum(ua - na, 0.0) + np.maximum(ub - nb, 0.0)
        frag = static + busy * (over / (ua + ub))
        return base / (1.0 + self.frag_weight * frag)


# ---------------------------------------------------------------------------
# Energy-aware dynamic repartitioning (arXiv:2606.25082)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Per-GPU-slice power model (A100-flavored defaults, ~400 W TDP).

    ``power(config) = base_w + slice_w * busy_slices + instance_w * n_instances``:
    a static floor for the powered device, a linear term per active compute
    slice, and a per-running-instance overhead (MIG runtime / context
    residency) — the overhead term is what makes fewer/larger instances
    cheaper at equal slice count, the preference arXiv:2606.25082 exploits.
    """

    base_w: float = 60.0
    slice_w: float = 40.0
    instance_w: float = 15.0

    def config_power(self, cfg: GPUConfig) -> float:
        active = [a for a in cfg.assignments if a.service is not None]
        busy = sum(a.size for a in active)
        return self.base_w + self.slice_w * busy + self.instance_w * len(active)

    def instances_power(
        self, instances: Iterable[Tuple[str, int, float]], gpus_in_use: int
    ) -> float:
        """Power of a live instance set (``(service, size, tput)`` triples,
        e.g. ``SimulatedCluster.busy_instances().values()``) across
        ``gpus_in_use`` powered devices."""
        watts = self.base_w * gpus_in_use
        for _svc, size, _tput in instances:
            watts += self.slice_w * size + self.instance_w
        return watts


class EnergyAwareRepartitioner(WeightedScoreGreedy):
    """Energy-aware scheduler: greedy score per watt.

    Each candidate's need-weighted utility is divided by its modeled power
    draw (normalized by a full-device reference so weights stay O(1)); at
    equal throughput the policy picks the config with fewer/larger
    instances.  Run inside the closed loop's periodic reoptimization it
    *repartitions* toward energy-lean deployments as demand moves — the
    dynamic-repartitioning setting of arXiv:2606.25082.
    """

    def __init__(
        self,
        space: ConfigSpace,
        power_model: PowerModel = PowerModel(),
        seed: int = 0,
    ):
        self.power_model = power_model
        power = np.array(
            [power_model.config_power(cfg) for cfg in space.configs],
            dtype=np.float64,
        )
        ref = (
            power_model.base_w
            + power_model.slice_w * space.rules.device_size
            + power_model.instance_w
        )
        super().__init__(space, ref / power, seed=seed)
        self.power = power


def deployment_power(
    configs: Iterable[GPUConfig], model: PowerModel = PowerModel()
) -> float:
    """Total modeled watts of a deployment (sum of per-config power)."""
    return sum(model.config_power(cfg) for cfg in configs)
