"""The tailored Genetic Algorithm gluing fast and slow algorithms (§5.2).

Chromosome = deployment; gene = GPU config.

  * **Crossover** (paper §5.2): randomly erase some GPU configs — completion
    drops below 100% — then run the *slow algorithm* against the residual to
    refill.  This mixes fast- and slow-algorithm genes and keeps the slow
    algorithm's problem size small.
  * **Mutation**: swap services between equal-sized instances running
    different services (inference has no affinity, §5.2).  Mutations do not
    change completion rates — they diversify the service mixes crossover can
    later split.

GA keeps the originals in each round's selection (elitism), so the best
deployment only improves; it stops on timeout/rounds or when the best stopped
improving for ``patience`` rounds (paper: ten).
"""

from __future__ import annotations

import dataclasses
import time  # contract-ok: wall-clock anytime-budget deadline only; sim time stays logical
from collections import Counter
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.deployment import (
    ConfigSpace,
    Deployment,
    GPUConfig,
    InstanceAssignment,
    OptimizerProcedure,
)


def _fitness(dep: Deployment, space: ConfigSpace) -> Tuple[int, float]:
    """Primary: fewer devices.  Secondary: less over-provisioned throughput
    (slack), so equal-GPU deployments with tighter packing rank better."""
    c = dep.completion_rates(space.workload)
    return (dep.num_gpus, float(np.sum(np.clip(c - 1.0, 0.0, None))))


def fitness_batch(
    deps: Sequence[Deployment], space: ConfigSpace
) -> List[Tuple[int, float]]:
    """Fitness of a whole population in one vectorized pass.

    Bit-identical to ``[_fitness(d, space) for d in deps]``: each config's
    exact utility vector is computed once (memoized per config *object* by
    ``space.utility_cached``) and accumulated into a ``(P, n)`` completion
    matrix row by row *in deployment config order* — that sequential
    accumulation order is load-bearing: it reproduces the legacy
    config-by-config summation float-for-float, so the GA's selection order
    (and therefore its seeded output) is unchanged.  Do not replace it with
    an order-changing scatter (``np.add.at`` over a globally stacked index
    array is fine only if rows stay grouped per deployment in config order);
    the slack reduction over the matrix stays vectorized.
    """
    if not deps:
        return []
    comp = np.zeros((len(deps), space.workload.n))
    for p, dep in enumerate(deps):
        row = comp[p]
        for cfg in dep.configs:
            row += space.utility_cached(cfg)
    slack = np.sum(np.clip(comp - 1.0, 0.0, None), axis=1)
    return [(dep.num_gpus, float(s)) for dep, s in zip(deps, slack)]


def _canonical_counter(dep: Deployment) -> Counter:
    return Counter(cfg.canonical() for cfg in dep.configs)


def deployment_edit_distance(a: Deployment, b: Deployment) -> int:
    """Devices to add plus devices to remove to turn ``a`` into ``b``.

    Configs compare by canonical form — instances of equal size are
    interchangeable (§5.2), so reordering is free.  The §6 controller's
    transition cost is roughly proportional to this count (each differing
    device is a destroy and/or create), which is why the warm-start path
    bounds it.
    """
    ca, cb = _canonical_counter(a), _canonical_counter(b)
    return sum((ca - cb).values()) + sum((cb - ca).values())


def mutate_swap(dep: Deployment, rng: np.random.Generator, swaps: int = 4) -> Deployment:
    """Swap services between same-size instances of different configs.

    Candidate filtering runs on flat size/service arrays (services swap as
    integer ids alongside the assignment objects); ``np.flatnonzero``
    preserves the scan order of the original list comprehension, so the
    seeded swap sequence is unchanged.
    """
    configs = [list(c.assignments) for c in dep.configs]
    sid: dict = {}
    items = [
        (gi, ii, a.size, sid.setdefault(a.service, len(sid)))
        for gi, assigns in enumerate(configs)
        for ii, a in enumerate(assigns)
        if a.service is not None
    ]
    flat: List[Tuple[int, int]] = [(gi, ii) for gi, ii, _, _ in items]
    size_arr = np.array([t[2] for t in items], dtype=np.int64)
    svc_arr = np.array([t[3] for t in items], dtype=np.int64)
    touched = set()
    for _ in range(swaps):
        if len(flat) < 2:
            break
        i1 = int(rng.integers(len(flat)))
        # same-size instances running a different service; the picked slot
        # itself is excluded for free (its service equals its own)
        cands = np.flatnonzero(
            (size_arr == size_arr[i1]) & (svc_arr != svc_arr[i1])
        )
        if not len(cands):
            continue
        j = int(cands[rng.integers(len(cands))])
        g1, a1 = flat[i1]
        g2, a2 = flat[j]
        s1, s2 = configs[g1][a1], configs[g2][a2]
        configs[g1][a1], configs[g2][a2] = (
            InstanceAssignment(s1.size, s2.service, s2.batch, s2.throughput),
            InstanceAssignment(s2.size, s1.service, s1.batch, s1.throughput),
        )
        svc_arr[i1], svc_arr[j] = svc_arr[j], svc_arr[i1]
        touched.add(g1)
        touched.add(g2)
    # untouched configs keep their objects (and their memoized canonical /
    # utility), so downstream batched fitness stays warm
    return Deployment(
        [
            GPUConfig(dep.configs[gi].partition, tuple(configs[gi]))
            if gi in touched
            else dep.configs[gi]
            for gi in range(len(configs))
        ]
    )


def crossover(
    dep: Deployment,
    space: ConfigSpace,
    slow: OptimizerProcedure,
    rng: np.random.Generator,
    erase_frac: float = 0.25,
) -> Deployment:
    """Erase a random subset of configs and refill with the slow algorithm."""
    n = dep.num_gpus
    k = max(1, int(round(erase_frac * n)))
    erase = set(rng.choice(n, size=min(k, n), replace=False).tolist())
    kept = [c for i, c in enumerate(dep.configs) if i not in erase]
    c = np.zeros(space.workload.n)
    for cfg in kept:
        c += space.utility_cached(cfg)  # exact per-config utility, memoized
    refill = slow.produce(c)
    return Deployment(kept + refill)


@dataclasses.dataclass
class GAResult:
    best: Deployment
    history: List[int]  # best num_gpus per round (round 0 = seed)


class GeneticOptimizer:
    """§5.2 two-phase glue: population of deployments evolved by
    crossover(slow-algorithm refill) + mutation(swap)."""

    def __init__(
        self,
        space: ConfigSpace,
        slow: OptimizerProcedure,
        population: int = 6,
        rounds: int = 10,
        patience: int = 10,
        erase_frac: float = 0.25,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
    ):
        self.space = space
        self.slow = slow
        self.population = population
        self.rounds = rounds
        self.patience = patience
        self.erase_frac = erase_frac
        self.rng = np.random.default_rng(seed)
        self.time_budget_s = time_budget_s

    def run(
        self,
        seed_deployment: Deployment,
        incumbent: Optional[Deployment] = None,
        edit_budget: Optional[int] = None,
    ) -> GAResult:
        # Warm start: with an incumbent and an edit budget, children whose
        # edit distance from the incumbent exceeds the budget are discarded
        # *after* the rng has been consumed for them — the random stream is
        # identical with and without the bound, only selection changes.
        inc_counter: Optional[Counter] = None
        if incumbent is not None and edit_budget is not None:
            inc_counter = _canonical_counter(incumbent)
        space = self.space
        pop: List[Deployment] = [seed_deployment]
        # diversify the initial population with mutated copies
        while len(pop) < self.population:
            pop.append(mutate_swap(seed_deployment, self.rng))
        history = [min(p.num_gpus for p in pop)]
        fits = fitness_batch(pop, space)
        bi = min(range(len(pop)), key=fits.__getitem__)
        best, best_fit = pop[bi], fits[bi]
        stale = 0
        t0 = time.monotonic()
        for _ in range(self.rounds):
            if self.time_budget_s and time.monotonic() - t0 > self.time_budget_s:
                break
            children: List[Deployment] = []
            for parent in pop:
                child = crossover(parent, space, self.slow, self.rng, self.erase_frac)
                children.append(mutate_swap(child, self.rng))
            if inc_counter is not None:
                kept = []
                for ch in children:
                    cc = _canonical_counter(ch)
                    dist = sum((cc - inc_counter).values()) + sum(
                        (inc_counter - cc).values()
                    )
                    if dist <= edit_budget:
                        kept.append(ch)
                children = kept
            # elitism: originals compete with children (§5.2); the whole
            # merged population is scored in one batched call, then
            # decorate-sort-undecorate keeps the stable ordering
            merged = pop + children
            fits = fitness_batch(merged, space)
            order = sorted(range(len(merged)), key=fits.__getitem__)
            pop = [merged[i] for i in order[: self.population]]
            new_best, new_fit = pop[0], fits[order[0]]
            if new_fit < best_fit:
                best, best_fit = new_best, new_fit
                stale = 0
            else:
                stale += 1
            history.append(best.num_gpus)
            if stale >= self.patience:
                break
        # same accumulation as Deployment.is_valid, from the utility memo
        comp = np.zeros(space.workload.n)
        for cfg in best.configs:
            comp += space.utility_cached(cfg)
        if not bool(np.all(comp >= 1.0 - 1e-9)):
            raise RuntimeError(
                "GA best individual fails SLO completion — repair should have "
                f"kept every service >= 1.0, got min {float(comp.min()):.6f}"
            )
        return GAResult(best=best, history=history)
