"""The tailored Genetic Algorithm gluing fast and slow algorithms (§5.2).

Chromosome = deployment; gene = GPU config.

  * **Crossover** (paper §5.2): randomly erase some GPU configs — completion
    drops below 100% — then run the *slow algorithm* against the residual to
    refill.  This mixes fast- and slow-algorithm genes and keeps the slow
    algorithm's problem size small.
  * **Mutation**: swap services between equal-sized instances running
    different services (inference has no affinity, §5.2).  Mutations do not
    change completion rates — they diversify the service mixes crossover can
    later split.

GA keeps the originals in each round's selection (elitism), so the best
deployment only improves; it stops on timeout/rounds or when the best stopped
improving for ``patience`` rounds (paper: ten).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.deployment import (
    ConfigSpace,
    Deployment,
    GPUConfig,
    InstanceAssignment,
    OptimizerProcedure,
)


def _fitness(dep: Deployment, space: ConfigSpace) -> Tuple[int, float]:
    """Primary: fewer devices.  Secondary: less over-provisioned throughput
    (slack), so equal-GPU deployments with tighter packing rank better."""
    c = dep.completion_rates(space.workload)
    return (dep.num_gpus, float(np.sum(np.clip(c - 1.0, 0.0, None))))


def mutate_swap(dep: Deployment, rng: np.random.Generator, swaps: int = 4) -> Deployment:
    """Swap services between same-size instances of different configs."""
    configs = [list(c.assignments) for c in dep.configs]
    flat = [
        (gi, ii)
        for gi, assigns in enumerate(configs)
        for ii, a in enumerate(assigns)
        if a.service is not None
    ]
    for _ in range(swaps):
        if len(flat) < 2:
            break
        i1 = rng.integers(len(flat))
        g1, a1 = flat[i1]
        s1 = configs[g1][a1]
        cands = [
            (g, a)
            for (g, a) in flat
            if configs[g][a].size == s1.size
            and configs[g][a].service != s1.service
            and (g, a) != (g1, a1)
        ]
        if not cands:
            continue
        g2, a2 = cands[rng.integers(len(cands))]
        s2 = configs[g2][a2]
        configs[g1][a1], configs[g2][a2] = (
            InstanceAssignment(s1.size, s2.service, s2.batch, s2.throughput),
            InstanceAssignment(s2.size, s1.service, s1.batch, s1.throughput),
        )
    return Deployment(
        [
            GPUConfig(dep.configs[gi].partition, tuple(assigns))
            for gi, assigns in enumerate(configs)
        ]
    )


def crossover(
    dep: Deployment,
    space: ConfigSpace,
    slow: OptimizerProcedure,
    rng: np.random.Generator,
    erase_frac: float = 0.25,
) -> Deployment:
    """Erase a random subset of configs and refill with the slow algorithm."""
    n = dep.num_gpus
    k = max(1, int(round(erase_frac * n)))
    erase = set(rng.choice(n, size=min(k, n), replace=False).tolist())
    kept = [c for i, c in enumerate(dep.configs) if i not in erase]
    c = np.zeros(space.workload.n)
    for cfg in kept:
        c += cfg.utility(space.workload)
    refill = slow.produce(c)
    return Deployment(kept + refill)


@dataclasses.dataclass
class GAResult:
    best: Deployment
    history: List[int]  # best num_gpus per round (round 0 = seed)


class GeneticOptimizer:
    """§5.2 two-phase glue: population of deployments evolved by
    crossover(slow-algorithm refill) + mutation(swap)."""

    def __init__(
        self,
        space: ConfigSpace,
        slow: OptimizerProcedure,
        population: int = 6,
        rounds: int = 10,
        patience: int = 10,
        erase_frac: float = 0.25,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
    ):
        self.space = space
        self.slow = slow
        self.population = population
        self.rounds = rounds
        self.patience = patience
        self.erase_frac = erase_frac
        self.rng = np.random.default_rng(seed)
        self.time_budget_s = time_budget_s

    def run(self, seed_deployment: Deployment) -> GAResult:
        space = self.space
        pop: List[Deployment] = [seed_deployment]
        # diversify the initial population with mutated copies
        while len(pop) < self.population:
            pop.append(mutate_swap(seed_deployment, self.rng))
        history = [min(p.num_gpus for p in pop)]
        best = min(pop, key=lambda d: _fitness(d, space))
        stale = 0
        t0 = time.monotonic()
        for _ in range(self.rounds):
            if self.time_budget_s and time.monotonic() - t0 > self.time_budget_s:
                break
            children: List[Deployment] = []
            for parent in pop:
                child = crossover(parent, space, self.slow, self.rng, self.erase_frac)
                children.append(mutate_swap(child, self.rng))
            # elitism: originals compete with children (§5.2)
            merged = pop + children
            merged.sort(key=lambda d: _fitness(d, space))
            pop = merged[: self.population]
            new_best = pop[0]
            if _fitness(new_best, space) < _fitness(best, space):
                best = new_best
                stale = 0
            else:
                stale += 1
            history.append(best.num_gpus)
            if stale >= self.patience:
                break
        assert best.is_valid(space.workload)
        return GAResult(best=best, history=history)
