"""Performance profiles: throughput/latency of a service on each instance size.

The optimizer (§5) consumes only a profile: for service *m* on an instance of
size *s*, what throughput can it sustain with per-request latency below the
SLO?  The paper measured 49 hub models on A100 instances (§2.2, Appendix B);
this module provides two profile sources:

  * :class:`SyntheticPaperProfiles` — a seeded generator reproducing the
    paper's measurement-study *shape*: sub-linear / linear / super-linear
    scaling classes, batch-dependent latency, minimum instance sizes for
    large models.  Used for the paper-faithful experiments (Figures 4/9/12…).

  * :class:`RooflineProfiles` — the beyond-paper closed loop (DESIGN.md §7):
    profiles *derived* from an analytic TPU roofline over the assigned
    architectures (weights/KV bytes vs FLOPs on a slice of ``s`` chips),
    so the scheduler consumes the same numbers the dry-run roofline reports.

Both implement :class:`PerfProfile`.

Latency model (both sources): a serving instance runs requests at batch ``b``;
``latency(m, s, b)`` must stay under the SLO.  MIG-Serving "always chooses the
largest batch sizes possible, as far as the inference latency is smaller than
what required by SLOs" (§7 of the paper) — :meth:`PerfProfile.throughput`
implements exactly that.
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

BATCH_CANDIDATES: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)


class PerfProfile(abc.ABC):
    """Throughput/latency oracle consumed by the optimizer."""

    @abc.abstractmethod
    def services(self) -> List[str]:
        ...

    @abc.abstractmethod
    def sizes(self) -> Sequence[int]:
        """Instance sizes this profile covers (must match the rule-set)."""

    @abc.abstractmethod
    def latency_ms(self, model: str, size: int, batch: int) -> float:
        """Per-request latency at the given batch (inf if infeasible)."""

    def feasible(self, model: str, size: int) -> bool:
        return math.isfinite(self.latency_ms(model, size, 1))

    def min_size(self, model: str) -> int:
        for s in sorted(self.sizes()):
            if self.feasible(model, s):
                return s
        raise ValueError(f"{model} fits on no instance size")

    def best_batch(self, model: str, size: int, latency_slo_ms: float) -> int:
        """Largest batch whose latency meets the SLO (0 if none)."""
        best = 0
        for b in BATCH_CANDIDATES:
            if self.latency_ms(model, size, b) <= latency_slo_ms:
                best = b
        return best

    def throughput(self, model: str, size: int, latency_slo_ms: float) -> float:
        """Sustained req/s on one instance at the best SLO-compliant batch."""
        b = self.best_batch(model, size, latency_slo_ms)
        if b == 0:
            return 0.0
        return b * 1000.0 / self.latency_ms(model, size, b)

    # -- the paper's §2.2 classification --------------------------------------
    def classify(self, model: str, latency_slo_ms: float = 1e9) -> str:
        """sub-linear / linear / super-linear, per §2.2's ratio test,
        normalized so the thresholds [6.5, 7.5]/7 transfer to any device size."""
        sizes = sorted(self.sizes())
        full = sizes[-1]
        smallest = self.min_size(model)
        unit = self.throughput(model, smallest, latency_slo_ms) / smallest
        if unit <= 0:
            return "infeasible"
        ratio = self.throughput(model, full, latency_slo_ms) / unit
        lo, hi = 6.5 / 7.0 * full, 7.5 / 7.0 * full
        if ratio < lo:
            return "sub-linear"
        if ratio > hi:
            return "super-linear"
        return "linear"


# ---------------------------------------------------------------------------
# Synthetic paper-like profiles
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _SyntheticModel:
    name: str
    unit_tput: float  # req/s per slice-unit at saturation on min instance
    alpha: float  # throughput ~ size**alpha  (alpha<1 sub-linear, >1 super)
    overhead_ms: float  # fixed per-batch launch overhead
    min_size: int  # smallest instance the model fits on


class SyntheticPaperProfiles(PerfProfile):
    """Seeded generator mirroring the paper's 49-model study (§2.2, App. B).

    Scaling classes are drawn so that non-linear models are prevalent
    (the paper's Figure 4): roughly 45% sub-linear, 30% linear, 25%
    super-linear at moderate batch sizes.
    """

    def __init__(
        self,
        n_models: int = 24,
        seed: int = 0,
        sizes: Sequence[int] = (1, 2, 3, 4, 7),
    ):
        rng = np.random.default_rng(seed)
        self._sizes = tuple(sizes)
        full = max(sizes)
        self._models: Dict[str, _SyntheticModel] = {}
        classes = rng.choice(
            ["sub", "lin", "sup"], size=n_models, p=[0.45, 0.30, 0.25]
        )
        for i in range(n_models):
            cls = classes[i]
            if cls == "sub":
                alpha = float(rng.uniform(0.55, 0.85))
            elif cls == "lin":
                alpha = float(rng.uniform(0.95, 1.05))
            else:
                alpha = float(rng.uniform(1.15, 1.45))
            unit = float(rng.uniform(40.0, 400.0))
            overhead = float(rng.uniform(1.0, 6.0))
            # ~20% of models are "large": need a 2- or 3-slice instance
            if rng.random() < 0.2:
                min_size = int(rng.choice([s for s in sizes if 1 < s < full]))
            else:
                min_size = min(sizes)
            name = f"model{i:02d}-{cls}"
            self._models[name] = _SyntheticModel(name, unit, alpha, overhead, min_size)

    def services(self) -> List[str]:
        return list(self._models)

    def sizes(self) -> Sequence[int]:
        return self._sizes

    def latency_ms(self, model: str, size: int, batch: int) -> float:
        m = self._models[model]
        if size < m.min_size:
            return math.inf
        rate = m.unit_tput * (size ** m.alpha)  # req/s at saturation
        return m.overhead_ms + batch * 1000.0 / rate


# ---------------------------------------------------------------------------
# Roofline-derived profiles (beyond-paper; DESIGN.md §7.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchPerfSpec:
    """The numbers the analytic roofline needs about one architecture.

    Derived from the arch configs (``repro.configs``): parameter counts and
    per-token KV/state bytes.  ``active_params`` < ``params`` for MoE.
    """

    name: str
    params: float  # total parameters
    active_params: float  # parameters touched per token (MoE: shared+top-k)
    kv_bytes_per_token: float  # decode cache traffic per token per request
    context: int = 4096  # typical serving context for the profile


@dataclasses.dataclass(frozen=True)
class TpuChip:
    flops: float = 197e12  # bf16 FLOP/s (v5e)
    hbm_bw: float = 819e9  # bytes/s
    hbm_bytes: float = 16e9  # capacity
    ici_bw: float = 50e9  # bytes/s per link


class RooflineProfiles(PerfProfile):
    """Decode-roofline profile: latency of one decode step on an ``s``-chip
    slice at batch ``b`` is

        max( weights_active/(s·BW) + b·kv_ctx/(s·BW),   2·N_active·b/(s·F) )
        + dispatch overhead

    Weight streaming dominates small batches (memory-bound → per-chip
    throughput grows super-linearly with slice size at a fixed latency SLO,
    the paper's xlnet regime); KV streaming dominates long contexts
    (sub-linear, densenet regime).  A model is infeasible on a slice whose
    aggregate HBM cannot hold weights + cache headroom — the paper's
    "smallest instance that can run M".
    """

    def __init__(
        self,
        archs: Sequence[ArchPerfSpec],
        sizes: Sequence[int] = (1, 2, 4, 8, 16),
        chip: TpuChip = TpuChip(),
        dtype_bytes: float = 2.0,
        overhead_ms: float = 0.3,
    ):
        self._archs = {a.name: a for a in archs}
        self._sizes = tuple(sizes)
        self.chip = chip
        self.dtype_bytes = dtype_bytes
        self.overhead_ms = overhead_ms

    def services(self) -> List[str]:
        return list(self._archs)

    def sizes(self) -> Sequence[int]:
        return self._sizes

    def latency_ms(self, model: str, size: int, batch: int) -> float:
        a = self._archs[model]
        c = self.chip
        weight_bytes = a.params * self.dtype_bytes
        kv_ctx = a.kv_bytes_per_token * a.context
        hbm_need = weight_bytes + batch * kv_ctx
        if hbm_need > 0.9 * size * c.hbm_bytes:
            return math.inf
        mem_s = (a.active_params * self.dtype_bytes + batch * kv_ctx) / (
            size * c.hbm_bw
        )
        comp_s = 2.0 * a.active_params * batch / (size * c.flops)
        return (max(mem_s, comp_s)) * 1000.0 + self.overhead_ms
