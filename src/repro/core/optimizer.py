"""The two-phase optimizer pipeline (§5.2, Figure 6) and algorithm registry.

Phase 1 runs the *fast algorithm* (greedy) to get a valid deployment quickly;
phase 2 runs the tailored GA whose crossover refills with the *slow
algorithm* (MCTS).  Both template algorithms are ``OptimizerProcedure``
subclasses and can be swapped (§7: "MIG-SERVING is designed to be able to
switch algorithms easily") — the registry also exposes the beyond-paper
``beam`` fast algorithm (DESIGN.md §7.2).
"""

from __future__ import annotations

import dataclasses
import math
import time  # contract-ok: wall-clock anytime-budget deadline only; sim time stays logical
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.deployment import (
    ConfigSpace,
    Deployment,
    GPUConfig,
    IndexedDeployment,
    OptimizerProcedure,
)
from repro.core.ga import GAResult, GeneticOptimizer
from repro.core.greedy import GreedyFast, warm_repair
from repro.core.mcts import MCTSSlow
from repro.core.profiles import PerfProfile
from repro.core.rms import ReconfigRules
from repro.core.deployment import Workload
from repro.core.zoo import EnergyAwareRepartitioner, FragAwarePacker


class BeamGreedy(OptimizerProcedure):
    """Beyond-paper fast algorithm: beam search of width B over the same
    heuristic score.  B=1 degenerates to the paper's greedy; B>1 keeps the
    B best partial deployments per round and returns the shortest finisher."""

    def __init__(self, space: ConfigSpace, beam: int = 4, branch: int = 4):
        super().__init__(space)
        self.beam = beam
        self.branch = branch

    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        space = self.space
        # state: (neg potential, completion, config-idx list)
        beams = [(completion.astype(np.float64).copy(), [])]
        done: Optional[List[int]] = None
        for _ in range(100_000):
            nxt = []
            for c, path in beams:
                if not np.any(c < 1.0 - 1e-9):
                    if done is None or len(path) < len(done):
                        done = path
                    continue
                if done is not None and len(path) + 1 >= len(done):
                    continue  # cannot beat the incumbent
                scores = space.score_all(c)
                order = np.argsort(-scores)[: self.branch]
                for idx in order:
                    if scores[idx] <= 0.0:
                        continue
                    nxt.append((c + space.utility_of(int(idx)), path + [int(idx)]))
            if not nxt:
                break
            # keep the B states with the least residual need
            nxt.sort(key=lambda s: float(np.sum(np.clip(1.0 - s[0], 0.0, None))))
            beams = nxt[: self.beam]
        if done is None:
            # all beams pruned (incumbent-bound) before finishing — fall back
            return GreedyFast(space).produce(completion)
        return [space.configs[i] for i in done]


FAST_ALGORITHMS: Dict[str, Callable[[ConfigSpace], OptimizerProcedure]] = {
    "greedy": lambda s: GreedyFast(s),
    "beam": lambda s: BeamGreedy(s),
    # the scheduler zoo (repro.core.zoo): competing policies from the
    # retrieved MIG-scheduling literature, benchmarked by the same closed loop
    "frag": lambda s: FragAwarePacker(s),
    "energy": lambda s: EnergyAwareRepartitioner(s),
}

SLOW_ALGORITHMS: Dict[str, Callable[[ConfigSpace], OptimizerProcedure]] = {
    "mcts": lambda s: MCTSSlow(s),
    "greedy": lambda s: GreedyFast(s),
    "frag": lambda s: FragAwarePacker(s),
    "energy": lambda s: EnergyAwareRepartitioner(s),
}


@dataclasses.dataclass
class OptimizeReport:
    fast_deployment: Deployment
    best_deployment: Deployment
    ga_history: List[int]
    fast_seconds: float
    total_seconds: float
    # warm-start telemetry: ``warm`` is True when phase 1 repaired the
    # incumbent instead of solving cold; ``warm_edits`` counts devices
    # added + removed against it; ``warm_fallback`` names why the warm path
    # bailed to a cold solve ("divergence" | "edit_budget"), None otherwise
    warm: bool = False
    warm_edits: Optional[int] = None
    warm_fallback: Optional[str] = None

    def best_indexed(self, space: ConfigSpace) -> IndexedDeployment:
        """The winning deployment in the array-native representation."""
        return IndexedDeployment.from_deployment(space, self.best_deployment)


class TwoPhaseOptimizer:
    def __init__(
        self,
        rules: ReconfigRules,
        profile: PerfProfile,
        workload: Workload,
        fast: str = "greedy",
        slow: str = "mcts",
        ga_rounds: int = 10,
        ga_population: int = 6,
        mcts_iterations: int = 200,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
        space: Optional[ConfigSpace] = None,
        incumbent: Optional[IndexedDeployment] = None,
        incumbent_workload: Optional[Workload] = None,
        warm_divergence: float = 0.5,
        warm_edit_frac: float = 0.5,
    ):
        # enumeration dominates setup cost — callers that already hold the
        # ConfigSpace for this exact problem can pass it in
        if space is not None:
            if (
                space.workload != workload
                or space.rules is not rules
                or space.profile is not profile
            ):
                raise ValueError(
                    "space was built for different rules/profile/workload"
                )
            self.space = space
        else:
            self.space = ConfigSpace(rules, profile, workload)
        # Warm start (incremental reoptimization): phase 1 repairs the
        # incumbent against the new workload instead of packing from empty.
        # ``incumbent_workload`` (what the incumbent was sized for) gates the
        # cold-solve fallback on required-rate divergence; without it the
        # caller has already decided the incumbent is usable.
        if incumbent is not None and incumbent.space is not self.space:
            raise ValueError(
                "incumbent must be indexed over this optimizer's space — "
                "rebind the old ConfigSpace to the new workload first"
            )
        self.incumbent = incumbent
        self.incumbent_workload = incumbent_workload
        self.warm_divergence = warm_divergence
        self.warm_edit_frac = warm_edit_frac
        self.time_budget_s = time_budget_s
        self.fast = FAST_ALGORITHMS[fast](self.space)
        if slow == "mcts":
            self.slow: OptimizerProcedure = MCTSSlow(
                self.space, iterations=mcts_iterations, seed=seed
            )
        else:
            self.slow = SLOW_ALGORITHMS[slow](self.space)
        self.ga = GeneticOptimizer(
            self.space,
            self.slow,
            population=ga_population,
            rounds=ga_rounds,
            seed=seed,
            time_budget_s=time_budget_s,
        )

    def _warm_fast(
        self, deadline: Optional[float]
    ) -> "tuple[Optional[Deployment], Optional[int], Optional[str], Optional[int]]":
        """Phase-1 warm path: (deployment, edits, fallback reason, budget)."""
        inc = self.incumbent
        if self.incumbent_workload is not None and self.space.workload.n:
            old = self.incumbent_workload.required()
            new = self.space.req
            div = float(np.max(np.abs(new - old) / np.maximum(old, 1e-12)))
            if div > self.warm_divergence:
                return None, None, "divergence", None
        budget = max(2, int(math.ceil(self.warm_edit_frac * max(inc.num_gpus, 1))))
        repaired = warm_repair(
            self.space, self.fast, inc, edit_budget=budget, deadline=deadline
        )
        if repaired is None:
            return None, None, "edit_budget", None
        idx, edits = repaired
        return idx.to_deployment(), edits, None, budget

    def run(self, skip_phase2: bool = False) -> OptimizeReport:
        t0 = time.monotonic()
        fast_dep: Optional[Deployment] = None
        warm_edits: Optional[int] = None
        warm_fallback: Optional[str] = None
        edit_budget: Optional[int] = None
        if self.incumbent is not None:
            deadline = (
                t0 + self.time_budget_s if self.time_budget_s is not None else None
            )
            fast_dep, warm_edits, warm_fallback, edit_budget = self._warm_fast(deadline)
        warm = fast_dep is not None
        if fast_dep is None:
            fast_dep = self.fast.solve()
        t1 = time.monotonic()
        if not fast_dep.is_valid(self.space.workload):
            raise RuntimeError(
                "phase-1 deployment does not satisfy the workload — the fast "
                "algorithm or warm-start edits produced an invalid placement"
            )
        if skip_phase2:
            return OptimizeReport(
                fast_dep,
                fast_dep,
                [fast_dep.num_gpus],
                t1 - t0,
                t1 - t0,
                warm=warm,
                warm_edits=warm_edits,
                warm_fallback=warm_fallback,
            )
        result: GAResult = self.ga.run(
            fast_dep,
            incumbent=self.incumbent.to_deployment() if warm else None,
            edit_budget=edit_budget,
        )
        t2 = time.monotonic()
        return OptimizeReport(
            fast_deployment=fast_dep,
            best_deployment=result.best,
            ga_history=result.history,
            fast_seconds=t1 - t0,
            total_seconds=t2 - t0,
            warm=warm,
            warm_edits=warm_edits,
            warm_fallback=warm_fallback,
        )
