"""Constraint-free GPU lower bound (§8 "lower-bound" baseline).

The paper computes "a lower bound of GPU usage by ignoring MIG's hardware
constraints": assume any instance combination is possible and every service
always runs on its most cost-efficient instance size.  Then

    slices_needed(service) = required_tput / (best per-slice tput)
    GPUs_lb = ceil( Σ_s slices_needed(s) / device_size )

This is likely unachievable (it ignores partition legality and instance
granularity) — MIG-Serving lands within 3% of it (§8.1).
"""

from __future__ import annotations

import math

from repro.core.deployment import Workload
from repro.core.profiles import PerfProfile
from repro.core.rms import ReconfigRules


def lower_bound_gpus(
    rules: ReconfigRules, profile: PerfProfile, workload: Workload
) -> int:
    total_slices = 0.0
    for svc in workload.services:
        best_eff = 0.0
        for size in rules.instance_sizes:
            t = profile.throughput(svc.name, size, svc.slo.latency_ms)
            if t > 0:
                best_eff = max(best_eff, t / size)
        if best_eff <= 0:
            raise ValueError(f"service {svc.name} infeasible on all sizes")
        total_slices += svc.slo.throughput / best_eff
    return math.ceil(total_slices / rules.device_size - 1e-9)


def baseline_homogeneous(
    rules: ReconfigRules,
    profile: PerfProfile,
    workload: Workload,
    size: int,
) -> int:
    """Static homogeneous partition baselines (§2.3): every device is carved
    into ``device_size // size`` instances of one size (A100-7×1/7 uses
    size=1; A100-7/7 uses size=device_size).  Greedy assignment is exact here
    because instances are identical (Identical Parallel Machine Scheduling
    with long-running jobs = per-service ceiling)."""
    per_dev = rules.device_size // size
    total_instances = 0
    for svc in workload.services:
        t = profile.throughput(svc.name, size, svc.slo.latency_ms)
        if t <= 0:
            return -1  # some service cannot run at this size at all
        total_instances += math.ceil(svc.slo.throughput / t - 1e-9)
    return math.ceil(total_instances / per_dev - 1e-9)


def baseline_static_mix(
    rules: ReconfigRules,
    profile: PerfProfile,
    workload: Workload,
    partition=None,
) -> int:
    """A100-MIX baseline (§8): every device uses one fixed heterogeneous
    partition (default "4-2-1") and runs a single service per device."""
    if partition is None:
        # the paper's 4-2-1 mix; for TPU rules use the analogous 8-4-2-1-1
        partition = (4, 2, 1) if rules.device_size == 7 else (8, 4, 2, 1, 1)
    gpus = 0
    for svc in workload.services:
        per_gpu = 0.0
        for size in partition:
            per_gpu += profile.throughput(svc.name, size, svc.slo.latency_ms)
        if per_gpu <= 0:
            return -1
        gpus += math.ceil(svc.slo.throughput / per_gpu - 1e-9)
    return gpus
