"""Controller: the exchange-and-compact transition algorithm (§6).

Given the cluster's current deployment and a new target deployment, the
controller plans and executes a transition that is *transparent*: at every
point of the trace, each service's aggregate throughput stays at or above
min(old required, new required) (§1, §6).

**Exchange phase** — fixes instance *sizes* per service.  For each service we
diff instance multisets (Δ_i), pair every new instance with unneeded
instances whose summed throughput does not exceed the new instance's
(pairing the other way could drop throughput, §6), execute each pair
create-first-delete-second (on extra GPUs if no legal room exists), and
delete the remaining unneeded instances only after all pairs finish.

**Compact phase** — fixes device *partitions* and defragments.  Repeatedly
bind one target GPU config to a physical device: migrate away instances the
target does not want, drop idle slots (repartition), migrate wanted
instances in.  Migration is create-then-delete so throughput never dips.
Locality: donors/scratch on the same machine are preferred (§6
"optimizations"); disjoint-GPU actions may run in parallel —
``parallel_makespan`` reports the dependency-aware wall clock.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import Action, GPUState, SimulatedCluster, parallel_makespan
from repro.core.deployment import Deployment, GPUConfig, Workload
from repro.core.profiles import PerfProfile
from repro.core.rms import ReconfigRules

Content = Tuple[Tuple[int, str], ...]  # sorted ((size, service), ...)


def _config_content(cfg: GPUConfig) -> Counter:
    # memoized on the (frozen) config: transition planning consults target
    # contents O(targets x devices) times
    c = cfg.__dict__.get("_content")
    if c is None:
        c = Counter((a.size, a.service) for a in cfg.assignments if a.service)
        cfg.__dict__["_content"] = c
    return c


def _gpu_content(g: GPUState) -> Counter:
    return Counter((r.size, r.service) for r in g.instances.values() if r.service)


@dataclasses.dataclass
class TransitionReport:
    actions: List[Action]
    serial_seconds: float
    parallel_seconds: float
    peak_gpus_busy: int
    final_gpus_busy: int

    @property
    def action_counts(self) -> Dict[str, int]:
        c: Dict[str, int] = {}
        for a in self.actions:
            c[a.kind] = c.get(a.kind, 0) + 1
        return c


class Controller:
    def __init__(self, rules: ReconfigRules, profile: PerfProfile):
        self.rules = rules
        self.profile = profile

    # -- initial placement -------------------------------------------------------
    def deploy_fresh(
        self, cluster: SimulatedCluster, deployment: Deployment
    ) -> None:
        """Place a deployment on an empty cluster (one config per device)."""
        empties = [
            gid for gid, g in cluster.gpus.items()
            if not g.instances and cluster.schedulable(gid)
        ]
        if len(empties) < deployment.num_gpus:
            cluster.grow(deployment.num_gpus - len(empties))
            empties = [
                gid for gid, g in cluster.gpus.items()
                if not g.instances and cluster.schedulable(gid)
            ]
        for cfg, gid in zip(deployment.configs, empties):
            for a in cfg.assignments:
                if a.service is None:
                    continue
                cluster.apply(
                    Action("create", gid, size=a.size, service=a.service,
                           throughput=a.throughput)
                )

    # -- exchange phase ------------------------------------------------------------
    def _exchange(
        self,
        cluster: SimulatedCluster,
        new_dep: Deployment,
        services_per_round: Optional[int] = None,
    ) -> None:
        # target / current per-service multisets of (size, throughput-per-inst)
        new_insts: Dict[str, List[Tuple[int, float]]] = {}
        for cfg in new_dep.configs:
            for a in cfg.assignments:
                if a.service:
                    new_insts.setdefault(a.service, []).append((a.size, a.throughput))
        cur_insts: Dict[str, List[Tuple[int, int, float, int]]] = {}
        for gid, g in cluster.gpus.items():
            for r in g.instances.values():
                if r.service:
                    cur_insts.setdefault(r.service, []).append(
                        (r.size, gid, r.throughput, r.uid)
                    )

        services = sorted(set(new_insts) | set(cur_insts))

        # -- plan per service: expanded creates + the unneeded pool -----------
        plans: Dict[str, Tuple[List[Tuple[int, float]], List[Tuple[int, int, float, int]]]] = {}
        for svc in services:
            want = Counter(s for s, _ in new_insts.get(svc, []))
            have = Counter(s for s, _, _, _ in cur_insts.get(svc, []))
            plus = want - have  # sizes to create
            minus = have - want  # sizes to drop
            # concrete unneeded instances, largest throughput first
            unneeded = sorted(
                (t for t in cur_insts.get(svc, []) if minus[t[0]] > 0),
                key=lambda t: -t[2],
            )
            picked: List[Tuple[int, int, float, int]] = []
            tally = Counter()
            for t in unneeded:
                if tally[t[0]] < minus[t[0]]:
                    picked.append(t)
                    tally[t[0]] += 1
            # new instances, largest first; multiplicity-expanded
            new_list = sorted(
                ((size, tput) for size, tput in new_insts.get(svc, []) if plus[size] > 0),
                key=lambda t: -t[1],
            )
            expanded: List[Tuple[int, float]] = []
            counted = Counter()
            for size, tput in new_list:
                if counted[size] < plus[size]:
                    expanded.append((size, tput))
                    counted[size] += 1
            plans[svc] = (expanded, picked)

        # -- execute in rounds (§6: granularity depends on extra GPUs) --------
        # Within a round, services' pairs are interleaved round-robin so that
        # actions on disjoint GPUs can run in parallel; a smaller
        # services_per_round bounds how many in-flight creations (hence extra
        # GPUs) exist at once.
        r = services_per_round or len(services)
        for lo in range(0, len(services), max(1, r)):
            chunk = services[lo : lo + max(1, r)]
            pending = {svc: list(plans[svc][0]) for svc in chunk}
            unneeded_pool = {svc: list(plans[svc][1]) for svc in chunk}
            while any(pending.values()):
                for svc in chunk:
                    if not pending[svc]:
                        continue
                    size, tput = pending[svc].pop(0)
                    gid = cluster.find_room(size)
                    if gid is None:
                        gid = cluster.grow(1)[0]
                    cluster.apply(
                        Action("create", gid, size=size, service=svc, throughput=tput)
                    )
                    # delete paired unneeded instances (sum tput <= new tput)
                    budget = tput
                    rest: List[Tuple[int, int, float, int]] = []
                    for t in unneeded_pool[svc]:
                        if t[2] <= budget + 1e-9:
                            cluster.apply(Action("delete", t[1], uid=t[3]))
                            budget -= t[2]
                        else:
                            rest.append(t)
                    unneeded_pool[svc] = rest
            # leftovers deleted only after all pairs of the round finished —
            # every service's throughput stays >= min(old, new) throughout
            for svc in chunk:
                for t in unneeded_pool[svc]:
                    cluster.apply(Action("delete", t[1], uid=t[3]))

    # -- compact phase ---------------------------------------------------------------
    def _find_scratch(
        self, cluster: SimulatedCluster, size: int, avoid: Sequence[int],
        near_machine: Optional[int],
    ) -> int:
        """A non-avoided, schedulable GPU that can legally host a ``size``
        instance, preferring the local machine (§6 locality optimization)."""
        avoid_set = set(avoid)
        cands = [
            gid for gid in cluster.gpus
            if gid not in avoid_set and cluster.schedulable(gid)
        ]
        cands.sort(key=lambda gid: (cluster.gpus[gid].machine != near_machine, gid))
        for gid in cands:
            part = tuple(sorted(cluster.gpus[gid].partition() + (size,)))
            if self.rules.is_legal_partition(part):
                return gid
        return cluster.grow(1)[0]

    def _compact(self, cluster: SimulatedCluster, new_dep: Deployment) -> None:
        targets: List[GPUConfig] = list(new_dep.configs)
        bound: Dict[int, int] = {}  # target idx -> gpu id

        def unbound_gpus() -> List[int]:
            """Donor-eligible devices: unbound, not failed (draining devices
            still *donate* instances — that is how a drain empties out)."""
            taken = set(bound.values())
            return [
                gid for gid in cluster.gpus
                if gid not in taken and gid not in cluster.failed
            ]

        def bindable_gpus() -> List[int]:
            """Target-eligible devices: unbound AND schedulable (a target
            config must never be shaped onto a failed or draining device)."""
            return [gid for gid in unbound_gpus() if cluster.schedulable(gid)]

        # 1) bind exact matches first (no actions run here, so per-GPU
        # contents can be computed once for the whole pass)
        contents = {gid: _gpu_content(g) for gid, g in cluster.gpus.items()}
        for ti, cfg in enumerate(targets):
            want = _config_content(cfg)
            for gid in bindable_gpus():
                if contents[gid] == want:
                    bound[ti] = gid
                    break

        # 2) one target at a time: shape a device into the target config
        for ti, cfg in enumerate(targets):
            if ti in bound:
                continue
            want = _config_content(cfg)
            # pick the unbound GPU with the most overlap; contents are
            # re-read per target (the previous target's migrations moved
            # instances) but only once per candidate, not per comparison
            cands = bindable_gpus()
            if not cands:
                # every healthy device is bound (fault domains shrank the
                # cluster mid-transition) — provision a fresh one
                cands = cluster.grow(1)
            contents = {gid: _gpu_content(cluster.gpus[gid]) for gid in cands}

            def overlap(gid: int) -> int:
                return sum((contents[gid] & want).values())

            gid = max(cands, key=overlap)
            g = cluster.gpus[gid]
            taken = set(bound.values()) | {gid}
            # 2a) migrate away busy instances the target does not want
            surplus = _gpu_content(g) - want
            for (size, svc), cnt in list(surplus.items()):
                uids = [
                    u for u, r in g.instances.items()
                    if r.size == size and r.service == svc
                ][:cnt]
                for uid in uids:
                    dst = self._find_scratch(cluster, size, avoid=taken,
                                             near_machine=g.machine)
                    cluster.apply(Action("migrate", gid, uid=uid, dst_gpu=dst))
            # 2b) drop idle slots so incoming instances always fit
            idle = tuple(u for u, r in g.instances.items() if r.service is None)
            if idle:
                cluster.apply(Action("repartition", gid, remove_uids=idle))
            # 2c) migrate wanted instances in (locality-aware donor order)
            missing = want - _gpu_content(g)
            for (size, svc), cnt in sorted(missing.items(), key=lambda kv: -kv[0][0]):
                for _ in range(cnt):
                    donor = None
                    donors = sorted(
                        (d for d in unbound_gpus() if d != gid),
                        key=lambda d: (cluster.gpus[d].machine != g.machine, d),
                    )
                    for d in donors:
                        for u, r in cluster.gpus[d].instances.items():
                            if r.size == size and r.service == svc:
                                donor = (d, u)
                                break
                        if donor:
                            break
                    if donor is None:
                        raise RuntimeError(
                            f"compact: no donor for ({size},{svc}) — "
                            "exchange phase left wrong multiset"
                        )
                    cluster.apply(Action("migrate", donor[0], uid=donor[1], dst_gpu=gid))
            bound[ti] = gid

        # 3) clear idle slots on non-target GPUs (skip failed/draining
        # devices: no point reconfiguring hardware that is gone or leaving)
        taken = set(bound.values())
        for gid, g in cluster.gpus.items():
            if gid in taken:
                continue
            if g.busy():
                raise RuntimeError(
                    f"compact left a running instance unplaced on gpu{gid}"
                )
            if not cluster.schedulable(gid):
                continue
            idle = tuple(g.instances)
            if idle:
                cluster.apply(Action("repartition", gid, remove_uids=idle))

    # -- incremental transition (warm-start targets) -------------------------------
    def transition_incremental(
        self, cluster: SimulatedCluster, new_dep: Deployment
    ) -> TransitionReport:
        """Delta-aware transition for warm-start targets.

        The warm optimizer bounds the edit distance between the running
        deployment and the target, so most devices already hold exactly one
        target config — the full exchange-and-compact would re-derive that
        with O(cluster) scans per action.  Instead: (1) bind every device
        whose content equals a target config (no actions at all), (2) create
        each remaining target config whole on an empty device (grown on
        demand, like ``deploy_fresh``), and (3) only after every create has
        landed, drain the surplus devices (delete busy instances, then
        repartition the idle slots away so the device is reusable).  Creates
        strictly before deletes keeps every service's aggregate throughput
        >= min(old, new) required at all times — the §6 transparency
        guarantee — and the action count is O(edit distance), not
        O(cluster).  Trade-off vs exchange-and-compact: peak extra devices
        during the transition can reach old+new for a wildly different
        target, which is why callers route only bounded-edit (warm) targets
        here.
        """
        start_idx = len(cluster.actions_applied)
        peak = cluster.gpus_in_use()
        # 1) exact-content binding, like compact step 1
        by_content: Dict[Content, List[int]] = {}
        for gid in sorted(cluster.gpus):
            g = cluster.gpus[gid]
            if g.busy() and cluster.schedulable(gid):
                key = tuple(sorted(_gpu_content(g).items()))
                by_content.setdefault(key, []).append(gid)
        unmatched: List[GPUConfig] = []
        for cfg in new_dep.configs:
            key = tuple(sorted(_config_content(cfg).items()))
            gids = by_content.get(key)
            if gids:
                gids.pop(0)  # bound: already serving this exact config
            else:
                unmatched.append(cfg)
        surplus = sorted(gid for gids in by_content.values() for gid in gids)
        # 2) create phase: each unmatched target lands whole on an empty device
        empties = sorted(
            gid
            for gid, g in cluster.gpus.items()
            if not g.instances and cluster.schedulable(gid)
        )
        if len(empties) < len(unmatched):
            empties += cluster.grow(len(unmatched) - len(empties))
        for cfg, gid in zip(unmatched, empties):
            for a in cfg.assignments:
                if a.service is None:
                    continue
                cluster.apply(
                    Action("create", gid, size=a.size, service=a.service,
                           throughput=a.throughput)
                )
        peak = max(peak, cluster.gpus_in_use())
        # 3) drain surplus devices — strictly after all creates
        for gid in surplus:
            g = cluster.gpus[gid]
            for uid in sorted(u for u, r in g.instances.items() if r.service):
                cluster.apply(Action("delete", gid, uid=uid))
            idle = tuple(sorted(g.instances))
            if idle:
                cluster.apply(Action("repartition", gid, remove_uids=idle))
        actions = cluster.actions_applied[start_idx:]
        return TransitionReport(
            actions=actions,
            serial_seconds=sum(a.seconds() for a in actions),
            parallel_seconds=parallel_makespan(actions),
            peak_gpus_busy=peak,
            final_gpus_busy=cluster.gpus_in_use(),
        )

    # -- end-to-end ---------------------------------------------------------------
    def transition(
        self,
        cluster: SimulatedCluster,
        new_dep: Deployment,
        services_per_round: Optional[int] = None,
    ) -> TransitionReport:
        """``services_per_round`` (§6): with many extra GPUs, run
        exchange-and-compact once for all services (None); with few, bound
        the number of services in flight per round."""
        start_idx = len(cluster.actions_applied)
        peak = cluster.gpus_in_use()
        self._exchange(cluster, new_dep, services_per_round)
        peak = max(peak, cluster.gpus_in_use())
        self._compact(cluster, new_dep)
        peak = max(peak, cluster.gpus_in_use())
        actions = cluster.actions_applied[start_idx:]
        return TransitionReport(
            actions=actions,
            serial_seconds=sum(a.seconds() for a in actions),
            parallel_seconds=parallel_makespan(actions),
            peak_gpus_busy=peak,
            final_gpus_busy=cluster.gpus_in_use(),
        )
