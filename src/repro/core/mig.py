"""A100 MIG partition rules (§2.1, Figure 2) — the paper-faithful rule-set.

An A100 exposes 7 compute slices.  Instances come in sizes 1,2,3,4,7 (5/7 and
6/7 are not allocatable).  Each instance size has a fixed set of *placements*
(which compute slices it may occupy) — this placement structure, not a
free-count, decides legality, which is exactly the paper's point: "having n
units of free resources does not imply that a GPU is able to allocate an n/7
instance".

Placements follow NVIDIA's profile placement table (MIG user guide):

  * 1/7 : any single slice 0..6
  * 2/7 : aligned pairs {0,1} {2,3} {4,5}
  * 3/7 : {0,1,2} or {4,5,6}
  * 4/7 : {0,1,2,3}
  * 7/7 : {0..6}

plus the paper's *hard-coded exception*: "4/7 + 3/7" is placement-compatible
but prohibited in practice (§2.1), while "3/7 + 3/7" is legal.  We encode the
exception explicitly.
"""

from __future__ import annotations

import functools
import itertools
from typing import Dict, FrozenSet, List, Sequence, Tuple

from repro.core.rms import Partition, ReconfigRules

# placement -> frozenset of occupied compute slices
PLACEMENTS: Dict[int, Tuple[FrozenSet[int], ...]] = {
    1: tuple(frozenset({i}) for i in range(7)),
    2: (frozenset({0, 1}), frozenset({2, 3}), frozenset({4, 5})),
    3: (frozenset({0, 1, 2}), frozenset({4, 5, 6})),
    4: (frozenset({0, 1, 2, 3}),),
    7: (frozenset(range(7)),),
}

# The paper's hard-coded rule: a 4/7 and a 3/7 instance may not coexist.
FORBIDDEN_PAIRS: Tuple[FrozenSet[int], ...] = (frozenset({3, 4}),)


class A100Rules(ReconfigRules):
    """The literal A100 MIG legality oracle."""

    @property
    def device_size(self) -> int:
        return 7

    @property
    def instance_sizes(self) -> Sequence[int]:
        return (1, 2, 3, 4, 7)

    def is_legal_partition(self, partition: Partition) -> bool:
        partition = tuple(sorted(partition))
        if partition == ():
            return True
        sizes = set(partition)
        for bad in FORBIDDEN_PAIRS:
            if bad <= sizes:
                return False
        return self._placeable(partition)

    @functools.lru_cache(maxsize=None)
    def _placeable(self, partition: Partition) -> bool:
        """Backtracking search for a non-overlapping placement assignment."""

        def rec(idx: int, occupied: FrozenSet[int]) -> bool:
            if idx == len(partition):
                return True
            size = partition[idx]
            for pl in PLACEMENTS[size]:
                if not (pl & occupied):
                    if rec(idx + 1, occupied | pl):
                        return True
            return False

        # place large instances first: fewer placements, prunes faster
        ordered = tuple(sorted(partition, reverse=True))
        partition = ordered
        return rec(0, frozenset())

    @functools.lru_cache(maxsize=None)
    def _legal_cache(self) -> Tuple[Partition, ...]:
        out = set()
        sizes = self.instance_sizes

        def rec(cur: Tuple[int, ...], start: int) -> None:
            for i in range(start, len(sizes)):
                cand = tuple(sorted(cur + (sizes[i],)))
                if sum(cand) > self.device_size:
                    continue
                if cand in out:
                    continue
                if self.is_legal_partition(cand):
                    out.add(cand)
                    rec(cand, 0)

        rec((), 0)
        return tuple(sorted(out))

    def legal_partitions(self) -> List[Partition]:
        return list(self._legal_cache())


@functools.lru_cache(maxsize=None)
def a100_rules() -> A100Rules:
    return A100Rules()
