"""Online profile refinement from production measurements (paper §8.3).

The paper attributes its <5% SLO shortfall to "slight performance variance
between the model performance profiling and the performance of serving
frameworks", and proposes "collecting model performance in production and
gradually updating profiling data used in MIG-SERVING's algorithms".  This
module is that loop: :class:`MeasuredProfile` wraps any base profile,
accepts per-(service, size) throughput observations from running engines,
and serves an EWMA-corrected profile back to the optimizer.

Corrections are multiplicative (observed / predicted at the observed batch)
so a single scale factor transfers across batch sizes and latency SLOs.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

from repro.core.profiles import PerfProfile


class MeasuredProfile(PerfProfile):
    def __init__(self, base: PerfProfile, ewma: float = 0.3):
        self.base = base
        self.ewma = ewma
        self._scale: Dict[Tuple[str, int], float] = {}

    # -- PerfProfile surface ---------------------------------------------------
    def services(self) -> List[str]:
        return self.base.services()

    def sizes(self) -> Sequence[int]:
        return self.base.sizes()

    def latency_ms(self, model: str, size: int, batch: int) -> float:
        lat = self.base.latency_ms(model, size, batch)
        s = self._scale.get((model, size), 1.0)
        # throughput scale s <=> service rate scale s <=> latency / s
        return lat / s if math.isfinite(lat) else lat

    # -- production feedback -----------------------------------------------------
    def observe(
        self, model: str, size: int, batch: int, measured_tput: float
    ) -> None:
        """Feed one measurement: sustained req/s at the given batch."""
        base_lat = self.base.latency_ms(model, size, batch)
        if not math.isfinite(base_lat) or measured_tput <= 0:
            return
        predicted = batch * 1000.0 / base_lat
        ratio = measured_tput / predicted
        key = (model, size)
        old = self._scale.get(key, 1.0)
        self._scale[key] = (1 - self.ewma) * old + self.ewma * ratio

    def correction(self, model: str, size: int) -> float:
        return self._scale.get((model, size), 1.0)
