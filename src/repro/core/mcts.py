"""The slow algorithm: customized Monte Carlo Tree Search (§5.3, Appendix A.2).

Tree shape (Figure 7): nodes are completion-rate vectors, edges are GPU
configs, leaves are all-≥100% nodes; the objective is the *shortest* path
(fewest devices).  Vanilla MCTS fails here for the paper's two reasons,
addressed exactly as the paper does:

  1. **Child explosion** — each expansion samples 5 not-fully-satisfied
     services, scores only configs touching them, and keeps the top-K
     (K=10) as edges.
  2. **Slow/inaccurate rollout** — the classic random playout estimates a
     *random* path, not the shortest.  We use the paper's memoized
     randomized estimation: a pool of "good candidate" configs is
     pre-computed per *type* of completion rates (the frozenset of unmet
     services, needs bucketed); a rollout repeatedly applies a random
     pool member and the step count is memoized by the bucketed signature.

Selection is UCT adapted to minimization (lower estimated total depth is
better).  Every completed rollout yields a concrete deployment suffix, so the
search is *anytime*: we track the best full config-sequence seen.

Array-native hot path: edge generation unions the space's precomputed
per-service boolean masks (``ConfigSpace.service_masks``) instead of a
Python scan over every config, top-K cuts use ``np.argpartition`` (O(n)
instead of a full sort), rollout/expansion completion updates are two
indexed adds, and signatures are raw little-endian bytes of the bucketed
need vector.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.deployment import ConfigSpace, GPUConfig, OptimizerProcedure

_BUCKETS = 8


def _bucket_signature(completion: np.ndarray, buckets: int = _BUCKETS) -> bytes:
    """The paper's "type of completion rates": unmet services with their
    residual need quantized to ``buckets`` levels (as hashable bytes)."""
    need = np.clip(1.0 - completion, 0.0, None)
    # ceil so that any strictly-positive residual lands in bucket >= 1:
    # met and nearly-met services must not share a signature, or cached
    # pools go stale and rollouts stall.
    q = np.minimum(np.ceil(need * buckets).astype(np.int64), buckets)
    return q.tobytes()


def _bucket_of(need: float) -> int:
    """Scalar twin of :func:`_bucket_signature`'s quantization (rollouts
    maintain the bucketed vector incrementally, one touched service at a
    time, instead of re-deriving the whole signature per step)."""
    if need <= 0.0:
        return 0
    b = int(math.ceil(need * _BUCKETS))
    return b if b < _BUCKETS else _BUCKETS


def _top_k_desc(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the ``k`` largest scores, sorted descending with ascending
    index as the deterministic tie-break (argpartition cut, O(n))."""
    if k >= len(scores):
        part = np.arange(len(scores))
    else:
        cut = len(scores) - k
        part = np.argpartition(scores, cut)[cut:]
    return part[np.lexsort((part, -scores[part]))]


@dataclasses.dataclass
class _Node:
    completion: np.ndarray
    depth: int
    children: Dict[int, "_Node"] = dataclasses.field(default_factory=dict)
    edges: Optional[List[int]] = None  # config indices (top-K cut)
    visits: int = 0
    total: float = 0.0  # sum of estimated total path lengths
    _done: Optional[bool] = None
    # edges with no child yet, in edge order (maintained by _make_child so
    # the selection loop need not rebuild the list every visit)
    unvisited: Optional[List[int]] = None

    def q(self) -> float:
        return self.total / self.visits if self.visits else math.inf

    def done(self) -> bool:
        # completion is fixed at construction, so compute once
        if self._done is None:
            self._done = bool(np.all(self.completion >= 1.0 - 1e-9))
        return self._done


class MCTSSlow(OptimizerProcedure):
    def __init__(
        self,
        space: ConfigSpace,
        iterations: int = 300,
        top_k: int = 10,
        sample_services: int = 5,
        ucb_c: float = 0.8,
        pool_size: int = 12,
        seed: int = 0,
    ):
        super().__init__(space)
        self.iterations = iterations
        self.top_k = top_k
        self.sample_services = sample_services
        self.ucb_c = ucb_c
        self.pool_size = pool_size
        self.rng = np.random.default_rng(seed)
        self._pool_cache: Dict[bytes, np.ndarray] = {}
        self._rollout_memo: Dict[bytes, Tuple[float, List[int]]] = {}
        # scratch for pool scoring and rollout state (single-threaded hot
        # loops; nothing here escapes the method that fills it)
        self._score_buf = np.empty(len(space))
        self._score_buf2 = np.empty(len(space))
        n = space.workload.n
        self._need_buf = np.empty(n)
        self._scaled_buf = np.empty(n)
        self._q_buf = np.empty(n, dtype=np.int64)
        self._c_buf = np.empty(n)
        self._unmet_buf = np.empty(n, dtype=bool)

    def _pick(self, seq) -> int:
        """Uniform draw from ``seq`` — same stream as ``rng.choice(seq)``
        (which reduces to ``integers(0, len)``) minus its array-conversion
        and shape-handling overhead on this per-step hot path."""
        return seq[int(self.rng.integers(0, len(seq)))]

    def _scores_into_scratch(self, need: np.ndarray) -> np.ndarray:
        """``score_all`` for a residual-need vector, gathered into the
        shared scratch buffers (valid until the next call; ia/ib are always
        in-bounds, so clip mode just skips the bounds check)."""
        space = self.space
        scores = np.take(need, space.ia, out=self._score_buf, mode="clip")
        scores *= space.ua
        sb = np.take(need, space.ib, out=self._score_buf2, mode="clip")
        sb *= space.ub
        scores += sb
        return scores

    # -- edge generation: the paper's top-K child cut ---------------------------
    def _edges(self, completion: np.ndarray) -> List[int]:
        space = self.space
        unmet = np.where(completion < 1.0 - 1e-9)[0]
        if len(unmet) == 0:
            return []
        k = min(self.sample_services, len(unmet))
        picked = self.rng.choice(unmet, size=k, replace=False)
        mask = np.logical_or.reduce(space.service_masks[picked])
        scores = self._scores_into_scratch(np.maximum(1.0 - completion, 0.0))
        # zero out configs missing the sampled services: scores are >= 0, so
        # every positive survivor is in-mask and the filtered edge list (and
        # its order) is identical to masking with -1
        scores *= mask
        order = _top_k_desc(scores, self.top_k)
        return [int(i) for i in order if scores[i] > 0.0]

    # -- memoized randomized estimation (Appendix A.2) ---------------------------
    def _pool_for(self, sig: bytes, need: np.ndarray) -> np.ndarray:
        """Pool of good candidate configs for one completion *type*.

        ``need`` must equal ``max(1 - completion, 0)`` for the completion the
        signature was taken from; scoring gathers directly from it, skipping
        the re-derivation ``score_all`` would do.
        """
        pool = self._pool_cache.get(sig)
        if pool is None:
            scores = self._scores_into_scratch(need)
            order = _top_k_desc(scores, self.pool_size)
            pool = order[scores[order] > 0.0]
            self._pool_cache[sig] = pool
        return pool

    def _pool(self, completion: np.ndarray) -> np.ndarray:
        return self._pool_for(
            _bucket_signature(completion), np.maximum(1.0 - completion, 0.0)
        )

    def _apply(self, c: np.ndarray, idx: int) -> None:
        """``c += utility_of(idx)`` as two indexed adds (no allocation)."""
        space = self.space
        c[space.ia[idx]] += space.ua[idx]
        c[space.ib[idx]] += space.ub[idx]

    def _rollout(self, completion: np.ndarray) -> Tuple[float, List[int]]:
        """Estimated #devices to finish from here, plus the config sequence."""
        # incremental rollout state: residual need, its bucketed signature,
        # and the unmet count — a step touches <= 2 services, so each update
        # is two scalar refreshes instead of three full-vector passes.  The
        # entry signature is the bucketed vector's bytes, so the memo key
        # falls out of the state initialization for free.
        need, scaled, q = self._need_buf, self._scaled_buf, self._q_buf
        np.subtract(1.0, completion, out=need)
        np.maximum(need, 0.0, out=need)
        np.multiply(need, float(_BUCKETS), out=scaled)
        np.ceil(scaled, out=scaled)
        np.minimum(scaled, float(_BUCKETS), out=scaled)
        q[...] = scaled  # integral floats in [0, 8]: cast is exact
        sig = q.tobytes()
        memo_map = self._rollout_memo
        memo = memo_map.get(sig)
        if memo is not None:
            return memo
        space = self.space
        ia, ib, ua, ub = space.ia, space.ib, space.ua, space.ub
        c = self._c_buf
        np.copyto(c, completion)
        unmet = self._unmet_buf
        np.less(c, 1.0 - 1e-9, out=unmet)
        n_unmet = int(np.count_nonzero(unmet))
        path: List[int] = []
        append = path.append
        pool_for = self._pool_for
        draw = self.rng.integers
        bucket_of = _bucket_of
        thr = 1.0 - 1e-9
        steps = 0.0
        pool = None  # invariant: valid for the current q whenever not None
        while n_unmet:
            if pool is None:
                pool = pool_for(q.tobytes(), need)
                if not len(pool):
                    # residual unsatisfiable via the pools: bail with +inf
                    memo_map[sig] = (math.inf, [])
                    return math.inf, []
            idx = pool[draw(0, len(pool))]
            i1 = ia[idx]
            i2 = ib[idx]
            c[i1] += ua[idx]
            c[i2] += ub[idx]
            ci = c[i1]
            v = 1.0 - ci
            nv = v if v > 0.0 else 0.0
            need[i1] = nv
            b = bucket_of(nv)
            if b != q[i1]:
                q[i1] = b
                pool = None  # signature moved: next step re-resolves
            now = ci < thr
            if unmet[i1] != now:
                unmet[i1] = now
                n_unmet += 1 if now else -1
            if i1 != i2:
                ci = c[i2]
                v = 1.0 - ci
                nv = v if v > 0.0 else 0.0
                need[i2] = nv
                b = bucket_of(nv)
                if b != q[i2]:
                    q[i2] = b
                    pool = None
                now = ci < thr
                if unmet[i2] != now:
                    unmet[i2] = now
                    n_unmet += 1 if now else -1
            append(int(idx))
            steps += 1.0
            if steps > 10_000:
                return math.inf, []
        memo_map[sig] = (steps, path)
        return steps, path

    # -- UCT for minimization -----------------------------------------------------
    def _select_child(self, node: _Node) -> Tuple[int, _Node]:
        if not node.edges:
            raise RuntimeError(
                "_select_child on a node without edges — expansion must "
                "populate edges before UCT selection"
            )
        best, best_val = None, math.inf
        log_visits = math.log(node.visits) if node.visits else 0.0
        for e in node.edges:
            child = node.children.get(e)
            if child is None or child.visits == 0:
                return e, child if child else self._make_child(node, e)
            explore = self.ucb_c * math.sqrt(log_visits / child.visits)
            q = child.q()
            val = (q if math.isfinite(q) else 1e18) - explore
            if val < best_val:
                best_val, best = val, (e, child)
        return best

    def _make_child(self, node: _Node, edge: int) -> _Node:
        c = node.completion.copy()
        self._apply(c, edge)
        child = _Node(completion=c, depth=node.depth + 1)
        node.children[edge] = child
        if node.unvisited is not None:
            node.unvisited.remove(edge)
        return child

    # -- main loop ------------------------------------------------------------------
    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        space = self.space
        root = _Node(completion=completion.astype(np.float64).copy(), depth=0)
        best_len = math.inf
        best_path: List[int] = []

        for _ in range(self.iterations):
            node = root
            path: List[int] = []
            # selection / expansion
            while not node.done():
                if node.edges is None:
                    node.edges = self._edges(node.completion)
                    node.unvisited = list(node.edges)
                if not node.edges:
                    break
                if node.unvisited:
                    e = int(self._pick(node.unvisited))
                    node = self._make_child(node, e)
                    path.append(e)
                    break
                e, node = self._select_child(node)
                path.append(e)
            # estimation
            est, suffix = self._rollout(node.completion)
            total = node.depth - root.depth + est
            if total < best_len and math.isfinite(total):
                best_len = total
                best_path = path + suffix
            # backpropagation
            back = root
            back.visits += 1
            back.total += total
            for e in path:
                back = back.children[e]
                back.visits += 1
                back.total += total

        if not best_path and not root.done():
            raise RuntimeError("MCTS found no completing path")
        # Repair: memoized rollouts are keyed by *bucketed* signatures, so a
        # reused suffix may undershoot the exact residual.  Greedily top up.
        c = completion.astype(np.float64).copy()
        out: List[int] = []
        for i in best_path:
            if not np.any(c < 1.0 - 1e-9):
                break  # drop superfluous tail configs
            self._apply(c, i)
            out.append(i)
        guard = 0
        while np.any(c < 1.0 - 1e-9):
            guard += 1
            if guard > 10_000:
                raise RuntimeError("MCTS repair failed to converge")
            scores = space.score_all(c)
            idx = int(np.argmax(scores))
            if scores[idx] <= 0.0:
                raise RuntimeError("MCTS repair: residual unsatisfiable")
            self._apply(c, idx)
            out.append(idx)
        return [space.configs[i] for i in out]
