"""The slow algorithm: customized Monte Carlo Tree Search (§5.3, Appendix A.2).

Tree shape (Figure 7): nodes are completion-rate vectors, edges are GPU
configs, leaves are all-≥100% nodes; the objective is the *shortest* path
(fewest devices).  Vanilla MCTS fails here for the paper's two reasons,
addressed exactly as the paper does:

  1. **Child explosion** — each expansion samples 5 not-fully-satisfied
     services, scores only configs touching them, and keeps the top-K
     (K=10) as edges.
  2. **Slow/inaccurate rollout** — the classic random playout estimates a
     *random* path, not the shortest.  We use the paper's memoized
     randomized estimation: a pool of "good candidate" configs is
     pre-computed per *type* of completion rates (the frozenset of unmet
     services, needs bucketed); a rollout repeatedly applies a random
     pool member and the step count is memoized by the bucketed signature.

Selection is UCT adapted to minimization (lower estimated total depth is
better).  Every completed rollout yields a concrete deployment suffix, so the
search is *anytime*: we track the best full config-sequence seen.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.deployment import ConfigSpace, GPUConfig, OptimizerProcedure


def _bucket_signature(completion: np.ndarray, buckets: int = 8) -> Tuple:
    """The paper's "type of completion rates": unmet services with their
    residual need quantized to ``buckets`` levels."""
    need = np.clip(1.0 - completion, 0.0, None)
    # ceil so that any strictly-positive residual lands in bucket >= 1:
    # met and nearly-met services must not share a signature, or cached
    # pools go stale and rollouts stall.
    q = np.minimum(np.ceil(need * buckets).astype(np.int64), buckets)
    return tuple(int(x) for x in q)


@dataclasses.dataclass
class _Node:
    completion: np.ndarray
    depth: int
    children: Dict[int, "_Node"] = dataclasses.field(default_factory=dict)
    edges: Optional[List[int]] = None  # config indices (top-K cut)
    visits: int = 0
    total: float = 0.0  # sum of estimated total path lengths

    def q(self) -> float:
        return self.total / self.visits if self.visits else math.inf

    def done(self) -> bool:
        return bool(np.all(self.completion >= 1.0 - 1e-9))


class MCTSSlow(OptimizerProcedure):
    def __init__(
        self,
        space: ConfigSpace,
        iterations: int = 300,
        top_k: int = 10,
        sample_services: int = 5,
        ucb_c: float = 0.8,
        pool_size: int = 12,
        seed: int = 0,
    ):
        super().__init__(space)
        self.iterations = iterations
        self.top_k = top_k
        self.sample_services = sample_services
        self.ucb_c = ucb_c
        self.pool_size = pool_size
        self.rng = np.random.default_rng(seed)
        self._pool_cache: Dict[Tuple, List[int]] = {}
        self._rollout_memo: Dict[Tuple, Tuple[float, List[int]]] = {}

    # -- edge generation: the paper's top-K child cut ---------------------------
    def _edges(self, completion: np.ndarray) -> List[int]:
        space = self.space
        unmet = np.where(completion < 1.0 - 1e-9)[0]
        if len(unmet) == 0:
            return []
        k = min(self.sample_services, len(unmet))
        picked = set(self.rng.choice(unmet, size=k, replace=False).tolist())
        mask = np.array(
            [int(ia) in picked or int(ib) in picked for ia, ib in zip(space.ia, space.ib)]
        )
        scores = space.score_all(completion)
        scores = np.where(mask, scores, -1.0)
        order = np.argsort(-scores)[: self.top_k]
        return [int(i) for i in order if scores[i] > 0.0]

    # -- memoized randomized estimation (Appendix A.2) ---------------------------
    def _pool(self, completion: np.ndarray) -> List[int]:
        sig = _bucket_signature(completion)
        pool = self._pool_cache.get(sig)
        if pool is None:
            scores = self.space.score_all(completion)
            order = np.argsort(-scores)[: self.pool_size]
            pool = [int(i) for i in order if scores[i] > 0.0]
            self._pool_cache[sig] = pool
        return pool

    def _rollout(self, completion: np.ndarray) -> Tuple[float, List[int]]:
        """Estimated #devices to finish from here, plus the config sequence."""
        sig = _bucket_signature(completion)
        memo = self._rollout_memo.get(sig)
        if memo is not None:
            return memo
        c = completion.copy()
        path: List[int] = []
        steps = 0.0
        while np.any(c < 1.0 - 1e-9):
            pool = self._pool(c)
            if not pool:
                # residual unsatisfiable via pooled configs: bail with +inf
                self._rollout_memo[sig] = (math.inf, [])
                return math.inf, []
            idx = int(self.rng.choice(pool))
            c = c + self.space.utility_of(idx)
            path.append(idx)
            steps += 1.0
            if steps > 10_000:
                return math.inf, []
        self._rollout_memo[sig] = (steps, path)
        return steps, path

    # -- UCT for minimization -----------------------------------------------------
    def _select_child(self, node: _Node) -> Tuple[int, _Node]:
        assert node.edges
        best, best_val = None, math.inf
        for e in node.edges:
            child = node.children.get(e)
            if child is None or child.visits == 0:
                return e, child if child else self._make_child(node, e)
            explore = self.ucb_c * math.sqrt(math.log(node.visits) / child.visits)
            q = child.q()
            val = (q if math.isfinite(q) else 1e18) - explore
            if val < best_val:
                best_val, best = val, (e, child)
        return best

    def _make_child(self, node: _Node, edge: int) -> _Node:
        child = _Node(
            completion=node.completion + self.space.utility_of(edge),
            depth=node.depth + 1,
        )
        node.children[edge] = child
        return child

    # -- main loop ------------------------------------------------------------------
    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        space = self.space
        root = _Node(completion=completion.astype(np.float64).copy(), depth=0)
        best_len = math.inf
        best_path: List[int] = []

        for _ in range(self.iterations):
            node = root
            path: List[int] = []
            # selection / expansion
            while not node.done():
                if node.edges is None:
                    node.edges = self._edges(node.completion)
                if not node.edges:
                    break
                unvisited = [e for e in node.edges if e not in node.children]
                if unvisited:
                    e = int(self.rng.choice(unvisited))
                    node = self._make_child(node, e)
                    path.append(e)
                    break
                e, node = self._select_child(node)
                path.append(e)
            # estimation
            est, suffix = self._rollout(node.completion)
            total = node.depth - root.depth + est
            if total < best_len and math.isfinite(total):
                best_len = total
                best_path = path + suffix
            # backpropagation
            back = root
            back.visits += 1
            back.total += total
            for e in path:
                back = back.children[e]
                back.visits += 1
                back.total += total

        if not best_path and not root.done():
            raise RuntimeError("MCTS found no completing path")
        # Repair: memoized rollouts are keyed by *bucketed* signatures, so a
        # reused suffix may undershoot the exact residual.  Greedily top up.
        c = completion.astype(np.float64).copy()
        out: List[int] = []
        for i in best_path:
            if not np.any(c < 1.0 - 1e-9):
                break  # drop superfluous tail configs
            c = c + space.utility_of(i)
            out.append(i)
        guard = 0
        while np.any(c < 1.0 - 1e-9):
            guard += 1
            if guard > 10_000:
                raise RuntimeError("MCTS repair failed to converge")
            scores = space.score_all(c)
            idx = int(np.argmax(scores))
            if scores[idx] <= 0.0:
                raise RuntimeError("MCTS repair: residual unsatisfiable")
            c = c + space.utility_of(idx)
            out.append(idx)
        return [space.configs[i] for i in out]
