"""MIG-Serving core: the Reconfigurable Machine Scheduling Problem in practice.

Public surface of the paper's contribution:

  * rule-sets:   :class:`repro.core.mig.A100Rules`,
                 :class:`repro.core.tpu_slice.TpuSliceRules`
  * profiles:    :class:`repro.core.profiles.SyntheticPaperProfiles`,
                 :class:`repro.core.profiles.RooflineProfiles`
  * optimizer:   :class:`repro.core.optimizer.TwoPhaseOptimizer`
  * controller:  :class:`repro.core.controller.Controller`
"""

from repro.core.cluster import Action, SimulatedCluster, parallel_makespan
from repro.core.controller import Controller, TransitionReport
from repro.core.deployment import (
    ConfigSpace,
    Deployment,
    GPUConfig,
    IndexedDeployment,
    InstanceAssignment,
    OptimizerProcedure,
    Workload,
)
from repro.core.ga import GeneticOptimizer, crossover, fitness_batch, mutate_swap
from repro.core.greedy import GreedyFast
from repro.core.lower_bound import (
    baseline_homogeneous,
    baseline_static_mix,
    lower_bound_gpus,
)
from repro.core.mcts import MCTSSlow
from repro.core.exact import PairSpaceExact, per_service_lower_bound
from repro.core.mig import A100Rules, a100_rules
from repro.core.online_profiles import MeasuredProfile
from repro.core.optimizer import BeamGreedy, OptimizeReport, TwoPhaseOptimizer
from repro.core.profiles import (
    ArchPerfSpec,
    PerfProfile,
    RooflineProfiles,
    SyntheticPaperProfiles,
    TpuChip,
)
from repro.core.rms import SLO, Instance, ReconfigRules, Service
from repro.core.tpu_slice import TpuSliceRules, tpu_slice_rules
from repro.core.zoo import (
    EnergyAwareRepartitioner,
    FragAwarePacker,
    PowerModel,
    WeightedScoreGreedy,
    deployment_power,
    stranded_slices_of,
)

__all__ = [
    "A100Rules", "a100_rules", "Action", "ArchPerfSpec", "BeamGreedy",
    "ConfigSpace", "Controller", "Deployment", "GeneticOptimizer", "GPUConfig",
    "GreedyFast", "IndexedDeployment", "Instance", "InstanceAssignment", "MCTSSlow",
    "OptimizeReport", "OptimizerProcedure", "parallel_makespan", "PerfProfile",
    "ReconfigRules", "RooflineProfiles", "Service", "SimulatedCluster", "SLO",
    "SyntheticPaperProfiles", "TpuChip", "TpuSliceRules", "tpu_slice_rules",
    "TransitionReport", "TwoPhaseOptimizer", "Workload",
    "baseline_homogeneous", "baseline_static_mix", "crossover",
    "fitness_batch", "lower_bound_gpus", "mutate_swap", "MeasuredProfile",
    "PairSpaceExact", "per_service_lower_bound",
    "EnergyAwareRepartitioner", "FragAwarePacker", "PowerModel",
    "WeightedScoreGreedy", "deployment_power", "stranded_slices_of",
]
