"""TPU-pod slice rules — the TPU-native adaptation of MIG (DESIGN.md §2).

A 16×16 v5e pod is carved into 16 *allocation domains* of 4×4 = 16 chips.
Within a domain, instances are aligned rectangular submeshes:

  size  1 : 1×1 at any chip
  size  2 : 1×2 at even columns
  size  4 : 2×2 at even rows/cols
  size  8 : 2×4 at row 0 or 2, col 0
  size 16 : 4×4 (the whole domain)

Alignment is the TPU analogue of MIG's peculiar rules: XLA requires an
ICI-contiguous rectangular mesh, so *n free chips do not imply an n-chip
slice is allocatable* — the same abstract property the paper identifies on
A100 ("no 4/7 + 3/7").  Non-power-of-two sizes mirror A100's forbidden
5/7 and 6/7 instances.

Partial reconfiguration: any subset of a domain's rectangles can be re-tiled
while other rectangles keep serving — matching MIG's on-the-fly repartition.
"""

from __future__ import annotations

import functools
from typing import Dict, FrozenSet, List, Sequence, Tuple

from repro.core.rms import Partition, ReconfigRules

DOMAIN_SHAPE = (4, 4)

# size -> (height, width) of the rectangle
SLICE_SHAPES: Dict[int, Tuple[int, int]] = {
    1: (1, 1),
    2: (1, 2),
    4: (2, 2),
    8: (2, 4),
    16: (4, 4),
}


def _placements(size: int) -> Tuple[FrozenSet[Tuple[int, int]], ...]:
    h, w = SLICE_SHAPES[size]
    rows, cols = DOMAIN_SHAPE
    out = []
    for r in range(0, rows - h + 1, h):
        for c in range(0, cols - w + 1, w):
            out.append(
                frozenset((r + dr, c + dc) for dr in range(h) for dc in range(w))
            )
    return tuple(out)


PLACEMENTS: Dict[int, Tuple[FrozenSet[Tuple[int, int]], ...]] = {
    s: _placements(s) for s in SLICE_SHAPES
}


class TpuSliceRules(ReconfigRules):
    """Legality oracle for rectangular slices of a 4×4 TPU allocation domain."""

    @property
    def device_size(self) -> int:
        return 16

    @property
    def instance_sizes(self) -> Sequence[int]:
        return (1, 2, 4, 8, 16)

    def is_legal_partition(self, partition: Partition) -> bool:
        partition = tuple(sorted(partition, reverse=True))
        if sum(partition) > self.device_size:
            return False
        return self._placeable(partition)

    @functools.lru_cache(maxsize=None)
    def _placeable(self, partition: Partition) -> bool:
        def rec(idx: int, occupied: FrozenSet[Tuple[int, int]]) -> bool:
            if idx == len(partition):
                return True
            for pl in PLACEMENTS[partition[idx]]:
                if not (pl & occupied):
                    if rec(idx + 1, occupied | pl):
                        return True
            return False

        return rec(0, frozenset())

    @functools.lru_cache(maxsize=None)
    def _legal_cache(self) -> Tuple[Partition, ...]:
        out = set()
        sizes = self.instance_sizes

        def rec(cur: Tuple[int, ...]) -> None:
            for s in sizes:
                cand = tuple(sorted(cur + (s,)))
                if sum(cand) > self.device_size or cand in out:
                    continue
                if self.is_legal_partition(cand):
                    out.add(cand)
                    rec(cand)

        rec(())
        return tuple(sorted(out))

    def legal_partitions(self) -> List[Partition]:
        return list(self._legal_cache())


@functools.lru_cache(maxsize=None)
def tpu_slice_rules() -> TpuSliceRules:
    return TpuSliceRules()


class PodSliceRules(TpuSliceRules):
    """Coarse granularity: the allocation domain is one whole 16×16 pod and
    slices are {16, 32, 64, 128, 256} chips (4×4 … 16×16 rectangles).

    Same placement engine as :class:`TpuSliceRules` — a pod is a 4×4 grid of
    16-chip units — with sizes reported in chips.  This granularity hosts the
    ≥200B assigned architectures (deepseek-v2/v3, llama3-405b), which need
    more than a 16-chip slice to hold their weights (DESIGN.md §4).
    """

    UNIT = 16  # chips per placement-grid cell

    @property
    def device_size(self) -> int:
        return 256

    @property
    def instance_sizes(self) -> Sequence[int]:
        return (16, 32, 64, 128, 256)

    def _to_units(self, partition: Partition) -> Partition:
        if not all(s % self.UNIT == 0 for s in partition):
            raise ValueError(
                f"partition {partition} has a size not divisible by the "
                f"{self.UNIT}-chip allocation unit"
            )
        return tuple(s // self.UNIT for s in partition)

    def is_legal_partition(self, partition: Partition) -> bool:
        partition = tuple(sorted(partition, reverse=True))
        if any(s % self.UNIT != 0 for s in partition):
            return False
        if sum(partition) > self.device_size:
            return False
        return self._placeable(self._to_units(partition))

    @functools.lru_cache(maxsize=None)
    def _legal_cache(self) -> Tuple[Partition, ...]:
        base = TpuSliceRules._legal_cache(self)
        # base is in units of 16 chips (the parent enumerates sizes 1..16)
        return tuple(
            tuple(self.UNIT * s for s in p)
            for p in base
        )

    def legal_partitions(self):
        out = set()
        sizes = self.instance_sizes

        def rec(cur):
            for s in sizes:
                cand = tuple(sorted(cur + (s,)))
                if sum(cand) > self.device_size or cand in out:
                    continue
                if self.is_legal_partition(cand):
                    out.add(cand)
                    rec(cand)

        rec(())
        return sorted(out)


@functools.lru_cache(maxsize=None)
def pod_slice_rules() -> PodSliceRules:
    return PodSliceRules()


def slice_mesh_shape(size: int) -> Tuple[int, int]:
    """The (rows, cols) mesh shape a serving engine uses for a slice."""
    if size in SLICE_SHAPES:
        return SLICE_SHAPES[size]
    h, w = SLICE_SHAPES[size // PodSliceRules.UNIT]
    return (4 * h, 4 * w)  # pod-granularity slice
