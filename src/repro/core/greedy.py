"""The fast algorithm: heuristic-score greedy (§5.3, Appendix A.1 / Fig. 15).

Each round picks the GPU config with the highest score

    score(config) = Σ_i (1 − c_i) · u_i

over the pair-config space (mixing ≤ 2 services).  When services are "almost
satisfied" (Fig. 15 lines 18–22) two services can no longer saturate a
device, so the algorithm additionally *packs* more services into one config:
we build a packed candidate greedily — every instance of every full
partition is assigned to the service with the highest need-weighted marginal
utility — and let it compete with the pair configs on score.

Complexity: O(#configs) numpy work per round, #rounds = #devices emitted —
the paper's O(n²m).
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.deployment import (
    ConfigSpace,
    Deployment,
    GPUConfig,
    InstanceAssignment,
    OptimizerProcedure,
    make_assignment,
)


class GreedyFast(OptimizerProcedure):
    def __init__(self, space: ConfigSpace, pack_threshold: float = 0.9):
        super().__init__(space)
        self.pack_threshold = pack_threshold

    # -- Fig. 15 lines 18-22: packed multi-service candidate --------------------
    def _packed_candidate(self, completion: np.ndarray) -> Optional[GPUConfig]:
        w = self.space.workload
        req = w.required()
        need0 = np.clip(1.0 - completion, 0.0, None)
        best_cfg, best_score = None, 0.0
        for partition in self.space.rules.full_partitions():
            need = need0.copy()
            assigns: List[InstanceAssignment] = []
            score = 0.0
            for size in sorted(partition, reverse=True):
                # marginal utility of putting each service on this instance
                gains = np.zeros(w.n)
                for svc in w.services:
                    t = self.space._tput.get((svc.name, size), 0.0)
                    if t <= 0:
                        continue
                    gains[svc.index] = need[svc.index] * (t / req[svc.index])
                i = int(np.argmax(gains))
                if gains[i] <= 0.0:
                    assigns.append(InstanceAssignment(size, None))
                    continue
                svc = w.services[i]
                a = make_assignment(self.space.profile, w, size, svc.name)
                assigns.append(a)
                u = a.throughput / req[i]
                score += need[i] * u
                need[i] = max(0.0, need[i] - u)
            if score > best_score and any(a.service for a in assigns):
                best_score = score
                best_cfg = GPUConfig(partition, tuple(assigns))
        return best_cfg

    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        space = self.space
        c = completion.astype(np.float64).copy()
        out: List[GPUConfig] = []
        guard = 0
        while np.any(c < 1.0 - 1e-9):
            guard += 1
            if guard > 100_000:
                raise RuntimeError("greedy failed to converge")
            scores = space.score_all(c)
            idx = int(np.argmax(scores))
            best_score = float(scores[idx])
            chosen: GPUConfig = space.configs[idx]
            chosen_u = space.utility_of(idx)
            # Fig. 15 lines 18-22: a packed >2-service candidate competes on
            # score every round; it wins exactly in the near-satisfied tail,
            # where two services no longer saturate a device.
            packed = self._packed_candidate(c)
            if packed is not None:
                pu = packed.utility(space.workload)
                need = np.clip(1.0 - c, 0.0, None)
                ps = float(np.sum(need * pu))
                if ps > best_score:
                    chosen, chosen_u, best_score = packed, pu, ps
            if best_score <= 0.0:
                raise RuntimeError(
                    "no config has positive score but SLOs unmet — "
                    "some service is infeasible on every instance size"
                )
            out.append(chosen)
            c = c + chosen_u
        return out
