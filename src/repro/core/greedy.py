"""The fast algorithm: heuristic-score greedy (§5.3, Appendix A.1 / Fig. 15).

Each round picks the GPU config with the highest score

    score(config) = Σ_i (1 − c_i) · u_i

over the pair-config space (mixing ≤ 2 services).  When services are "almost
satisfied" (Fig. 15 lines 18–22) two services can no longer saturate a
device, so the algorithm additionally *packs* more services into one config:
we build a packed candidate greedily — every instance of every full
partition is assigned to the service with the highest need-weighted marginal
utility — and let it compete with the pair configs on score.

Array-native hot path: completion and the per-config score vector are
maintained *incrementally* (a chosen pair config touches ≤ 2 services, so
only the configs sharing those services are re-scored), and the packed
candidate is one vectorized scan advancing every partition in lock-step
(``ConfigSpace.packed_tables``) instead of a per-service Python loop.  Both
paths reproduce the scalar reference float-for-float — same seed, same
deployment, byte-identical downstream ``SimReport``s.

Complexity: O(#configs) numpy work per round, #rounds = #devices emitted —
the paper's O(n²m).
"""

from __future__ import annotations

import time  # contract-ok: wall-clock anytime-budget deadline only; sim time stays logical
from typing import List, Optional, Tuple

import numpy as np

from repro.core.deployment import (
    ConfigSpace,
    Deployment,
    GPUConfig,
    IndexedDeployment,
    InstanceAssignment,
    OptimizerProcedure,
    make_assignment,
)


class GreedyFast(OptimizerProcedure):
    def __init__(self, space: ConfigSpace, pack_threshold: float = 0.9):
        super().__init__(space)
        self.pack_threshold = pack_threshold

    # -- Fig. 15 lines 18-22: packed multi-service candidate --------------------
    def _packed_candidate(self, completion: np.ndarray) -> Optional[GPUConfig]:
        """Scalar reference implementation (kept for the property tests that
        pin the vectorized scan to it; the hot path uses ``_packed_scan``)."""
        w = self.space.workload
        req = w.required()
        need0 = np.clip(1.0 - completion, 0.0, None)
        best_cfg, best_score = None, 0.0
        for partition in self.space.rules.full_partitions():
            need = need0.copy()
            assigns: List[InstanceAssignment] = []
            score = 0.0
            for size in sorted(partition, reverse=True):
                # marginal utility of putting each service on this instance
                gains = np.zeros(w.n)
                for svc in w.services:
                    t = self.space._tput.get((svc.name, size), 0.0)
                    if t <= 0:
                        continue
                    gains[svc.index] = need[svc.index] * (t / req[svc.index])
                i = int(np.argmax(gains))
                if gains[i] <= 0.0:
                    assigns.append(InstanceAssignment(size, None))
                    continue
                svc = w.services[i]
                a = make_assignment(self.space.profile, w, size, svc.name)
                assigns.append(a)
                u = a.throughput / req[i]
                score += need[i] * u
                need[i] = max(0.0, need[i] - u)
            if score > best_score and any(a.service for a in assigns):
                best_score = score
                best_cfg = GPUConfig(partition, tuple(assigns))
        return best_cfg

    def _packed_scan(
        self, need0: np.ndarray
    ) -> Optional[Tuple[np.ndarray, int, np.ndarray]]:
        """Vectorized packed-candidate scan over all full partitions at once.

        Returns ``(utility, row, choices)`` of the winning partition — or
        ``None`` when no partition scores positive — without materializing a
        :class:`GPUConfig` (losing candidates never allocate anything).
        Bit-identical to :meth:`_packed_candidate`.
        """
        tbl = self.space.packed_tables
        if tbl.max_len == 0:
            return None
        # scratch buffers from the tables: valid until the next scan, which
        # is fine — the caller consumes the winning row within the round
        need, gains = tbl.need_buf, tbl.gains_buf
        score, util, choice = tbl.score_buf, tbl.util_buf, tbl.choice_buf
        np.copyto(need, need0[None, :])
        score.fill(0.0)
        util.fill(0.0)
        choice.fill(-1)
        for j, m in enumerate(tbl.M_step):  # m: (k, n) normalized throughputs
            k = m.shape[0]
            g_all = np.multiply(need[:k], m, out=gains[:k])
            pick = g_all.argmax(axis=1)
            rows = tbl.arange[:k]
            g = g_all[rows, pick]
            assigned = g > 0.0
            if not assigned.all():
                if not assigned.any():
                    continue
                rows, pick, g = rows[assigned], pick[assigned], g[assigned]
            uval = m[rows, pick]
            score[rows] += g
            util[rows, pick] += uval
            need[rows, pick] = np.maximum(0.0, need[rows, pick] - uval)
            choice[rows, j] = pick
        # earliest-partition winner in full_partitions() order, as the
        # scalar loop's strict `score > best_score` replacement rule keeps it
        score_orig = score[tbl.orig_to_row]
        w = int(np.argmax(score_orig))
        if score_orig[w] <= 0.0:
            return None
        row = int(tbl.orig_to_row[w])
        return util[row], row, choice[row]

    def _build_packed(self, row: int, choices: np.ndarray) -> GPUConfig:
        """Materialize the winning packed candidate from its choice row."""
        space = self.space
        tbl = space.packed_tables
        names = space.workload.names
        partition = space.partitions[int(tbl.row_to_orig[row])]
        assigns = tuple(
            space._assign[
                (names[int(choices[j])] if choices[j] >= 0 else None,
                 int(tbl.step_size[row, j]))
            ]
            for j in range(int(tbl.row_len[row]))
        )
        return GPUConfig(partition, assigns)

    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        return self._produce(completion)[0]

    def produce_indexed(self, completion: np.ndarray) -> IndexedDeployment:
        """``produce`` in the array-native representation (config order is
        forgotten; completion math stays two gathers from here on)."""
        _, counts, extras = self._produce(completion)
        return IndexedDeployment(self.space, counts, extras)

    def _produce(
        self, completion: np.ndarray
    ) -> Tuple[List[GPUConfig], np.ndarray, List[GPUConfig]]:
        space = self.space
        ia, ib, ua, ub = space.ia, space.ib, space.ua, space.ub
        c = completion.astype(np.float64).copy()
        need = np.clip(1.0 - c, 0.0, None)
        scores = need[ia] * ua + need[ib] * ub
        out: List[GPUConfig] = []
        counts = np.zeros(len(space), dtype=np.int64)
        extras: List[GPUConfig] = []
        guard = 0
        while np.any(c < 1.0 - 1e-9):
            guard += 1
            if guard > 100_000:
                raise RuntimeError("greedy failed to converge")
            idx = int(np.argmax(scores)) if len(scores) else 0
            best_score = float(scores[idx]) if len(scores) else 0.0
            # Fig. 15 lines 18-22: a packed >2-service candidate competes on
            # score every round; it wins exactly in the near-satisfied tail,
            # where two services no longer saturate a device.
            packed = self._packed_scan(need)
            chosen_packed = None
            if packed is not None:
                pu, row, choices = packed
                ps = float(np.sum(need * pu))
                if ps > best_score:
                    chosen_packed, best_score = (pu, row, choices), ps
            if best_score <= 0.0:
                raise RuntimeError(
                    "no config has positive score but SLOs unmet — "
                    "some service is infeasible on every instance size"
                )
            if chosen_packed is None:
                out.append(space.configs[idx])
                counts[idx] += 1
                i, j = int(ia[idx]), int(ib[idx])
                c[i] += ua[idx]
                c[j] += ub[idx]
                changed = (i,) if i == j else (i, j)
            else:
                pu, row, choices = chosen_packed
                cfg = self._build_packed(row, choices)
                out.append(cfg)
                extras.append(cfg)
                c += pu
                changed = tuple(int(t) for t in np.flatnonzero(pu))
            # incremental maintenance: only configs touching a changed
            # service can change score
            for i in changed:
                need[i] = max(0.0, 1.0 - c[i])
            upd = (
                space.service_configs[changed[0]]
                if len(changed) == 1
                else np.concatenate([space.service_configs[i] for i in changed])
            )
            scores[upd] = need[ia[upd]] * ua[upd] + need[ib[upd]] * ub[upd]
        return out, counts, extras


# ---------------------------------------------------------------------------
# Warm-start repair (incremental reoptimization)
# ---------------------------------------------------------------------------


def warm_repair(
    space: ConfigSpace,
    fast: OptimizerProcedure,
    incumbent: IndexedDeployment,
    edit_budget: Optional[int] = None,
    deadline: Optional[float] = None,
) -> Optional[Tuple[IndexedDeployment, int]]:
    """Repair ``incumbent`` against ``space``'s (drifted) workload.

    Instead of packing a deployment from empty, start from the incumbent's
    completion under the new required rates and edit it: an *add* phase runs
    the fast algorithm from the incumbent's completion (covering only the
    deficit), then a *trim* phase drops devices the (possibly lower) demand
    no longer needs.  One edit = one device added or removed, the same count
    :func:`repro.core.ga.deployment_edit_distance` measures — the §6
    controller pays per device changed, so bounding edits bounds transition
    cost.

    Returns ``(repaired, edits)``; ``None`` when the mandatory adds alone
    exceed ``edit_budget`` (callers fall back to a cold solve).  Trims are
    the anytime part: they stop at ``edit_budget`` or ``deadline`` (a
    ``time.monotonic()`` instant), never at the cost of validity.
    Deterministic for a fixed (space, incumbent, budget): ties break toward
    the lowest config index, enumerated configs before extras.
    """
    counts = incumbent.counts.copy()
    extras = list(incumbent.extras)
    c = space.completion_of_counts(counts)
    for cfg in extras:
        c = c + space.utility_cached(cfg)
    edits = 0
    # -- add phase (mandatory): cover the deficit left by upward drift ------
    if bool(np.any(c < 1.0 - 1e-9)):
        added = fast.produce(c.copy())
        edits += len(added)
        if edit_budget is not None and edits > edit_budget:
            return None
        for cfg in added:
            i = space.index_of(cfg)
            if i >= 0:
                counts[i] += 1
                c = c + space.utility_of(i)
            else:
                extras.append(cfg)
                c = c + space.utility_cached(cfg)
    # -- trim phase (anytime): shed devices over-provisioned by downward
    # drift, largest normalized utility first; every intermediate state is a
    # valid deployment, so stopping early is always safe
    ia, ib, ua, ub = space.ia, space.ib, space.ua, space.ub
    while edit_budget is None or edits < edit_budget:
        if deadline is not None and time.monotonic() >= deadline:
            break
        gi, g_best = -1, 0.0
        if len(counts):
            removable = (counts > 0) & (c[ia] - ua >= 1.0) & (c[ib] - ub >= 1.0)
            if bool(removable.any()):
                gain = np.where(removable, ua + ub, -1.0)
                gi = int(np.argmax(gain))
                g_best = float(gain[gi])
        ei, e_best = -1, 0.0
        for k, cfg in enumerate(extras):
            u = space.utility_cached(cfg)
            if bool(np.all(c - u >= 1.0)):
                s = float(u.sum())
                if s > e_best:
                    ei, e_best = k, s
        if gi < 0 and ei < 0:
            break
        if gi >= 0 and g_best >= e_best:
            counts[gi] -= 1
            c = c - space.utility_of(gi)
        else:
            c = c - space.utility_cached(extras.pop(ei))
        edits += 1
    return IndexedDeployment(space, counts, extras), edits
