"""Exact search and tighter lower bounds for small RMS instances
(beyond-paper, DESIGN.md §7.3).

Two tools:

* :func:`per_service_lower_bound` — a *universal* bound: no device config can
  cover more of service s than a whole device dedicated to s (single-service
  configs are in the pair space), so ceil(max_s need_s / best_s) devices are
  required by ANY deployment.  Combined with the paper's LP-style sum bound
  this tightens the optimality gap.

* :class:`PairSpaceExact` — complete depth-first branch-and-bound over the
  ≤2-services-per-device config space (the space the paper's fast/slow
  algorithms search).  Utility-duplicate configs are collapsed and paths are
  enumerated as multisets (non-increasing candidate index), with the
  admissible per-service bound for pruning.  Note: the GA's packed configs
  mix >2 services, so the two-phase optimizer can legitimately beat the
  pair-space optimum — measuring exactly that effect is the point
  (see benchmarks/optimality_gap.py).
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.deployment import ConfigSpace, Deployment, GPUConfig


def _best_per_service(space: ConfigSpace) -> np.ndarray:
    best = np.zeros(space.workload.n)
    for i in range(len(space)):
        best = np.maximum(best, space.utility_of(i))
    return best


def per_service_lower_bound(space: ConfigSpace) -> int:
    """Universal: ceil(max_s 1/best_coverage_s) devices needed."""
    best = _best_per_service(space)
    if np.any(best <= 0):
        raise ValueError("some service is uncoverable")
    return int(math.ceil(float(np.max(1.0 / best)) - 1e-9))


class PairSpaceExact:
    def __init__(self, space: ConfigSpace, node_limit: int = 2_000_000):
        self.space = space
        self.node_limit = node_limit
        self.best_per_device = _best_per_service(space)
        self.nodes = 0
        # collapse configs with identical utility signatures
        sig_seen = {}
        self.cand: List[int] = []
        for i in range(len(space)):
            sig = (
                int(space.ia[i]), int(space.ib[i]),
                round(float(space.ua[i]), 12), round(float(space.ub[i]), 12),
            )
            if sig not in sig_seen:
                sig_seen[sig] = i
                self.cand.append(i)
        # strongest first so good incumbents arrive early
        scores = space.score_all(np.zeros(space.workload.n))
        self.cand.sort(key=lambda i: -scores[i])

    def _bound(self, completion: np.ndarray) -> int:
        need = np.clip(1.0 - completion, 0.0, None)
        with np.errstate(divide="ignore", invalid="ignore"):
            per = np.where(self.best_per_device > 0, need / self.best_per_device, np.inf)
        worst = float(np.max(per)) if per.size else 0.0
        if not math.isfinite(worst):
            return 10**9
        return int(math.ceil(worst - 1e-9))

    def solve(self, ub_deployment: Deployment) -> Tuple[Deployment, bool]:
        """Returns (best pair-space deployment found, completed) — when
        ``completed`` the result is the pair-space optimum."""
        space = self.space
        incumbent = list(ub_deployment.configs)
        best_len = len(incumbent)
        completed = True

        def dfs(completion: np.ndarray, path: List[int], start: int) -> None:
            nonlocal incumbent, best_len, completed
            self.nodes += 1
            if self.nodes > self.node_limit:
                completed = False
                return
            if not np.any(completion < 1.0 - 1e-9):
                if len(path) < best_len:
                    best_len = len(path)
                    incumbent = [space.configs[i] for i in path]
                return
            if len(path) + self._bound(completion) >= best_len:
                return
            need = np.clip(1.0 - completion, 0.0, None)
            # multiset enumeration: only candidates at index >= start
            for pos in range(start, len(self.cand)):
                idx = self.cand[pos]
                u = space.utility_of(idx)
                if float(np.sum(need * u)) <= 0.0:
                    continue  # config helps nothing that is still needed
                dfs(completion + u, path + [idx], pos)

        dfs(np.zeros(space.workload.n), [], 0)
        return Deployment(incumbent), completed
