"""The Reconfigurable Machine Scheduling Problem (RMS) — abstract definitions.

The paper (§3) defines RMS as ``(R_m | reconf | *)``: unrelated parallel
machines that can be *partially* reconfigured under problem-specific
``rule_reconf``.  This module holds the problem-agnostic pieces:

  * :class:`Instance` — a machine (a GPU instance / TPU slice) of a given size.
  * :class:`ReconfigRules` — the ``rule_reconf`` interface: which partitions of
    one reconfigurable device are legal, and which reconfiguration operations
    are permitted.
  * :class:`Service` / :class:`SLO` — jobs.  Serving jobs are long-running
    (§3.3), which spares job-timing decisions.

Concrete rule-sets live in :mod:`repro.core.mig` (the literal A100 rules used
for the paper-faithful reproduction) and :mod:`repro.core.tpu_slice` (the
TPU-pod-slice adaptation described in DESIGN.md §2).
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Dict, List, Sequence, Tuple

Partition = Tuple[int, ...]  # sorted multiset of instance sizes on one device


@dataclasses.dataclass(frozen=True)
class Instance:
    """One machine: an instance of ``size`` resource slices on device ``device_id``.

    ``uid`` disambiguates equal-sized instances on the same device.
    """

    size: int
    device_id: int = -1
    uid: int = -1


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objective: required aggregate throughput (req/s) and a
    per-request latency bound (ms) that every serving instance must meet."""

    throughput: float
    latency_ms: float


@dataclasses.dataclass(frozen=True)
class Service:
    """A long-running DNN serving job."""

    name: str
    slo: SLO
    index: int = -1  # position in the optimizer's service vector


class ReconfigRules(abc.ABC):
    """``rule_reconf`` (§3.1): the legality oracle for device partitions.

    A *partition* is the multiset of instance sizes living on one
    reconfigurable device (one A100 / one TPU allocation domain).  A
    reconfiguration op replaces a sub-multiset ``mset`` of a device's
    partition with ``mset'``; it is legal iff both the old and the new
    partition are legal (§3.3).
    """

    # -- sizes ---------------------------------------------------------------
    @property
    @abc.abstractmethod
    def device_size(self) -> int:
        """Total resource slices on one device (7 for A100, 16 for a TPU domain)."""

    @property
    @abc.abstractmethod
    def instance_sizes(self) -> Sequence[int]:
        """Allocatable instance sizes, ascending (A100: 1,2,3,4,7)."""

    # -- legality ------------------------------------------------------------
    @abc.abstractmethod
    def is_legal_partition(self, partition: Partition) -> bool:
        """True iff this multiset of instance sizes can coexist on one device."""

    @abc.abstractmethod
    def legal_partitions(self) -> List[Partition]:
        """All legal partitions (including non-full ones), sorted multisets."""

    def full_partitions(self) -> List[Partition]:
        """Legal partitions to which no further instance can be added."""
        legal = set(self.legal_partitions())
        full = []
        for p in legal:
            extendable = any(
                tuple(sorted(p + (s,))) in legal for s in self.instance_sizes
            )
            if not extendable:
                full.append(p)
        return sorted(full)

    # -- rule_reconf (§3.3) ---------------------------------------------------
    def rule_reconf(
        self, mset: Sequence[int], mset_new: Sequence[int], partition: Partition
    ) -> bool:
        """Is replacing sub-multiset ``mset`` by ``mset_new`` legal on a device
        currently holding ``partition``?  Implements the paper's definition:
        both the current and the resulting partition must be legal, and the
        removed instances must actually be present."""
        cur = list(partition)
        for s in mset:
            if s not in cur:
                return False
            cur.remove(s)
        new_partition = tuple(sorted(cur + list(mset_new)))
        return self.is_legal_partition(partition) and self.is_legal_partition(
            new_partition
        )

    # -- helpers ---------------------------------------------------------------
    def max_instances(self) -> int:
        return max(len(p) for p in self.legal_partitions())

    def partition_slack(self, partition: Partition) -> int:
        return self.device_size - sum(partition)


def validate_partition_universe(rules: ReconfigRules) -> None:
    """Sanity checks shared by all rule-sets (used by tests and by new
    rule-set authors).  Raises :class:`ValueError` naming the offending
    partition — typed exceptions, not asserts, so the checks survive
    ``python -O`` (contract: no-bare-assert)."""
    legal = rules.legal_partitions()
    if not legal:
        raise ValueError(f"{type(rules).__name__}: no legal partitions")
    for p in legal:
        if p != tuple(sorted(p)):
            raise ValueError(f"partition not sorted: {p}")
        if sum(p) > rules.device_size:
            raise ValueError(
                f"oversubscribed partition {p}: sums to {sum(p)} on a "
                f"size-{rules.device_size} device"
            )
        if not all(s in rules.instance_sizes for s in p):
            raise ValueError(
                f"partition {p} uses a size outside "
                f"{tuple(rules.instance_sizes)}"
            )
        if not rules.is_legal_partition(p):
            raise ValueError(
                f"legal_partitions() returned {p} but is_legal_partition "
                "rejects it — the rule-set's oracles disagree"
            )
