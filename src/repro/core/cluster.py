"""Simulated GPU/TPU cluster and the controller's action vocabulary (§4, §6).

The controller's four action types — instance creation, deletion, migration
(local/remote), and device repartition — are implemented against an
in-memory cluster state with the paper's measured action latencies
(Figure 13c).  On the real system these would be k8s operations (§7); here
the actuation layer is simulated (DESIGN.md §8) while the planning algorithm
is implemented exactly.

The cluster records a **throughput trace**: after every applied action, the
per-service aggregate throughput.  The controller's transparency guarantee —
during a transition every service's throughput stays ≥ min(old, new)
required throughput (§1, §6) — is asserted from this trace by the tests.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.rms import Partition, ReconfigRules

# Action latencies in seconds, read off the paper's Figure 13c.
ACTION_SECONDS = {
    "create": 62.0,
    "delete": 2.0,
    "repartition": 1.0,
    "migrate_local": 64.0,
    "migrate_remote": 70.0,
}

GPUS_PER_MACHINE = 8  # the paper's testbed machines hold 8 A100s each


@dataclasses.dataclass
class InstanceRec:
    uid: int
    size: int
    service: Optional[str]
    throughput: float = 0.0


@dataclasses.dataclass
class GPUState:
    gpu_id: int
    instances: Dict[int, InstanceRec] = dataclasses.field(default_factory=dict)

    @property
    def machine(self) -> int:
        return self.gpu_id // GPUS_PER_MACHINE

    def partition(self) -> Partition:
        return tuple(sorted(r.size for r in self.instances.values()))

    def busy(self) -> bool:
        return any(r.service for r in self.instances.values())


# -- actions -------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str  # create | delete | repartition | migrate
    gpu: int
    size: int = 0
    service: Optional[str] = None
    throughput: float = 0.0
    uid: int = -1
    dst_gpu: int = -1  # migrate only
    add_sizes: Tuple[int, ...] = ()  # repartition only
    remove_uids: Tuple[int, ...] = ()  # repartition only

    def seconds(self) -> float:
        if self.kind == "migrate":
            local = (
                self.gpu // GPUS_PER_MACHINE == self.dst_gpu // GPUS_PER_MACHINE
            )
            return ACTION_SECONDS["migrate_local" if local else "migrate_remote"]
        return ACTION_SECONDS[self.kind]

    def gpus_touched(self) -> Tuple[int, ...]:
        return (self.gpu, self.dst_gpu) if self.kind == "migrate" else (self.gpu,)


class SimulatedCluster:
    """In-memory cluster with legality enforcement and a throughput trace."""

    def __init__(self, rules: ReconfigRules, n_gpus: int):
        self.rules = rules
        self.gpus: Dict[int, GPUState] = {i: GPUState(i) for i in range(n_gpus)}
        self._uid = itertools.count()
        self.trace: List[Tuple[float, Dict[str, float]]] = []
        # instance-level twin of ``trace``: after every action, the busy
        # instances as {uid: (service, size, throughput)}.  The closed-loop
        # simulator (repro.sim) replays this to charge action latencies to
        # in-flight serving capacity; opt-in because it costs an
        # O(busy-instances) snapshot per action and only that driver reads it.
        self.record_instance_trace = False
        self.instance_trace: List[Tuple[float, Dict[int, Tuple[str, int, float]]]] = []
        self.clock = 0.0
        self.actions_applied: List[Action] = []

    # -- queries ----------------------------------------------------------------
    def busy_instances(self) -> Dict[int, Tuple[str, int, float]]:
        """The currently serving instances: uid -> (service, size, req/s)."""
        out: Dict[int, Tuple[str, int, float]] = {}
        for g in self.gpus.values():
            for r in g.instances.values():
                if r.service:
                    out[r.uid] = (r.service, r.size, r.throughput)
        return out

    def throughput(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for g in self.gpus.values():
            for r in g.instances.values():
                if r.service:
                    out[r.service] = out.get(r.service, 0.0) + r.throughput
        return out

    def find_room(self, size: int, prefer: Sequence[int] = ()) -> Optional[int]:
        """A GPU that can legally add a ``size`` instance right now."""
        order = list(prefer) + [g for g in self.gpus if g not in prefer]
        for gid in order:
            cand = tuple(sorted(self.gpus[gid].partition() + (size,)))
            if self.rules.is_legal_partition(cand):
                return gid
        return None

    def grow(self, n: int = 1) -> List[int]:
        new_ids = []
        base = max(self.gpus) + 1 if self.gpus else 0
        for i in range(n):
            self.gpus[base + i] = GPUState(base + i)
            new_ids.append(base + i)
        return new_ids

    def gpus_in_use(self) -> int:
        return sum(1 for g in self.gpus.values() if g.busy())

    # -- mutation ----------------------------------------------------------------
    def apply(self, a: Action) -> int:
        """Apply one action; returns the uid of a created instance (or -1)."""
        created = -1
        if a.kind == "create":
            g = self.gpus[a.gpu]
            new_part = tuple(sorted(g.partition() + (a.size,)))
            if not self.rules.is_legal_partition(new_part):
                raise ValueError(f"illegal create {a.size} on gpu{a.gpu} {g.partition()}")
            created = next(self._uid)
            g.instances[created] = InstanceRec(created, a.size, a.service, a.throughput)
        elif a.kind == "delete":
            g = self.gpus[a.gpu]
            g.instances.pop(a.uid)
        elif a.kind == "migrate":
            g = self.gpus[a.gpu]
            rec = g.instances.pop(a.uid)
            dst = self.gpus[a.dst_gpu]
            new_part = tuple(sorted(dst.partition() + (rec.size,)))
            if not self.rules.is_legal_partition(new_part):
                raise ValueError(f"illegal migrate to gpu{a.dst_gpu}")
            created = next(self._uid)
            dst.instances[created] = dataclasses.replace(rec, uid=created)
        elif a.kind == "repartition":
            g = self.gpus[a.gpu]
            for uid in a.remove_uids:
                rec = g.instances[uid]
                if rec.service is not None:
                    raise ValueError("repartition may only touch idle instances")
                g.instances.pop(uid)
            for s in a.add_sizes:
                uid = next(self._uid)
                g.instances[uid] = InstanceRec(uid, s, None)
            if not self.rules.is_legal_partition(g.partition()):
                raise ValueError(f"illegal repartition on gpu{a.gpu}: {g.partition()}")
        else:
            raise ValueError(a.kind)
        self.clock += a.seconds()
        self.actions_applied.append(a)
        self.trace.append((self.clock, self.throughput()))
        if self.record_instance_trace:
            self.instance_trace.append((self.clock, self.busy_instances()))
        return created


def parallel_makespan(actions: Sequence[Action]) -> float:
    """Dependency-aware makespan: actions conflict iff they touch a common
    GPU (§6 "actions can run in parallel if the affected GPUs are separate");
    order among conflicting actions follows the plan order (list scheduling)."""
    ready: Dict[int, float] = {}
    makespan = 0.0
    for a in actions:
        start = max((ready.get(g, 0.0) for g in a.gpus_touched()), default=0.0)
        end = start + a.seconds()
        for g in a.gpus_touched():
            ready[g] = end
        makespan = max(makespan, end)
    return makespan
