"""Simulated GPU/TPU cluster and the controller's action vocabulary (§4, §6).

The controller's four action types — instance creation, deletion, migration
(local/remote), and device repartition — are implemented against an
in-memory cluster state with the paper's measured action latencies
(Figure 13c).  On the real system these would be k8s operations (§7); here
the actuation layer is simulated (DESIGN.md §8) while the planning algorithm
is implemented exactly.

The cluster records a **throughput trace**: after every applied action, the
per-service aggregate throughput.  The controller's transparency guarantee —
during a transition every service's throughput stays ≥ min(old, new)
required throughput (§1, §6) — is asserted from this trace by the tests.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.rms import Partition, ReconfigRules

# Action latencies in seconds, read off the paper's Figure 13c.  This is
# the ONE canonical copy — the reoptimize driver, the controller, the
# control plane, benchmarks, and tests all import it from here.
ACTION_SECONDS = {
    "create": 62.0,
    "delete": 2.0,
    "repartition": 1.0,
    "migrate_local": 64.0,
    "migrate_remote": 70.0,
}

GPUS_PER_MACHINE = 8  # the paper's testbed machines hold 8 A100s each


class ActionFault(RuntimeError):
    """An injected fault: the action attempt failed *atomically* — cluster
    state is unchanged, but ``wasted_s`` seconds of wall clock were burned
    on the attempt.  Raised out of :meth:`SimulatedCluster.apply` when a
    fault hook (``repro.controlplane.faults``) vetoes the action; the
    reconciler catches it, backs off, and re-plans."""

    def __init__(self, action: "Action", reason: str, wasted_s: float):
        super().__init__(
            f"{action.kind} on gpu{action.gpu} failed: {reason}"
        )
        self.action = action
        self.reason = reason
        self.wasted_s = wasted_s


@dataclasses.dataclass
class InstanceRec:
    uid: int
    size: int
    service: Optional[str]
    throughput: float = 0.0


@dataclasses.dataclass
class GPUState:
    gpu_id: int
    instances: Dict[int, InstanceRec] = dataclasses.field(default_factory=dict)

    @property
    def machine(self) -> int:
        return self.gpu_id // GPUS_PER_MACHINE

    def partition(self) -> Partition:
        return tuple(sorted(r.size for r in self.instances.values()))

    def busy(self) -> bool:
        return any(r.service for r in self.instances.values())


# -- actions -------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str  # create | delete | repartition | migrate
    gpu: int
    size: int = 0
    service: Optional[str] = None
    throughput: float = 0.0
    uid: int = -1
    dst_gpu: int = -1  # migrate only
    add_sizes: Tuple[int, ...] = ()  # repartition only
    remove_uids: Tuple[int, ...] = ()  # repartition only

    def seconds(self) -> float:
        if self.kind == "migrate":
            local = (
                self.gpu // GPUS_PER_MACHINE == self.dst_gpu // GPUS_PER_MACHINE
            )
            return ACTION_SECONDS["migrate_local" if local else "migrate_remote"]
        return ACTION_SECONDS[self.kind]

    def gpus_touched(self) -> Tuple[int, ...]:
        return (self.gpu, self.dst_gpu) if self.kind == "migrate" else (self.gpu,)


class SimulatedCluster:
    """In-memory cluster with legality enforcement and a throughput trace."""

    def __init__(self, rules: ReconfigRules, n_gpus: int):
        self.rules = rules
        self.gpus: Dict[int, GPUState] = {i: GPUState(i) for i in range(n_gpus)}
        self._uid = itertools.count()
        # uid -> home device, for every uid ever minted (uids never move:
        # migration mints a fresh uid on the destination).  The control
        # plane consults this on device failure to also kill uids that only
        # survive inside in-flight transition timelines.
        self.uid_gpu: Dict[int, int] = {}
        self.trace: List[Tuple[float, Dict[str, float]]] = []
        # instance-level twin of ``trace``: after every action, the busy
        # instances as {uid: (service, size, throughput)}.  The closed-loop
        # simulator (repro.sim) replays this to charge action latencies to
        # in-flight serving capacity; opt-in because it costs an
        # O(busy-instances) snapshot per action and only that driver reads it.
        self.record_instance_trace = False
        self.instance_trace: List[Tuple[float, Dict[int, Tuple[str, int, float]]]] = []
        self.clock = 0.0
        self.actions_applied: List[Action] = []
        # actual seconds charged per applied action (== Action.seconds()
        # unless a fault hook stretched it — stragglers); same indexing as
        # actions_applied, so makespan recomputation can honor stragglers
        self.applied_seconds: List[float] = []
        # fault domains (repro.controlplane): failed devices are gone for
        # good (instances lost, never schedulable again); draining devices
        # keep serving but accept no new placements until emptied; cordoned
        # machines accept no new devices (grow skips them)
        self.failed: set = set()
        self.draining: set = set()
        self.cordoned: set = set()
        # optional fault injection point (repro.controlplane.faults): called
        # with each action before it mutates state; returns a latency
        # multiplier (stragglers) or raises ActionFault (botched attempt)
        self.fault_hook = None

    # -- queries ----------------------------------------------------------------
    def busy_instances(self) -> Dict[int, Tuple[str, int, float]]:
        """The currently serving instances: uid -> (service, size, req/s)."""
        out: Dict[int, Tuple[str, int, float]] = {}
        for g in self.gpus.values():
            for r in g.instances.values():
                if r.service:
                    out[r.uid] = (r.service, r.size, r.throughput)
        return out

    def throughput(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for g in self.gpus.values():
            for r in g.instances.values():
                if r.service:
                    out[r.service] = out.get(r.service, 0.0) + r.throughput
        return out

    def schedulable(self, gid: int) -> bool:
        """May new work land on this device? (not failed, not draining)"""
        return gid not in self.failed and gid not in self.draining

    def find_room(self, size: int, prefer: Sequence[int] = ()) -> Optional[int]:
        """A GPU that can legally add a ``size`` instance right now."""
        order = list(prefer) + [g for g in self.gpus if g not in prefer]
        for gid in order:
            if not self.schedulable(gid):
                continue
            cand = tuple(sorted(self.gpus[gid].partition() + (size,)))
            if self.rules.is_legal_partition(cand):
                return gid
        return None

    def grow(self, n: int = 1) -> List[int]:
        new_ids = []
        base = max(self.gpus) + 1 if self.gpus else 0
        for _ in range(n):
            # never provision onto a cordoned machine (node drain, §7)
            while base // GPUS_PER_MACHINE in self.cordoned:
                base = (base // GPUS_PER_MACHINE + 1) * GPUS_PER_MACHINE
            self.gpus[base] = GPUState(base)
            new_ids.append(base)
            base += 1
        return new_ids

    def gpus_in_use(self) -> int:
        return sum(1 for g in self.gpus.values() if g.busy())

    def machine_gpus(self, machine: int) -> List[int]:
        return [gid for gid, g in self.gpus.items() if g.machine == machine]

    # -- fault domains (repro.controlplane) --------------------------------------
    def _note_state(self) -> None:
        self.trace.append((self.clock, self.throughput()))
        if self.record_instance_trace:
            self.instance_trace.append((self.clock, self.busy_instances()))

    def fail_gpu(self, gid: int) -> List[int]:
        """Whole-device failure: every instance on the device vanishes
        instantly (no graceful latency — this is the fault, not an action)
        and the device never schedules again.  Returns the killed uids."""
        g = self.gpus[gid]
        killed = sorted(g.instances)
        g.instances.clear()
        self.failed.add(gid)
        self.draining.discard(gid)
        self._note_state()
        return killed

    def drain_gpu(self, gid: int) -> None:
        """Mark a device draining: its instances keep serving, but nothing
        new lands on it.  The reconciler migrates the survivors off."""
        if gid not in self.failed:
            self.draining.add(gid)

    def drain_machine(self, machine: int) -> List[int]:
        """Drain every device of one machine and cordon it against new
        devices (a node going down for maintenance — the §7 kubernetes
        cordon-and-drain)."""
        self.cordoned.add(machine)
        gids = [g for g in self.machine_gpus(machine) if g not in self.failed]
        for gid in gids:
            self.drain_gpu(gid)
        return gids

    # -- mutation ----------------------------------------------------------------
    def apply(self, a: Action) -> int:
        """Apply one action; returns the uid of a created instance (or -1).

        Actions are atomic: an injected :class:`ActionFault` charges its
        wasted wall clock but leaves cluster state untouched."""
        for gid in a.gpus_touched():
            if gid in self.failed:
                raise ValueError(f"action {a.kind} targets failed gpu{gid}")
        mult = 1.0
        if self.fault_hook is not None:
            try:
                mult = self.fault_hook(a)
            except ActionFault as fault:
                self.clock += fault.wasted_s
                self._note_state()
                raise
        created = -1
        if a.kind == "create":
            g = self.gpus[a.gpu]
            new_part = tuple(sorted(g.partition() + (a.size,)))
            if not self.rules.is_legal_partition(new_part):
                raise ValueError(f"illegal create {a.size} on gpu{a.gpu} {g.partition()}")
            created = next(self._uid)
            g.instances[created] = InstanceRec(created, a.size, a.service, a.throughput)
            self.uid_gpu[created] = a.gpu
        elif a.kind == "delete":
            g = self.gpus[a.gpu]
            g.instances.pop(a.uid)
        elif a.kind == "migrate":
            g = self.gpus[a.gpu]
            rec = g.instances.pop(a.uid)
            dst = self.gpus[a.dst_gpu]
            new_part = tuple(sorted(dst.partition() + (rec.size,)))
            if not self.rules.is_legal_partition(new_part):
                raise ValueError(f"illegal migrate to gpu{a.dst_gpu}")
            created = next(self._uid)
            dst.instances[created] = dataclasses.replace(rec, uid=created)
            self.uid_gpu[created] = a.dst_gpu
        elif a.kind == "repartition":
            g = self.gpus[a.gpu]
            for uid in a.remove_uids:
                rec = g.instances[uid]
                if rec.service is not None:
                    raise ValueError("repartition may only touch idle instances")
                g.instances.pop(uid)
            for s in a.add_sizes:
                uid = next(self._uid)
                g.instances[uid] = InstanceRec(uid, s, None)
                self.uid_gpu[uid] = a.gpu
            if not self.rules.is_legal_partition(g.partition()):
                raise ValueError(f"illegal repartition on gpu{a.gpu}: {g.partition()}")
        else:
            raise ValueError(a.kind)
        seconds = a.seconds() * mult
        self.clock += seconds
        self.actions_applied.append(a)
        self.applied_seconds.append(seconds)
        self.trace.append((self.clock, self.throughput()))
        if self.record_instance_trace:
            self.instance_trace.append((self.clock, self.busy_instances()))
        return created


def parallel_makespan(
    actions: Sequence[Action],
    seconds: Optional[Sequence[float]] = None,
    max_concurrent: Optional[int] = None,
) -> float:
    """Dependency-aware makespan: actions conflict iff they touch a common
    GPU (§6 "actions can run in parallel if the affected GPUs are separate");
    order among conflicting actions follows the plan order (list scheduling).

    ``seconds`` overrides per-action durations (index-aligned with
    ``actions`` — how straggler-stretched charges flow back in);
    ``max_concurrent`` list-schedules over that many executor slots (the
    control plane's bounded concurrency), None meaning unbounded."""
    ready: Dict[int, float] = {}
    makespan = 0.0
    slots: Optional[List[float]] = (
        [0.0] * max_concurrent if max_concurrent else None
    )
    for i, a in enumerate(actions):
        dur = a.seconds() if seconds is None else seconds[i]
        start = max((ready.get(g, 0.0) for g in a.gpus_touched()), default=0.0)
        if slots is not None:
            j = min(range(len(slots)), key=slots.__getitem__)
            start = max(start, slots[j])
            slots[j] = start + dur
        end = start + dur
        for g in a.gpus_touched():
            ready[g] = end
        makespan = max(makespan, end)
    return makespan
