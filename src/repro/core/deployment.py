"""Deployments, GPU configurations, utilities and completion rates (§5.1).

Vocabulary (paper §5.1):

  * **workload** — services with SLOs (required throughput + latency bound).
  * **GPU configuration** — one device's partition plus a service assignment
    (and batch size) per instance.
  * **utility** of a config — vector over services: fraction of each service's
    required throughput this one device contributes.
  * **completion rates** — vector over services: fraction of required
    throughput currently met (capped at 1 for scoring).
  * **deployment** — a list of GPU configurations; valid iff completion
    rates are all ≥ 1.

An *optimizer procedure* (§5.1) maps (profiles, workload, completion rates)
→ a list of GPU configs whose summed utility covers the remaining need.
"""

from __future__ import annotations

import abc
import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import PerfProfile
from repro.core.rms import Partition, ReconfigRules, Service, SLO


@dataclasses.dataclass(frozen=True)
class Workload:
    services: Tuple[Service, ...]

    @staticmethod
    def make(slos: Dict[str, SLO]) -> "Workload":
        return Workload(
            tuple(
                Service(name=n, slo=s, index=i) for i, (n, s) in enumerate(slos.items())
            )
        )

    @property
    def names(self) -> List[str]:
        return [s.name for s in self.services]

    @property
    def n(self) -> int:
        return len(self.services)

    def required(self) -> np.ndarray:
        return np.array([s.slo.throughput for s in self.services], dtype=np.float64)

    def index(self, name: str) -> int:
        for s in self.services:
            if s.name == name:
                return s.index
        raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class InstanceAssignment:
    """One instance inside a GPU config: ``service is None`` means idle."""

    size: int
    service: Optional[str]
    batch: int = 0
    throughput: float = 0.0  # req/s this instance sustains for its service


@dataclasses.dataclass(frozen=True)
class GPUConfig:
    """A device partition plus per-instance service assignments."""

    partition: Partition
    assignments: Tuple[InstanceAssignment, ...]

    def __post_init__(self):
        assert tuple(sorted(a.size for a in self.assignments)) == tuple(
            sorted(self.partition)
        ), "assignments must cover the partition"

    def services_used(self) -> Tuple[str, ...]:
        return tuple(sorted({a.service for a in self.assignments if a.service}))

    def utility(self, workload: Workload) -> np.ndarray:
        """Fraction of each service's SLO throughput this device contributes."""
        u = np.zeros(workload.n)
        req = workload.required()
        for a in self.assignments:
            if a.service is not None:
                i = workload.index(a.service)
                u[i] += a.throughput / req[i]
        return u

    def canonical(self) -> Tuple:
        """Hashable form that ignores instance ordering (instances of equal
        size are interchangeable — the mutation insight, §5.2)."""
        return tuple(
            sorted((a.size, a.service or "", a.batch) for a in self.assignments)
        )


@dataclasses.dataclass
class Deployment:
    configs: List[GPUConfig]

    @property
    def num_gpus(self) -> int:
        return len(self.configs)

    def utility(self, workload: Workload) -> np.ndarray:
        u = np.zeros(workload.n)
        for c in self.configs:
            u += c.utility(workload)
        return u

    def completion_rates(self, workload: Workload) -> np.ndarray:
        return self.utility(workload)

    def is_valid(self, workload: Workload, atol: float = 1e-9) -> bool:
        return bool(np.all(self.completion_rates(workload) >= 1.0 - atol))

    def copy(self) -> "Deployment":
        return Deployment(list(self.configs))


def make_assignment(
    profile: PerfProfile, workload: Workload, size: int, service: Optional[str]
) -> InstanceAssignment:
    """Assign ``service`` to a ``size`` instance at the paper's batching rule:
    largest batch whose latency meets the SLO."""
    if service is None:
        return InstanceAssignment(size, None)
    slo = workload.services[workload.index(service)].slo
    b = profile.best_batch(service, size, slo.latency_ms)
    if b == 0:
        return InstanceAssignment(size, None)  # infeasible: leave idle
    tput = profile.throughput(service, size, slo.latency_ms)
    return InstanceAssignment(size, service, b, tput)


# ---------------------------------------------------------------------------
# Config-space enumeration (§5.1: "the utility space is enormous")
# ---------------------------------------------------------------------------


class ConfigSpace:
    """All GPU configs mixing at most two services (Fig. 15 line 2), scored
    vectorially.

    For each full partition we group equal-sized instances; for a service
    pair (a, b) each size-group of multiplicity m admits m+1 splits.  Configs
    are deduplicated by canonical form.  The utility of each config touches
    ≤ 2 services, so scoring is two sparse gathers (see ``score_all``).
    """

    def __init__(
        self,
        rules: ReconfigRules,
        profile: PerfProfile,
        workload: Workload,
    ):
        self.rules = rules
        self.profile = profile
        self.workload = workload
        self._tput: Dict[Tuple[str, int], float] = {}
        for svc in workload.services:
            for size in rules.instance_sizes:
                self._tput[(svc.name, size)] = profile.throughput(
                    svc.name, size, svc.slo.latency_ms
                )
        self.configs: List[GPUConfig] = []
        self._ia: List[int] = []  # service index a
        self._ib: List[int] = []  # service index b (may equal a)
        self._ua: List[float] = []  # utility toward a
        self._ub: List[float] = []  # utility toward b
        self._build()
        self.ia = np.array(self._ia, dtype=np.int64)
        self.ib = np.array(self._ib, dtype=np.int64)
        self.ua = np.array(self._ua, dtype=np.float64)
        self.ub = np.array(self._ub, dtype=np.float64)

    # -- enumeration -----------------------------------------------------------
    def _config_for_split(
        self, partition: Partition, groups: List[Tuple[int, int]], pick: Tuple[int, ...], a: str, b: str
    ) -> Optional[GPUConfig]:
        assigns: List[InstanceAssignment] = []
        for (size, mult), ja in zip(groups, pick):
            for _ in range(ja):
                assigns.append(make_assignment(self.profile, self.workload, size, a))
            for _ in range(mult - ja):
                assigns.append(make_assignment(self.profile, self.workload, size, b))
        cfg = GPUConfig(partition, tuple(assigns))
        if all(x.service is None for x in cfg.assignments):
            return None
        return cfg

    def _build(self) -> None:
        req = self.workload.required()
        names = self.workload.names
        seen = set()
        partitions = self.rules.full_partitions()
        pairs = list(itertools.combinations(range(len(names)), 2)) + [
            (i, i) for i in range(len(names))
        ]
        for partition in partitions:
            groups = [
                (size, sum(1 for s in partition if s == size))
                for size in sorted(set(partition))
            ]
            ranges = [range(m + 1) for _, m in groups]
            for (i, j) in pairs:
                a, b = names[i], names[j]
                for pick in itertools.product(*ranges):
                    if i == j and any(p != groups[k][1] for k, p in enumerate(pick)):
                        continue  # single-service: only the all-a split
                    cfg = self._config_for_split(partition, groups, pick, a, b)
                    if cfg is None:
                        continue
                    key = cfg.canonical()
                    if key in seen:
                        continue
                    seen.add(key)
                    ta = sum(
                        x.throughput for x in cfg.assignments if x.service == a
                    )
                    tb = sum(
                        x.throughput for x in cfg.assignments if x.service == b
                    )
                    self.configs.append(cfg)
                    self._ia.append(i)
                    self._ib.append(j)
                    self._ua.append(ta / req[i])
                    self._ub.append(tb / req[j] if j != i else 0.0)

    # -- scoring (§5.3) ----------------------------------------------------------
    def score_all(self, completion: np.ndarray) -> np.ndarray:
        """score(config) = Σ_i (1 − c_i)·u_i with c clamped to [0,1]."""
        need = np.clip(1.0 - completion, 0.0, None)
        return need[self.ia] * self.ua + need[self.ib] * self.ub

    def utility_of(self, idx: int) -> np.ndarray:
        u = np.zeros(self.workload.n)
        u[self.ia[idx]] += self.ua[idx]
        u[self.ib[idx]] += self.ub[idx]
        return u

    def __len__(self) -> int:
        return len(self.configs)


class OptimizerProcedure(abc.ABC):
    """§5.1: given completion rates, emit configs covering the residual need.

    Implementations: the fast greedy (Appendix A.1), the MCTS slow algorithm
    (Appendix A.2), and the beyond-paper beam-greedy.  MIG-Serving "is
    designed to be able to switch algorithms easily" (§7) — this ABC is that
    switch point.
    """

    def __init__(self, space: ConfigSpace):
        self.space = space

    @abc.abstractmethod
    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        ...

    def solve(self) -> Deployment:
        return Deployment(self.produce(np.zeros(self.space.workload.n)))
