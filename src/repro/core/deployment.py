"""Deployments, GPU configurations, utilities and completion rates (§5.1).

Vocabulary (paper §5.1):

  * **workload** — services with SLOs (required throughput + latency bound).
  * **GPU configuration** — one device's partition plus a service assignment
    (and batch size) per instance.
  * **utility** of a config — vector over services: fraction of each service's
    required throughput this one device contributes.
  * **completion rates** — vector over services: fraction of required
    throughput currently met (capped at 1 for scoring).
  * **deployment** — a list of GPU configurations; valid iff completion
    rates are all ≥ 1.

An *optimizer procedure* (§5.1) maps (profiles, workload, completion rates)
→ a list of GPU configs whose summed utility covers the remaining need.
"""

from __future__ import annotations

import abc
import dataclasses
import itertools
import weakref
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import PerfProfile
from repro.core.rms import Partition, ReconfigRules, Service, SLO


@dataclasses.dataclass(frozen=True)
class Workload:
    services: Tuple[Service, ...]

    def __post_init__(self):
        # name -> service index, built once: ``index`` is called per
        # assignment in every utility evaluation on the optimizer hot path.
        object.__setattr__(
            self, "_index", {s.name: s.index for s in self.services}
        )

    @staticmethod
    def make(slos: Dict[str, SLO]) -> "Workload":
        return Workload(
            tuple(
                Service(name=n, slo=s, index=i) for i, (n, s) in enumerate(slos.items())
            )
        )

    @property
    def names(self) -> List[str]:
        return [s.name for s in self.services]

    @property
    def n(self) -> int:
        return len(self.services)

    def required(self) -> np.ndarray:
        return np.array([s.slo.throughput for s in self.services], dtype=np.float64)

    def index(self, name: str) -> int:
        return self._index[name]


@dataclasses.dataclass(frozen=True)
class InstanceAssignment:
    """One instance inside a GPU config: ``service is None`` means idle."""

    size: int
    service: Optional[str]
    batch: int = 0
    throughput: float = 0.0  # req/s this instance sustains for its service


@dataclasses.dataclass(frozen=True)
class GPUConfig:
    """A device partition plus per-instance service assignments."""

    partition: Partition
    assignments: Tuple[InstanceAssignment, ...]

    def __post_init__(self):
        sizes = tuple(sorted(a.size for a in self.assignments))
        if sizes != tuple(sorted(self.partition)):
            raise ValueError(
                f"assignments must cover the partition: assignment sizes "
                f"{sizes} != partition {tuple(sorted(self.partition))}"
            )

    def services_used(self) -> Tuple[str, ...]:
        return tuple(sorted({a.service for a in self.assignments if a.service}))

    def utility(self, workload: Workload) -> np.ndarray:
        """Fraction of each service's SLO throughput this device contributes."""
        u = np.zeros(workload.n)
        req = workload.required()
        for a in self.assignments:
            if a.service is not None:
                i = workload.index(a.service)
                u[i] += a.throughput / req[i]
        return u

    def canonical(self) -> Tuple:
        """Hashable form that ignores instance ordering (instances of equal
        size are interchangeable — the mutation insight, §5.2).  Memoized:
        it keys the config-index lookup on every fitness evaluation."""
        c = self.__dict__.get("_canonical")
        if c is None:
            c = tuple(
                sorted((a.size, a.service or "", a.batch) for a in self.assignments)
            )
            self.__dict__["_canonical"] = c
        return c


@dataclasses.dataclass
class Deployment:
    configs: List[GPUConfig]

    @property
    def num_gpus(self) -> int:
        return len(self.configs)

    def utility(self, workload: Workload) -> np.ndarray:
        u = np.zeros(workload.n)
        for c in self.configs:
            u += c.utility(workload)
        return u

    def completion_rates(self, workload: Workload) -> np.ndarray:
        return self.utility(workload)

    def is_valid(self, workload: Workload, atol: float = 1e-9) -> bool:
        return bool(np.all(self.completion_rates(workload) >= 1.0 - atol))

    def copy(self) -> "Deployment":
        return Deployment(list(self.configs))


@dataclasses.dataclass(eq=False)  # auto __eq__ would bool() the counts array
class IndexedDeployment:
    """A deployment as a config-index count vector over a :class:`ConfigSpace`.

    The array-native representation of the optimizer core: ``counts[i]`` is
    the multiplicity of ``space.configs[i]``; configs outside the enumerated
    pair space (the greedy's packed >2-service candidates, exotic mutants)
    ride along in ``extras``.  Completion rates collapse to two sparse
    ``np.bincount`` gathers instead of a Python walk over configs and
    assignments.

    The count vector forgets config *order*, so order-sensitive consumers
    (the §6 controller transitions one target config at a time) should keep
    using :class:`Deployment`; ``to_deployment`` emits enumeration order.
    """

    space: ConfigSpace
    counts: np.ndarray  # (len(space),) int64 multiplicities
    extras: List[GPUConfig] = dataclasses.field(default_factory=list)

    @staticmethod
    def from_deployment(space: ConfigSpace, dep: Deployment) -> "IndexedDeployment":
        counts = np.zeros(len(space), dtype=np.int64)
        extras: List[GPUConfig] = []
        for cfg in dep.configs:
            i = space.index_of(cfg)
            if i >= 0:
                counts[i] += 1
            else:
                extras.append(cfg)
        return IndexedDeployment(space, counts, extras)

    @property
    def num_gpus(self) -> int:
        return int(self.counts.sum()) + len(self.extras)

    def completion_rates(self) -> np.ndarray:
        c = self.space.completion_of_counts(self.counts)
        for cfg in self.extras:
            c = c + self.space.utility_cached(cfg)
        return c

    def is_valid(self, atol: float = 1e-9) -> bool:
        return bool(np.all(self.completion_rates() >= 1.0 - atol))

    def to_deployment(self) -> Deployment:
        configs: List[GPUConfig] = []
        for i in np.flatnonzero(self.counts):
            configs.extend([self.space.configs[int(i)]] * int(self.counts[i]))
        return Deployment(configs + list(self.extras))


def make_assignment(
    profile: PerfProfile, workload: Workload, size: int, service: Optional[str]
) -> InstanceAssignment:
    """Assign ``service`` to a ``size`` instance at the paper's batching rule:
    largest batch whose latency meets the SLO."""
    if service is None:
        return InstanceAssignment(size, None)
    slo = workload.services[workload.index(service)].slo
    b = profile.best_batch(service, size, slo.latency_ms)
    if b == 0:
        return InstanceAssignment(size, None)  # infeasible: leave idle
    tput = profile.throughput(service, size, slo.latency_ms)
    return InstanceAssignment(size, service, b, tput)


# ---------------------------------------------------------------------------
# Config-space enumeration (§5.1: "the utility space is enormous")
# ---------------------------------------------------------------------------


class ConfigSpace:
    """All GPU configs mixing at most two services (Fig. 15 line 2), scored
    vectorially.

    For each full partition we group equal-sized instances; for a service
    pair (a, b) each size-group of multiplicity m admits m+1 splits.  Configs
    are deduplicated by canonical form.  The utility of each config touches
    ≤ 2 services, so scoring is two sparse gathers (see ``score_all``).
    """

    def __init__(
        self,
        rules: ReconfigRules,
        profile: PerfProfile,
        workload: Workload,
    ):
        self.rules = rules
        self.profile = profile
        self.workload = workload
        self.req = workload.required()
        self.partitions: List[Partition] = rules.full_partitions()
        self._tput: Dict[Tuple[str, int], float] = {}
        self._batch: Dict[Tuple[str, int], int] = {}
        # (service, size) -> the one InstanceAssignment every config shares;
        # assignments are frozen, so enumeration and the packed-candidate
        # builder reuse objects instead of re-deriving batch/throughput.
        self._assign: Dict[Tuple[Optional[str], int], InstanceAssignment] = {
            (None, size): InstanceAssignment(size, None)
            for size in rules.instance_sizes
        }
        for svc in workload.services:
            for size in rules.instance_sizes:
                t = profile.throughput(svc.name, size, svc.slo.latency_ms)
                b = profile.best_batch(svc.name, size, svc.slo.latency_ms)
                self._tput[(svc.name, size)] = t
                self._batch[(svc.name, size)] = b
                self._assign[(svc.name, size)] = (
                    InstanceAssignment(size, svc.name, b, t)
                    if b > 0
                    else InstanceAssignment(size, None)  # infeasible: idle
                )
        self.configs: List[GPUConfig] = []
        self._ia: List[int] = []  # service index a
        self._ib: List[int] = []  # service index b (may equal a)
        self._ua: List[float] = []  # utility toward a
        self._ub: List[float] = []  # utility toward b
        self._ta: List[float] = []  # raw throughput toward a (for rebind)
        self._tb: List[float] = []  # raw throughput toward b
        self._index_of: Dict[Tuple, int] = {}  # canonical form -> config index
        self._build()
        self.ia = np.array(self._ia, dtype=np.int64)
        self.ib = np.array(self._ib, dtype=np.int64)
        self.ua = np.array(self._ua, dtype=np.float64)
        self.ub = np.array(self._ub, dtype=np.float64)
        self.ta = np.array(self._ta, dtype=np.float64)
        self.tb = np.array(self._tb, dtype=np.float64)
        # per-service boolean masks over the config space: row i is True at
        # configs touching service i (MCTS edge generation unions these
        # instead of scanning every config in Python).
        cidx = np.arange(len(self.configs))
        self.service_masks = np.zeros((workload.n, len(self.configs)), dtype=bool)
        if len(self.configs):
            self.service_masks[self.ia, cidx] = True
            self.service_masks[self.ib, cidx] = True
        # per-service config index lists, for incremental score maintenance
        self.service_configs: List[np.ndarray] = [
            np.flatnonzero(self.service_masks[i]) for i in range(workload.n)
        ]
        self._util_matrix: Optional[np.ndarray] = None
        self._packed_tables: Optional["_PackedTables"] = None

    # -- enumeration -----------------------------------------------------------
    def _config_for_split(
        self, partition: Partition, groups: List[Tuple[int, int]], pick: Tuple[int, ...], a: str, b: str
    ) -> Optional[GPUConfig]:
        assigns: List[InstanceAssignment] = []
        for (size, mult), ja in zip(groups, pick):
            assigns.extend([self._assign[(a, size)]] * ja)
            assigns.extend([self._assign[(b, size)]] * (mult - ja))
        if all(x.service is None for x in assigns):
            return None
        return GPUConfig(partition, tuple(assigns))

    def _build(self) -> None:
        req = self.req
        names = self.workload.names
        pairs = list(itertools.combinations(range(len(names)), 2)) + [
            (i, i) for i in range(len(names))
        ]
        for partition in self.partitions:
            groups = [
                (size, sum(1 for s in partition if s == size))
                for size in sorted(set(partition))
            ]
            ranges = [range(m + 1) for _, m in groups]
            for (i, j) in pairs:
                a, b = names[i], names[j]
                for pick in itertools.product(*ranges):
                    if i == j and any(p != groups[k][1] for k, p in enumerate(pick)):
                        continue  # single-service: only the all-a split
                    cfg = self._config_for_split(partition, groups, pick, a, b)
                    if cfg is None:
                        continue
                    key = cfg.canonical()
                    if key in self._index_of:
                        continue
                    self._index_of[key] = len(self.configs)
                    ta = sum(
                        x.throughput for x in cfg.assignments if x.service == a
                    )
                    self.configs.append(cfg)
                    self._ia.append(i)
                    self._ib.append(j)
                    self._ua.append(ta / req[i])
                    self._ta.append(ta)
                    if j != i:
                        tb = sum(
                            x.throughput for x in cfg.assignments if x.service == b
                        )
                        self._ub.append(tb / req[j])
                        self._tb.append(tb)
                    else:
                        self._ub.append(0.0)
                        self._tb.append(0.0)

    # -- scoring (§5.3) ----------------------------------------------------------
    def score_all(self, completion: np.ndarray) -> np.ndarray:
        """score(config) = Σ_i (1 − c_i)·u_i with c clamped to [0,1]."""
        # np.maximum is np.clip(lo=0, hi=None) minus the dispatch overhead
        need = np.maximum(1.0 - completion, 0.0)
        return need[self.ia] * self.ua + need[self.ib] * self.ub

    def utility_of(self, idx: int) -> np.ndarray:
        u = np.zeros(self.workload.n)
        u[self.ia[idx]] += self.ua[idx]
        u[self.ib[idx]] += self.ub[idx]
        return u

    # -- the array-native fast path ----------------------------------------------
    def index_of(self, cfg: GPUConfig) -> int:
        """Index of ``cfg`` in the enumerated space, or -1 when it lies
        outside it (packed >2-service candidates, exotic mutants)."""
        return self._index_of.get(cfg.canonical(), -1)

    def utility_cached(self, cfg: GPUConfig) -> np.ndarray:
        """Exact ``cfg.utility(workload)``, computed once per config object.

        The returned array is shared — treat it as read-only.  The memo is
        per *object*, not per canonical form: canonical-equal configs built
        with different instance orderings can sum to utilities differing in
        the last ulp, and the bit-identity contract (``fitness_batch`` ==
        the scalar ``_fitness``) requires each object to see exactly its own
        ``cfg.utility`` result.  The space is held through a weakref so a
        long-lived deployment doesn't pin every ConfigSpace it ever met.
        """
        memo = cfg.__dict__.get("_util")
        if memo is not None and memo[0]() is self:
            return memo[1]
        u = cfg.utility(self.workload)
        cfg.__dict__["_util"] = (weakref.ref(self), u)
        return u

    @property
    def util_matrix(self) -> np.ndarray:
        """Dense ``(num_configs, n)`` utility rows; row ``i`` equals
        ``utility_of(i)`` bit-for-bit (built by two scatter-adds)."""
        if self._util_matrix is None:
            m = np.zeros((len(self.configs), self.workload.n))
            if len(self.configs):
                cidx = np.arange(len(self.configs))
                np.add.at(m, (cidx, self.ia), self.ua)
                np.add.at(m, (cidx, self.ib), self.ub)
            self._util_matrix = m
        return self._util_matrix

    def completion_of_counts(self, counts: np.ndarray) -> np.ndarray:
        """Completion rates of a config-index count vector: two sparse
        ``np.bincount`` gathers over the (ia, ua)/(ib, ub) structure."""
        n = self.workload.n
        nz = np.flatnonzero(counts)
        if not len(nz):
            return np.zeros(n)
        w = counts[nz].astype(np.float64)
        c = np.bincount(self.ia[nz], weights=w * self.ua[nz], minlength=n)
        c += np.bincount(self.ib[nz], weights=w * self.ub[nz], minlength=n)
        return c

    def completion_of_count_matrix(self, counts: np.ndarray) -> np.ndarray:
        """Batched completion: ``(P, num_configs)`` counts -> ``(P, n)``
        completions in one matrix multiply against :attr:`util_matrix`."""
        return counts @ self.util_matrix

    @property
    def packed_tables(self) -> "_PackedTables":
        """Precomputed arrays for the vectorized packed-candidate scan."""
        if self._packed_tables is None:
            self._packed_tables = _PackedTables(self)
        return self._packed_tables

    # -- warm-start rebinding ----------------------------------------------------
    def compatible(self, workload: Workload) -> bool:
        """True when ``workload`` differs from this space's only in required
        throughputs: same service names in the same order, same latency SLOs.
        Enumeration (configs, assignments, batch sizes) depends only on names
        and latency bounds, so a compatible workload can :meth:`rebind`."""
        if workload.names != self.workload.names:
            return False
        return all(
            a.slo.latency_ms == b.slo.latency_ms
            for a, b in zip(workload.services, self.workload.services)
        )

    def rebind(self, workload: Workload) -> "ConfigSpace":
        """A ConfigSpace over ``workload`` sharing this one's enumeration.

        The reoptimize loop's workloads differ only in required rates (traffic
        drift), which enter the space solely through the ``t / req`` utility
        normalization.  Rebinding recomputes those divisions from the stored
        raw throughputs — the identical IEEE operations a cold build performs,
        so a rebound space is bit-identical to a fresh ``ConfigSpace`` (pinned
        by tests) at a fraction of the cost.  Config indices carry over
        one-for-one, so incumbent count vectors need no remapping.
        """
        if not self.compatible(workload):
            raise ValueError(
                "rebind requires identical service names and latency SLOs; "
                "build a fresh ConfigSpace instead"
            )
        new = object.__new__(ConfigSpace)
        new.rules = self.rules
        new.profile = self.profile
        new.workload = workload
        new.req = workload.required()
        new.partitions = self.partitions
        new._tput = self._tput
        new._batch = self._batch
        new._assign = self._assign
        new.configs = self.configs
        new._ia = self._ia
        new._ib = self._ib
        new._ua = self._ua
        new._ub = self._ub
        new._ta = self._ta
        new._tb = self._tb
        new._index_of = self._index_of
        new.ia = self.ia
        new.ib = self.ib
        new.ta = self.ta
        new.tb = self.tb
        # the only req-dependent arrays: same element-wise divisions _build
        # performs (ta / req[i]), so results match a cold build bit-for-bit
        new.ua = self.ta / new.req[self.ia] if len(self.ia) else self.ua
        new.ub = self.tb / new.req[self.ib] if len(self.ib) else self.ub
        new.service_masks = self.service_masks
        new.service_configs = self.service_configs
        new._util_matrix = None  # req-dependent lazies rebuild on demand
        new._packed_tables = None
        return new

    def __len__(self) -> int:
        return len(self.configs)


class _PackedTables:
    """Arrays driving the vectorized Fig.-15 packed-candidate scan.

    Partitions become rows, sorted by instance count (descending) so that at
    step ``j`` exactly the first ``active[j]`` rows still have an instance to
    assign; ``M[k, i]`` is service ``i``'s throughput on size-slot ``k``
    normalized by its required rate — the same ``t / req_i`` the scalar loop
    computed, so the vectorized scan reproduces it float-for-float.
    """

    def __init__(self, space: ConfigSpace):
        n = space.workload.n
        sizes = sorted({s for p in space.partitions for s in p})
        slot = {s: k for k, s in enumerate(sizes)}
        self.M = np.zeros((len(sizes), n))
        for k, s in enumerate(sizes):
            for svc in space.workload.services:
                self.M[k, svc.index] = (
                    space._tput[(svc.name, s)] / space.req[svc.index]
                )
        seqs = [sorted(p, reverse=True) for p in space.partitions]
        self.P = len(seqs)
        order = sorted(range(self.P), key=lambda i: -len(seqs[i]))
        self.row_to_orig = np.array(order, dtype=np.int64)
        self.orig_to_row = np.empty(self.P, dtype=np.int64)
        self.orig_to_row[self.row_to_orig] = np.arange(self.P)
        self.max_len = max((len(s) for s in seqs), default=0)
        self.step_slot = np.zeros((self.P, self.max_len), dtype=np.int64)
        self.step_size = np.zeros((self.P, self.max_len), dtype=np.int64)
        self.row_len = np.zeros(self.P, dtype=np.int64)
        for r, oi in enumerate(order):
            self.row_len[r] = len(seqs[oi])
            for j, s in enumerate(seqs[oi]):
                self.step_slot[r, j] = slot[s]
                self.step_size[r, j] = s
        self.active = np.array(
            [int(np.sum(self.row_len > j)) for j in range(self.max_len)],
            dtype=np.int64,
        )
        # per-step pre-gathered normalized-throughput rows: M_step[j][r] is
        # row r's instance at step j (rows are length-sorted, so the first
        # active[j] rows are exactly the live ones)
        self.M_step = [
            self.M[self.step_slot[: int(self.active[j]), j]]
            for j in range(self.max_len)
        ]
        self.arange = np.arange(self.P)
        # scratch buffers reused by every packed scan (single-threaded hot
        # loop; contents are only valid until the next scan)
        self.need_buf = np.zeros((self.P, n))
        self.gains_buf = np.zeros((self.P, n))
        self.util_buf = np.zeros((self.P, n))
        self.score_buf = np.zeros(self.P)
        self.choice_buf = np.full((self.P, max(self.max_len, 1)), -1, dtype=np.int64)


class OptimizerProcedure(abc.ABC):
    """§5.1: given completion rates, emit configs covering the residual need.

    Implementations: the fast greedy (Appendix A.1), the MCTS slow algorithm
    (Appendix A.2), and the beyond-paper beam-greedy.  MIG-Serving "is
    designed to be able to switch algorithms easily" (§7) — this ABC is that
    switch point.
    """

    def __init__(self, space: ConfigSpace):
        self.space = space

    @abc.abstractmethod
    def produce(self, completion: np.ndarray) -> List[GPUConfig]:
        ...

    def solve(self) -> Deployment:
        return Deployment(self.produce(np.zeros(self.space.workload.n)))

    def solve_indexed(self) -> IndexedDeployment:
        """``solve()`` in the array-native representation."""
        return IndexedDeployment.from_deployment(self.space, self.solve())
