"""Config registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``.

One module per assigned architecture; each cites its source paper/model card
and carries the exact numbers from the assignment.  ``smoke`` variants are
reduced same-family configs (≤2 layers, d_model ≤ 512, ≤4 experts) used by
the per-arch CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen3-8b": "qwen3_8b",
    "mamba2-370m": "mamba2_370m",
    "internvl2-1b": "internvl2_1b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "musicgen-large": "musicgen_large",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-20b": "granite_20b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama3-405b": "llama3_405b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg: ModelConfig = mod.CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(arch_id: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg: ModelConfig = mod.SMOKE
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def long_context_variant(cfg: ModelConfig, window: int = 8192) -> ModelConfig:
    """The sliding-window variant used for ``long_500k`` on architectures
    whose attention is otherwise full (DESIGN.md §4).  SSM archs need no
    change; hybrids window only their shared-attention block."""
    if cfg.arch_type == "ssm":
        return cfg
    return dataclasses.replace(cfg, sliding_window=window)
