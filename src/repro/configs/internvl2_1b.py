"""internvl2-1b [vlm] — InternViT + InternLM2/Qwen2-0.5B language backbone.
[arXiv:2404.16821]

The vision frontend (InternViT + MLP projector) is a STUB per the assignment
brief: ``input_specs()`` supplies pre-projected patch embeddings of shape
(batch, frontend_tokens, d_model); this config describes the language
decoder that consumes them.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    modality="vision_stub",
    frontend_tokens=256,
    rope_theta=1e6,
    citation="arXiv:2404.16821",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    arch_type="vlm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    modality="vision_stub",
    frontend_tokens=16,
    citation="arXiv:2404.16821 (reduced)",
)
