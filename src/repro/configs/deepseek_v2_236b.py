"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.
[arXiv:2405.04434]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    attention_kind="mla",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=12288,  # dense layers' FFN width
    vocab_size=102400,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    num_experts=160,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    citation="arXiv:2405.04434",
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    arch_type="moe",
    attention_kind="mla",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    kv_lora_rank=32,
    q_lora_rank=48,
    rope_head_dim=16,
    nope_head_dim=32,
    v_head_dim=32,
    num_experts=4,
    experts_per_token=2,
    num_shared_experts=1,
    moe_d_ff=64,
    first_dense_layers=1,
    citation="arXiv:2405.04434 (reduced)",
)
