"""phi4-mini-3.8b [dense] — RoPE, SwiGLU, GQA.  [arXiv:2412.08905]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    citation="arXiv:2412.08905",
)

SMOKE = ModelConfig(
    name="phi4-smoke",
    arch_type="dense",
    num_layers=2,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    citation="arXiv:2412.08905 (reduced)",
)
