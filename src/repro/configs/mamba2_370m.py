"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    attention_kind="none",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    conv_width=4,
    citation="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    arch_type="ssm",
    attention_kind="none",
    num_layers=2,
    d_model=128,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    conv_width=4,
    ssm_chunk=16,
    citation="arXiv:2405.21060 (reduced)",
)
