"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

The EnCodec tokenizer/conv frontend is a STUB per the assignment brief:
``input_specs()`` supplies frame embeddings; this config is the decoder
backbone (vocab = 2048 codebook entries).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    modality="audio_stub",
    frontend_tokens=256,
    citation="arXiv:2306.05284",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    arch_type="audio",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    modality="audio_stub",
    frontend_tokens=16,
    citation="arXiv:2306.05284 (reduced)",
)
