"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention blocks.
[arXiv:2411.15242]

38 Mamba2 layers; one *shared* GQA block (single weight set) invoked after
every ``shared_attn_every`` Mamba2 layers.  DESIGN.md §8 records the cadence
simplification (every 2nd layer so the 38-layer stack scans as 19 uniform
superblocks).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    conv_width=4,
    shared_attn_every=2,
    citation="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    arch_type="hybrid",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    conv_width=4,
    shared_attn_every=2,
    ssm_chunk=16,
    citation="arXiv:2411.15242 (reduced)",
)
