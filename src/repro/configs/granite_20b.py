"""granite-20b [dense] — llama-arch code model, MQA (kv=1).  [arXiv:2405.04324]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    arch_type="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_gated=False,  # GPT-BigCode-style GELU MLP
    citation="arXiv:2405.04324",
)

SMOKE = ModelConfig(
    name="granite-smoke",
    arch_type="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    mlp_gated=False,
    citation="arXiv:2405.04324 (reduced)",
)
