"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    attention_kind="mla",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,  # dense layers' FFN width
    vocab_size=129280,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    mtp=True,
    citation="arXiv:2412.19437",
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    arch_type="moe",
    attention_kind="mla",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    kv_lora_rank=32,
    q_lora_rank=48,
    rope_head_dim=16,
    nope_head_dim=32,
    v_head_dim=32,
    num_experts=4,
    experts_per_token=2,
    num_shared_experts=1,
    moe_d_ff=64,
    first_dense_layers=1,
    mtp=True,
    citation="arXiv:2412.19437 (reduced)",
)
