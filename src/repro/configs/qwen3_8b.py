"""qwen3-8b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    citation="hf:Qwen/Qwen3-8B",
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    arch_type="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    qk_norm=True,
    citation="hf:Qwen/Qwen3-8B (reduced)",
)
