"""Closed-loop trace-driven cluster serving simulation (Figures 13-14).

The subsystem wiring the repo's isolated pieces — router, profiles,
optimizer pipeline, exchange-and-compact controller — into the paper's
closed loop: traffic arrives, gets routed over MIG instances, SLO
attainment is measured, and a periodic re-optimizer executes transparent
transitions whose Figure-13c action latencies are charged to in-flight
capacity.

Extension points (see ROADMAP.md "Simulator"):

  * new trace shapes  -> add a generator in :mod:`repro.sim.traffic`
  * SLO policies      -> :class:`SimConfig` (headroom, latency, cadence)
  * algorithm swaps   -> ``optimizer_kwargs`` routes to
                         :class:`repro.core.optimizer.TwoPhaseOptimizer`'s
                         registry (``fast=/slow=``)
"""

from repro.controlplane.faults import FAULT_PROFILES
from repro.sim.events import Clock, Event, EventQueue
from repro.sim.reoptimize import PendingTransition, ReoptimizeDriver
from repro.sim.report import (
    FaultRecord,
    ServiceTimeline,
    SimReport,
    TransitionRecord,
)
from repro.sim.scenarios import (
    FLUID_SCHEDULERS,
    PRIORITY_MIXES,
    SCALES,
    SCHEDULERS,
    SLO_POLICIES,
    TRACE_SHAPES,
    CellResult,
    ScaleSpec,
    ScenarioCell,
    build_cell,
    default_matrix,
    run_cell,
    run_cell_obs,
    run_matrix,
    smoke_matrix,
)
from repro.sim.servemodel import (
    InstanceModel,
    TokenKnobs,
    TokenRequest,
    TokenServingState,
)
from repro.sim.simulator import ClusterSimulator, SimConfig
from repro.sim.traffic import (
    PRIORITY_CLASSES,
    PriorityMix,
    Trace,
    correlated_surge_trace,
    diurnal_trace,
    flash_crowd_trace,
    poisson_burst_trace,
    replay_trace,
)

__all__ = [
    "Clock", "ClusterSimulator", "Event", "EventQueue", "FaultRecord",
    "PendingTransition", "ReoptimizeDriver", "ServiceTimeline", "SimConfig",
    "SimReport", "Trace", "TransitionRecord", "correlated_surge_trace",
    "diurnal_trace", "flash_crowd_trace", "poisson_burst_trace",
    "replay_trace", "FAULT_PROFILES", "FLUID_SCHEDULERS", "SCALES",
    "SCHEDULERS", "SLO_POLICIES",
    "TRACE_SHAPES", "CellResult", "ScaleSpec", "ScenarioCell", "build_cell",
    "default_matrix", "run_cell", "run_cell_obs", "run_matrix",
    "smoke_matrix",
    "InstanceModel", "TokenKnobs", "TokenRequest", "TokenServingState",
    "PRIORITY_CLASSES", "PRIORITY_MIXES", "PriorityMix",
]
