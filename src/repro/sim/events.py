"""Discrete-event machinery: a simulation clock and a deterministic queue.

Events fire in (time, sequence-number) order, so two events scheduled for
the same instant pop in the order they were pushed — the tie-break that
keeps a simulation run reproducible regardless of heap internals.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Iterator, Optional

# Event kinds used by the cluster simulator.
BIN_TICK = "bin_tick"  # process one traffic bin
REOPTIMIZE = "reoptimize"  # periodic observe -> optimize -> transition
TRANSITION_DONE = "transition_done"  # a controller transition finished
FAULT = "fault"  # an injected device fault fires (repro.controlplane)
RECONCILE = "reconcile"  # the control plane reacts to observed divergence
END = "end"  # end of trace


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: Any = dataclasses.field(compare=False, default=None)


class EventQueue:
    """Min-heap of events with deterministic FIFO tie-breaking."""

    def __init__(self) -> None:
        self._heap: list = []
        self._seq = itertools.count()

    def push(self, time: float, kind: str, payload: Any = None) -> Event:
        ev = Event(time, next(self._seq), kind, payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()


class Clock:
    """Monotone simulation clock; advancing backwards is a bug."""

    def __init__(self, t0: float = 0.0) -> None:
        self.now = t0

    def advance_to(self, t: float) -> float:
        # a real exception, not an assert: this invariant must hold even
        # under ``python -O``, where asserts are compiled away
        if t < self.now - 1e-9:
            raise RuntimeError(f"clock moved backwards: {self.now} -> {t}")
        self.now = max(self.now, t)
        return self.now
