"""Simulation reports: per-bin timelines, transition records, summaries.

A :class:`SimReport` is the simulator's only output — everything the
benchmarks and tests consume (SLO attainment, transition makespans, the §6
transparency margin) is derived from it.  ``to_json()`` is deterministic
(sorted keys, canonical float repr), so two runs with the same seed must
produce byte-identical serializations — the property the test suite pins.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TransitionRecord:
    """One re-optimization + controller transition executed mid-run."""

    start_s: float  # sim time the reoptimize fired
    end_s: float  # sim time in-flight actions all finished
    serial_seconds: float
    parallel_seconds: float
    action_counts: Dict[str, int]
    old_required: Dict[str, float]  # SLO throughput before
    new_required: Dict[str, float]  # SLO throughput after
    gpus_before: int
    gpus_after: int
    # min over trace points of (capacity - min(old, new) required), per service;
    # the §6 transparency guarantee is exactly: every value >= 0.
    transparency_margin: Dict[str, float]
    # control-plane extensions: populated ONLY under a fault profile (the
    # serializer skips them when None, so default-mode reports keep their
    # exact pre-control-plane bytes)
    trigger: str = "demand"  # "demand" | "fault" — what fired this pass
    reconcile: Optional[Dict] = None  # ReconcileStats.to_dict()

    @property
    def transparent(self) -> bool:
        return all(m >= -1e-6 for m in self.transparency_margin.values())


@dataclasses.dataclass
class FaultRecord:
    """One injected device-level fault (repro.controlplane.faults)."""

    time_s: float
    kind: str  # "gpu_failure" | "node_drain" | "instance_crash"
    target: int  # gpu id (failure), machine id (drain) or uid (crash)
    fault_domain: str
    killed_instances: int
    lost_throughput: Dict[str, float]  # per-service req/s that vanished
    # instance_crash only: in-flight requests (token mode) or backlogged
    # fluid requests that spilled with their work lost.  Serialized only
    # for crash records so historical fault-profile bytes stay identical.
    spilled: float = 0.0

    def to_dict(self) -> Dict:
        return {
            "time_s": self.time_s,
            "kind": self.kind,
            "target": self.target,
            "fault_domain": self.fault_domain,
            "killed_instances": self.killed_instances,
            "lost_throughput": dict(sorted(self.lost_throughput.items())),
            **(
                {"spilled": self.spilled}
                if self.kind == "instance_crash"
                else {}
            ),
        }


@dataclasses.dataclass
class ServiceTimeline:
    """Per-bin series for one service (arrays of length num_bins)."""

    arrivals: np.ndarray  # requests arriving in the bin
    served: np.ndarray  # requests served in the bin
    capacity: np.ndarray  # requests the bin's instances could serve
    backlog: np.ndarray  # queued requests at bin end
    required: np.ndarray  # current SLO throughput * bin_s
    attainment: np.ndarray  # min(1, capacity / required)
    # degraded-mode admission control (fault profiles only; None otherwise
    # so default-mode serializations are unchanged)
    shed: Optional[np.ndarray] = None  # requests shed by admission control
    # token-level serving model only (serving_model="token"; None in fluid
    # mode so fluid serializations keep their exact pre-token bytes)
    preempted: Optional[np.ndarray] = None  # KV-pressure preemptions per bin
    refused: Optional[np.ndarray] = None  # OutOfPages admission refusals
    # resilience path only (token mode + priority mix; None otherwise so
    # no-priority token serializations keep their exact bytes)
    deadline_dropped: Optional[np.ndarray] = None  # expired-in-queue drops
    retry_dropped: Optional[np.ndarray] = None  # retry-budget exhaustions


@dataclasses.dataclass
class SimReport:
    seed: int
    bin_s: float
    times: np.ndarray  # bin start times
    services: List[str]
    timelines: Dict[str, ServiceTimeline]
    transitions: List[TransitionRecord]
    reoptimize_checks: int  # how many observe-points fired
    final_gpus: int
    # injected device faults (control-plane fault profiles only; empty in
    # default mode, where the serializer omits the key entirely)
    faults: List[FaultRecord] = dataclasses.field(default_factory=list)
    # token-level serving model extensions (serving_model="token" only; the
    # serializer omits both keys in fluid mode so fluid reports keep their
    # exact pre-token bytes)
    serving_model: str = "fluid"
    # per-service TTFT/TPOT/queueing-delay percentiles + "_totals" counts,
    # as produced by TokenServingState.latency_summary()
    latency: Optional[Dict] = None
    # per-priority-class goodput / SLO-attainment / drop / retry totals, as
    # produced by TokenServingState.priority_summary(); present only when a
    # priority mix is active (the serializer omits the key otherwise so
    # no-priority reports keep their exact bytes)
    priority: Optional[Dict] = None
    # flight-recorder observability (SimConfig.observability=True only): the
    # metrics-registry snapshot, span counts, and — token mode — the
    # per-request flight-recorder block, all sim-time.  None by default, and
    # the serializer omits the key, so every historical report (and all 67
    # BENCH cell SHAs) keeps its exact bytes.
    obs: Optional[Dict] = None

    # -- derived -----------------------------------------------------------------
    def slo_satisfaction(self, svc: str) -> float:
        """Fraction of bins whose provided capacity met the required rate."""
        tl = self.timelines[svc]
        return float(np.mean(tl.attainment >= 1.0 - 1e-9))

    def mean_attainment(self, svc: str) -> float:
        return float(np.mean(self.timelines[svc].attainment))

    def served_fraction(self, svc: str) -> float:
        tl = self.timelines[svc]
        tot = float(np.sum(tl.arrivals))
        return float(np.sum(tl.served)) / tot if tot > 0 else 1.0

    @property
    def transparent(self) -> bool:
        return all(t.transparent for t in self.transitions)

    def _all_attained(self) -> np.ndarray:
        """Per-bin bool: every service met its required rate this bin."""
        ok = np.ones(len(self.times), dtype=bool)
        for tl in self.timelines.values():
            ok &= tl.attainment >= 1.0 - 1e-9
        return ok

    def availability(self) -> float:
        """Fraction of bins in which every service met its required rate —
        the headline the fault-profile scenario cells compare."""
        return float(np.mean(self._all_attained()))

    def recovery_time_s(self) -> Optional[float]:
        """Worst time from an injected device fault to SLO re-attainment
        (the first bin at or after the fault where every service meets its
        required rate again).  ``None`` when no faults were injected; when a
        fault is never recovered from, censored at the end of the trace."""
        if not self.faults:
            return None
        ok = self._all_attained()
        end_s = float(self.times[-1] + self.bin_s)
        worst = 0.0
        for f in self.faults:
            k = int(np.searchsorted(self.times, f.time_s - 1e-9))
            recovered = None
            for j in range(k, len(ok)):
                if ok[j]:
                    recovered = float(self.times[j])
                    break
            took = (recovered - f.time_s) if recovered is not None else (
                end_s - f.time_s
            )
            worst = max(worst, took)
        return float(max(worst, 0.0))

    def shed_total(self) -> float:
        """Requests shed by degraded-mode admission control over the run."""
        return float(
            sum(
                np.sum(tl.shed)
                for tl in self.timelines.values()
                if tl.shed is not None
            )
        )

    def transparency_margin(self) -> float:
        """Worst §6 margin over all transitions and services (>= 0 means the
        guarantee held at every trace point)."""
        margins = [
            m for t in self.transitions for m in t.transparency_margin.values()
        ]
        return min(margins) if margins else float("inf")

    # -- serialization -----------------------------------------------------------
    def to_dict(self) -> Dict:
        def arr(a: np.ndarray) -> List[float]:
            return [float(x) for x in a]

        return {
            "seed": self.seed,
            "bin_s": self.bin_s,
            "times": arr(self.times),
            "services": list(self.services),
            "timelines": {
                svc: {
                    "arrivals": arr(tl.arrivals),
                    "served": arr(tl.served),
                    "capacity": arr(tl.capacity),
                    "backlog": arr(tl.backlog),
                    "required": arr(tl.required),
                    "attainment": arr(tl.attainment),
                    # key present only under fault profiles — default-mode
                    # bytes must not change
                    **({"shed": arr(tl.shed)} if tl.shed is not None else {}),
                    # keys present only under the token serving model —
                    # fluid-mode bytes must not change
                    **(
                        {"preempted": arr(tl.preempted)}
                        if tl.preempted is not None
                        else {}
                    ),
                    **(
                        {"refused": arr(tl.refused)}
                        if tl.refused is not None
                        else {}
                    ),
                    # keys present only on the resilience path (token +
                    # priority mix) — no-priority bytes must not change
                    **(
                        {"deadline_dropped": arr(tl.deadline_dropped)}
                        if tl.deadline_dropped is not None
                        else {}
                    ),
                    **(
                        {"retry_dropped": arr(tl.retry_dropped)}
                        if tl.retry_dropped is not None
                        else {}
                    ),
                }
                for svc, tl in sorted(self.timelines.items())
            },
            "transitions": [
                {
                    "start_s": t.start_s,
                    "end_s": t.end_s,
                    "serial_seconds": t.serial_seconds,
                    "parallel_seconds": t.parallel_seconds,
                    "action_counts": dict(sorted(t.action_counts.items())),
                    "old_required": dict(sorted(t.old_required.items())),
                    "new_required": dict(sorted(t.new_required.items())),
                    "gpus_before": t.gpus_before,
                    "gpus_after": t.gpus_after,
                    "transparency_margin": dict(
                        sorted(t.transparency_margin.items())
                    ),
                    "transparent": t.transparent,
                    # reconcile metadata only exists under fault profiles
                    **(
                        {"trigger": t.trigger, "reconcile": t.reconcile}
                        if t.reconcile is not None
                        else {}
                    ),
                }
                for t in self.transitions
            ],
            "reoptimize_checks": self.reoptimize_checks,
            "final_gpus": self.final_gpus,
            # injected faults only exist under fault profiles
            **(
                {"faults": [f.to_dict() for f in self.faults]}
                if self.faults
                else {}
            ),
            # token serving model only: fluid-mode reports omit both keys so
            # their serializations keep the exact pre-token bytes
            **(
                {"serving_model": self.serving_model, "latency": self.latency}
                if self.serving_model != "fluid"
                else {}
            ),
            # priority-mix resilience path only: no-priority reports (token
            # or fluid) omit the key so their bytes stay identical
            **(
                {"priority": self.priority}
                if self.priority is not None
                else {}
            ),
            # observability only: absent unless SimConfig.observability was
            # on, so default-mode reports keep their exact bytes
            **({"obs": self.obs} if self.obs is not None else {}),
        }

    def to_json(self) -> str:
        """Canonical serialization: byte-identical across same-seed runs."""
        return json.dumps(self.to_dict(), sort_keys=True, separators=(",", ":"))

    def summary(self) -> str:
        lines = [
            f"simulated {self.times[-1] + self.bin_s:.0f}s in {len(self.times)} bins"
            f" of {self.bin_s:.0f}s, seed={self.seed}",
            f"re-optimization checks: {self.reoptimize_checks},"
            f" transitions executed: {len(self.transitions)},"
            f" final GPUs busy: {self.final_gpus}",
        ]
        for svc in self.services:
            lines.append(
                f"  {svc}: slo-satisfied {self.slo_satisfaction(svc):.1%} of bins,"
                f" mean attainment {self.mean_attainment(svc):.3f},"
                f" served {self.served_fraction(svc):.1%} of arrivals"
            )
        if self.latency is not None:
            tot = self.latency.get("_totals", {})
            lines.append(
                f"  token serving: completed={tot.get('completed', 0)}"
                f" preemptions={tot.get('preemptions', 0)}"
                f" refusals={tot.get('refusals', 0)}"
            )
            for svc in self.services:
                s = self.latency.get(svc)
                if not s:
                    continue
                lines.append(
                    f"    {svc}: ttft p50={s['ttft_p50_s']:.3f}s"
                    f" p99={s['ttft_p99_s']:.3f}s"
                    f" tpot p50={s['tpot_p50_s'] * 1e3:.1f}ms"
                    f" queue p99={s['queue_delay_p99_s']:.3f}s"
                )
        if self.priority is not None:
            for cls, s in self.priority.items():
                lines.append(
                    f"  class {cls}: goodput={s['goodput']}/{s['arrivals']}"
                    f" (slo {s['slo_attainment']:.1%})"
                    f" deadline_dropped={s['deadline_dropped']}"
                    f" retry_dropped={s['retry_dropped']}"
                    f" shed={s['shed']} retries={s['retries']}"
                )
        for f in self.faults:
            spill = (
                f" spilled={f.spilled:.0f}"
                if f.kind == "instance_crash"
                else ""
            )
            lines.append(
                f"  FAULT t={f.time_s:.0f}s {f.kind} target={f.target}"
                f" ({f.fault_domain}) killed={f.killed_instances}"
                f" lost={dict(sorted(f.lost_throughput.items()))}" + spill
            )
        for i, t in enumerate(self.transitions):
            extra = ""
            if t.reconcile is not None:
                extra = (
                    f" trigger={t.trigger}"
                    f" reconcile(iter={t.reconcile['iterations']},"
                    f" retried={t.reconcile['retried']},"
                    f" converged={t.reconcile['converged']})"
                )
            lines.append(
                f"  transition {i}: t={t.start_s:.0f}s"
                f" parallel={t.parallel_seconds:.0f}s serial={t.serial_seconds:.0f}s"
                f" actions={dict(sorted(t.action_counts.items()))}"
                f" transparent={t.transparent}" + extra
            )
        lines.append(
            "  §6 transparency margin (worst over trace points):"
            f" {self.transparency_margin():.3f} req/s"
        )
        return "\n".join(lines)
