"""Simulation reports: per-bin timelines, transition records, summaries.

A :class:`SimReport` is the simulator's only output — everything the
benchmarks and tests consume (SLO attainment, transition makespans, the §6
transparency margin) is derived from it.  ``to_json()`` is deterministic
(sorted keys, canonical float repr), so two runs with the same seed must
produce byte-identical serializations — the property the test suite pins.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TransitionRecord:
    """One re-optimization + controller transition executed mid-run."""

    start_s: float  # sim time the reoptimize fired
    end_s: float  # sim time in-flight actions all finished
    serial_seconds: float
    parallel_seconds: float
    action_counts: Dict[str, int]
    old_required: Dict[str, float]  # SLO throughput before
    new_required: Dict[str, float]  # SLO throughput after
    gpus_before: int
    gpus_after: int
    # min over trace points of (capacity - min(old, new) required), per service;
    # the §6 transparency guarantee is exactly: every value >= 0.
    transparency_margin: Dict[str, float]

    @property
    def transparent(self) -> bool:
        return all(m >= -1e-6 for m in self.transparency_margin.values())


@dataclasses.dataclass
class ServiceTimeline:
    """Per-bin series for one service (arrays of length num_bins)."""

    arrivals: np.ndarray  # requests arriving in the bin
    served: np.ndarray  # requests served in the bin
    capacity: np.ndarray  # requests the bin's instances could serve
    backlog: np.ndarray  # queued requests at bin end
    required: np.ndarray  # current SLO throughput * bin_s
    attainment: np.ndarray  # min(1, capacity / required)


@dataclasses.dataclass
class SimReport:
    seed: int
    bin_s: float
    times: np.ndarray  # bin start times
    services: List[str]
    timelines: Dict[str, ServiceTimeline]
    transitions: List[TransitionRecord]
    reoptimize_checks: int  # how many observe-points fired
    final_gpus: int

    # -- derived -----------------------------------------------------------------
    def slo_satisfaction(self, svc: str) -> float:
        """Fraction of bins whose provided capacity met the required rate."""
        tl = self.timelines[svc]
        return float(np.mean(tl.attainment >= 1.0 - 1e-9))

    def mean_attainment(self, svc: str) -> float:
        return float(np.mean(self.timelines[svc].attainment))

    def served_fraction(self, svc: str) -> float:
        tl = self.timelines[svc]
        tot = float(np.sum(tl.arrivals))
        return float(np.sum(tl.served)) / tot if tot > 0 else 1.0

    @property
    def transparent(self) -> bool:
        return all(t.transparent for t in self.transitions)

    def transparency_margin(self) -> float:
        """Worst §6 margin over all transitions and services (>= 0 means the
        guarantee held at every trace point)."""
        margins = [
            m for t in self.transitions for m in t.transparency_margin.values()
        ]
        return min(margins) if margins else float("inf")

    # -- serialization -----------------------------------------------------------
    def to_dict(self) -> Dict:
        def arr(a: np.ndarray) -> List[float]:
            return [float(x) for x in a]

        return {
            "seed": self.seed,
            "bin_s": self.bin_s,
            "times": arr(self.times),
            "services": list(self.services),
            "timelines": {
                svc: {
                    "arrivals": arr(tl.arrivals),
                    "served": arr(tl.served),
                    "capacity": arr(tl.capacity),
                    "backlog": arr(tl.backlog),
                    "required": arr(tl.required),
                    "attainment": arr(tl.attainment),
                }
                for svc, tl in sorted(self.timelines.items())
            },
            "transitions": [
                {
                    "start_s": t.start_s,
                    "end_s": t.end_s,
                    "serial_seconds": t.serial_seconds,
                    "parallel_seconds": t.parallel_seconds,
                    "action_counts": dict(sorted(t.action_counts.items())),
                    "old_required": dict(sorted(t.old_required.items())),
                    "new_required": dict(sorted(t.new_required.items())),
                    "gpus_before": t.gpus_before,
                    "gpus_after": t.gpus_after,
                    "transparency_margin": dict(
                        sorted(t.transparency_margin.items())
                    ),
                    "transparent": t.transparent,
                }
                for t in self.transitions
            ],
            "reoptimize_checks": self.reoptimize_checks,
            "final_gpus": self.final_gpus,
        }

    def to_json(self) -> str:
        """Canonical serialization: byte-identical across same-seed runs."""
        return json.dumps(self.to_dict(), sort_keys=True, separators=(",", ":"))

    def summary(self) -> str:
        lines = [
            f"simulated {self.times[-1] + self.bin_s:.0f}s in {len(self.times)} bins"
            f" of {self.bin_s:.0f}s, seed={self.seed}",
            f"re-optimization checks: {self.reoptimize_checks},"
            f" transitions executed: {len(self.transitions)},"
            f" final GPUs busy: {self.final_gpus}",
        ]
        for svc in self.services:
            lines.append(
                f"  {svc}: slo-satisfied {self.slo_satisfaction(svc):.1%} of bins,"
                f" mean attainment {self.mean_attainment(svc):.3f},"
                f" served {self.served_fraction(svc):.1%} of arrivals"
            )
        for i, t in enumerate(self.transitions):
            lines.append(
                f"  transition {i}: t={t.start_s:.0f}s"
                f" parallel={t.parallel_seconds:.0f}s serial={t.serial_seconds:.0f}s"
                f" actions={dict(sorted(t.action_counts.items()))}"
                f" transparent={t.transparent}"
            )
        lines.append(
            "  §6 transparency margin (worst over trace points):"
            f" {self.transparency_margin():.3f} req/s"
        )
        return "\n".join(lines)
