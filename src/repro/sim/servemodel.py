"""Token-level serving model: a discrete, numpy-only twin of the Engine.

The fluid bin model in :mod:`repro.sim.simulator` serves at profile rates —
it cannot represent queueing delay, TTFT/TPOT latency, preemption storms, or
KV-pressure collapse, exactly the effects the paper's SLO story (§7 "largest
batch size possible, as far as the inference latency is smaller than what
required by SLOs", §8.3 measured-profile feedback) hinges on.  This module
is the drop-in alternative (``SimConfig.serving_model = "token"``): every
request is a discrete object with a per-token clock, and every simulated
instance is an :class:`InstanceModel` that mirrors the real
:class:`repro.serving.engine.Engine` state machine —

  * a fixed number of batch *slots* (the §7 rule: the profile's best
    SLO-compliant batch),
  * paged-KV accounting through the *same* :class:`PagePool` /
    :func:`page_bytes` math the engine uses (a slice's HBM budget maps to
    ``num_pages``),
  * admission = reserve ``context + 1`` page-tokens, pay a prefill charge,
    emit the first output token (the engine samples it from the prefill
    logits); :class:`OutOfPages` *refuses* admission,
  * decode = one step advances every live slot by one token; a slot that
    cannot grow its pages mid-decode is *preempted* — pages released,
    request resumed later with its generated tokens folded into the context,
  * per-token step time comes from the profile:
    ``latency_ms(svc, size, b) / 1000 / profiled_decode_tokens`` — the
    profile's request latency at batch ``b`` is the time to decode the
    *profiled* token budget at that occupancy, so when the workload's drawn
    budgets match the profiled one, a full batch sustains the profile's
    throughput (and when they are longer, capacity falls short of the
    planner's rate math — the fidelity gap the fluid model hides).  Running
    the simulation on a
    :class:`repro.core.online_profiles.MeasuredProfile` (fed by the real
    engine's ``run_closed_loop(measured=...)`` §8.3 loop) calibrates the
    per-token rates to *measured* throughput.

Everything is numpy-only (the ``repro.sim`` jax-free contract) and
seed-deterministic: request shapes are drawn from the simulator's single
seeded rng, instances advance in sorted-uid order, and queues are FIFO with
preempted requests resumed first — same seed, byte-identical
:meth:`repro.sim.report.SimReport.to_json`.

Overload resilience (ISSUE 7): when a :class:`repro.sim.traffic.PriorityMix`
is active, requests carry a priority class and an SLO deadline, and the
model switches to the resilience path — per-class FIFO queues admitted
class-major (critical first, FIFO within class, preempted-resume-first
preserved), deadline-expired queued requests dropped instead of served
uselessly (goodput, not throughput), ``OutOfPages`` mid-decode growth
evicting the lowest-class/shortest victim instead of always preempting
self, and refused/crash-spilled requests retrying with capped exponential
backoff under a retry budget.  Without a mix, every request is standard
class with an infinite deadline and the legacy code paths run untouched —
the no-priority token goldens stay byte-identical.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.flight import FlightRecorder
from repro.obs.metrics import percentile_summary
from repro.serving.paged_cache import OutOfPages, PagePool, page_bytes
from repro.sim.traffic import PRIORITY_CLASSES, STANDARD_CLASS, PriorityMix

# uid -> (service, size, throughput); mirrors repro.sim.reoptimize.InstanceSet
InstanceSet = Dict[int, Tuple[str, int, float]]

# how many queued requests one admission pass may scan past a refusal: the
# engine's run_closed_loop scans its whole pending list (first-fit), but a
# simulated flash crowd can queue thousands of requests per instance — a
# bounded head-of-line window keeps the per-step cost O(slots)
ADMIT_SCAN = 4

# percentiles the latency summaries report (ISSUE: p50/p95/p99)
_PCTS = (50.0, 95.0, 99.0)


@dataclasses.dataclass
class TokenRequest:
    """One discrete request moving through the token-level model."""

    rid: int
    service: str
    arrival_s: float
    prompt_tokens: int
    decode_tokens: int  # output-token budget
    generated: int = 0  # survives preemption (engine folds them into ctx)
    admit_s: float = -1.0  # first successful admission
    first_token_s: float = -1.0
    finish_s: float = -1.0
    preemptions: int = 0
    priority: int = STANDARD_CLASS  # index into PRIORITY_CLASSES (0 = top)
    deadline_s: float = math.inf  # absolute SLO deadline; inf = deadline-less
    retries: int = 0  # backoff retries consumed (refusals + crash spills)
    next_try_s: float = 0.0  # not admittable before this clock (backoff)

    @property
    def context_len(self) -> int:
        return self.prompt_tokens + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.decode_tokens


@dataclasses.dataclass
class TokenKnobs:
    """Shape of the modeled requests and of the per-instance KV budget.

    The KV geometry (heads / head_dim / layers / page size) feeds the same
    :func:`page_bytes` math the engine's ``page_hbm_bytes`` uses, so an
    instance of MIG size ``s`` gets ``s * hbm_gb_per_unit`` GB of page pool.
    Defaults are sized so a flash crowd actually produces KV pressure
    (refusals/preemptions) at the curated ``micro`` scenario scale.

    Fields, by group:

    * request shape — ``prompt_tokens`` / ``decode_tokens`` are *means*;
      each request draws uniformly in ``[1, 2*mean)`` from the simulator's
      seeded rng.  ``max_len`` caps context like ``Engine.max_len``;
      ``prefill_chunk`` is prompt tokens prefilled per step-equivalent.
    * ``profiled_decode_tokens`` — the single most consequential knob: the
      decode budget the *profile's* latency numbers assumed.  Per-token
      step time is ``latency_ms(svc, size, b) / 1000 /
      profiled_decode_tokens``, so when drawn budgets exceed it, requests
      outlive the profiled request latency and real capacity falls short
      of the planner's rate math — the fidelity gap the token model exists
      to show (the curated token slice sets drawn budgets to 4x the
      profiled one).  ``None`` means "profile matches the workload".
    * KV budget — ``page_size`` / ``kv_heads`` / ``head_dim`` /
      ``n_layers`` / ``hbm_gb_per_unit`` determine ``num_pages(size)``.
    * retry policy (``retry_*``) — capped exponential backoff for refused /
      crash-spilled requests; consulted only when a
      :class:`repro.sim.traffic.PriorityMix` is active.  A ``PriorityMix``
      assigns each request a priority class (by traffic ``weights``) and an
      absolute SLO deadline (``deadline_s`` per class, ``inf`` =
      deadline-less batch); admission is class-major, expiries are dropped
      for goodput, and KV-pressure eviction targets the lowest class first.
    """

    prompt_tokens: int = 24  # mean prompt length (uniform in [1, 2*mean))
    decode_tokens: int = 16  # mean output budget (uniform in [1, 2*mean))
    # decode budget the profile's latency numbers assumed: per-token step
    # time is latency_ms / 1000 / profiled_decode_tokens.  When the drawn
    # budgets (decode_tokens) exceed this, requests take longer than the
    # profile's request latency and real capacity falls short of the
    # planner's rate math — the fidelity gap the token model exists to show.
    # None -> equal to decode_tokens (profile matches the workload).
    profiled_decode_tokens: Optional[int] = None
    max_len: int = 96  # context cap, like Engine.max_len
    page_size: int = 16
    kv_heads: int = 8
    head_dim: int = 128
    n_layers: int = 32
    hbm_gb_per_unit: float = 0.020  # page-pool GB per MIG size unit
    prefill_chunk: int = 32  # prompt tokens prefilled per step-equivalent
    # refused / crash-spilled requests retry with capped exponential backoff:
    # attempt k waits min(retry_base_s * retry_mult**(k-1), retry_cap_s); a
    # request past retry_budget attempts is dropped (counted retry_dropped).
    # Only consulted when a priority mix is active (the resilience path).
    retry_budget: int = 4
    retry_base_s: float = 0.25
    retry_mult: float = 2.0
    retry_cap_s: float = 4.0

    def retry_backoff_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based), capped exponential."""
        return min(
            self.retry_base_s * self.retry_mult ** max(attempt - 1, 0),
            self.retry_cap_s,
        )

    def num_pages(self, size: int) -> int:
        """A slice's HBM budget -> page count (engine's page_hbm_bytes math),
        floored so one max-context request always fits (no livelock)."""
        per_page = page_bytes(
            self.page_size, self.kv_heads, self.head_dim, self.n_layers
        )
        budget = int(size * self.hbm_gb_per_unit * 2**30)
        return max(budget // per_page, self.max_pages_per_req)

    @property
    def step_decode_tokens(self) -> int:
        """Decode budget behind the profile's latency numbers (the per-token
        step-time denominator)."""
        if self.profiled_decode_tokens is not None:
            return self.profiled_decode_tokens
        return self.decode_tokens

    @property
    def max_pages_per_req(self) -> int:
        # context cap + the one-ahead decode write the engine reserves
        return -(-(self.max_len + 1) // self.page_size)


class InstanceModel:
    """Twin of one Engine: slots + page pool + a per-token clock.

    ``step_time_s(b)`` is the seconds one ragged decode step takes with
    ``b`` live slots; admission charges ``ceil(context / prefill_chunk)``
    step-equivalents serially (the engine's jit'd batch-1 prefill blocks the
    decode loop the same way).
    """

    def __init__(
        self,
        uid: int,
        service: str,
        size: int,
        slots: int,
        knobs: TokenKnobs,
        step_time_s: Callable[[int], float],
        now: float,
        resilience: bool = False,
    ):
        self.uid = uid
        self.service = service
        self.size = size
        self.slots = max(int(slots), 1)
        self.knobs = knobs
        self.step_time_s = step_time_s
        self.clock = now
        self.resilience = resilience
        self.pool = PagePool(
            knobs.num_pages(size), knobs.page_size, knobs.max_pages_per_req
        )
        self.live: List[TokenRequest] = []
        # one FIFO per priority class; preempted requests resume first
        # within their class.  Without a mix every request is standard
        # class, so queues[STANDARD_CLASS] is the legacy single queue.
        self.queues: List[List[TokenRequest]] = [
            [] for _ in PRIORITY_CLASSES
        ]
        # (ready_s, seq, req) min-heap of backed-off refused/spilled requests
        self.backoff: List[Tuple[float, int, TokenRequest]] = []
        self._seq = 0

    @property
    def queue(self) -> List[TokenRequest]:
        """Legacy view: the standard-class FIFO (the only populated queue
        when no priority mix is active)."""
        return self.queues[STANDARD_CLASS]

    def enqueue(self, req: TokenRequest) -> None:
        self.queues[req.priority].append(req)

    # -- admission (mirrors Engine.admit) -------------------------------------
    def _try_admit(self, req: TokenRequest, metrics: "TokenMetrics") -> bool:
        L = req.context_len
        self.pool.admit(req.rid)
        try:
            # context + room for the first decode write, like the engine
            self.pool.append_tokens(req.rid, L + 1)
        except OutOfPages:
            self.pool.release(req.rid)
            metrics.refusals[req.service] += 1
            if metrics.recorder is not None:
                metrics.recorder.note(
                    req.rid, "refused", self.clock, uid=self.uid
                )
            return False
        if metrics.recorder is not None:
            metrics.recorder.note(
                req.rid,
                "resumed" if req.admit_s >= 0.0 else "admitted",
                self.clock,
                uid=self.uid,
            )
        if req.admit_s < 0.0:
            req.admit_s = self.clock
            metrics.queue_delay_s[req.service].append(
                self.clock - req.arrival_s
            )
        # serialized prefill charge, then the first token from its logits
        steps = -(-max(L, 1) // self.knobs.prefill_chunk)
        self.clock += steps * self.step_time_s(len(self.live) + 1)
        req.generated += 1
        if req.first_token_s < 0.0:
            req.first_token_s = self.clock
            metrics.ttft_s[req.service].append(self.clock - req.arrival_s)
            if metrics.recorder is not None:
                metrics.recorder.note(req.rid, "first_token", self.clock)
        if req.done or req.context_len >= self.knobs.max_len:
            self._finish(req, metrics)
        else:
            self.live.append(req)
        return True

    def _admit_pass(self, metrics: "TokenMetrics") -> None:
        """First-fit over the arrived head of the queue (bounded scan), like
        the engine's run_closed_loop: a request the pool cannot hold must
        not head-of-line block admittable requests right behind it."""
        scanned = 0
        i = 0
        while i < len(self.queue) and len(self.live) < self.slots:
            req = self.queue[i]
            if req.arrival_s > self.clock + 1e-12 or scanned >= ADMIT_SCAN:
                break
            if self._try_admit(req, metrics):
                self.queue.pop(i)
            else:
                scanned += 1
                i += 1

    def _admit_pass_priority(self, metrics: "TokenMetrics") -> None:
        """Resilience-path admission: class-major (higher class first), FIFO
        within class, preempted-resume-first preserved (preempted requests
        sit at their class's head).  Deadline-expired queued requests are
        dropped instead of served uselessly, and an ``OutOfPages`` refusal
        backs the request off with capped exponential backoff instead of
        letting it spin at the queue head every step."""
        # backed-off requests whose timer expired rejoin their class's head
        # (they are the oldest of their class — they were refused earlier)
        if self.backoff and self.backoff[0][0] <= self.clock + 1e-12:
            ready: List[List[TokenRequest]] = [[] for _ in PRIORITY_CLASSES]
            while self.backoff and self.backoff[0][0] <= self.clock + 1e-12:
                _, _, req = heapq.heappop(self.backoff)
                ready[req.priority].append(req)
            for cls, reqs in enumerate(ready):
                if reqs:
                    self.queues[cls][:0] = reqs
        scanned = 0
        for q in self.queues:
            i = 0
            while (
                i < len(q)
                and len(self.live) < self.slots
                and scanned < ADMIT_SCAN
            ):
                req = q[i]
                if req.arrival_s > self.clock + 1e-12:
                    break  # this class's tail has not arrived yet
                if req.deadline_s < self.clock:
                    # deadline already passed while queued: serving it is
                    # wasted work — drop for goodput, not throughput
                    q.pop(i)
                    metrics.deadline_dropped[req.service] += 1
                    metrics.class_deadline_dropped[req.priority] += 1
                    if metrics.recorder is not None:
                        metrics.recorder.close(
                            req.rid,
                            "deadline_dropped",
                            self.clock,
                            cause="deadline expired while queued",
                        )
                    continue
                if self._try_admit(req, metrics):
                    q.pop(i)
                    continue
                # refused (OutOfPages): back off under the retry budget
                q.pop(i)
                scanned += 1
                req.retries += 1
                metrics.class_retries[req.priority] += 1
                if req.retries > self.knobs.retry_budget:
                    metrics.retry_dropped[req.service] += 1
                    metrics.class_retry_dropped[req.priority] += 1
                    if metrics.recorder is not None:
                        metrics.recorder.close(
                            req.rid,
                            "retry_dropped",
                            self.clock,
                            cause="retry budget exhausted after refusals",
                        )
                else:
                    req.next_try_s = self.clock + self.knobs.retry_backoff_s(
                        req.retries
                    )
                    heapq.heappush(
                        self.backoff, (req.next_try_s, self._seq, req)
                    )
                    self._seq += 1
                    if metrics.recorder is not None:
                        metrics.recorder.note(
                            req.rid,
                            "backoff",
                            self.clock,
                            next_try_s=req.next_try_s,
                        )
            if len(self.live) >= self.slots or scanned >= ADMIT_SCAN:
                break

    # -- decode (mirrors Engine.step) ------------------------------------------
    def _decode_step(self, metrics: "TokenMetrics") -> None:
        dt = self.step_time_s(len(self.live))
        self.clock += dt
        still_live: List[TokenRequest] = []
        resumed: List[TokenRequest] = []
        evicted: set = set()  # rids evicted mid-step as preemption victims
        finished: set = set()  # rids finished this step (pages released)
        for req in self.live:
            if req.rid in evicted or req.rid in finished:
                continue
            # grow pages to cover this step's cache write (the engine keeps
            # pool length == written positions + the sampled-but-unwritten
            # token: exactly context_len), so the first post-admission step
            # needs no growth — the admission reserved one slot ahead
            need = req.context_len - self.pool.request(req.rid).length
            if need > 0 and not self._grow(
                req, need, still_live, resumed, evicted, finished, metrics
            ):
                continue
            req.generated += 1
            if req.done or req.context_len >= self.knobs.max_len:
                finished.add(req.rid)
                self._finish(req, metrics)
            else:
                still_live.append(req)
        self.live = still_live
        # preempted requests resume first, like run_closed_loop's re-queue
        # (within their own class on the resilience path)
        for cls in range(len(self.queues)):
            front = [r for r in resumed if r.priority == cls]
            if front:
                self.queues[cls][:0] = front

    def _grow(
        self,
        req: TokenRequest,
        need: int,
        still_live: List[TokenRequest],
        resumed: List[TokenRequest],
        evicted: set,
        finished: set,
        metrics: "TokenMetrics",
    ) -> bool:
        """Grow ``req``'s pages by ``need`` mid-decode.  On ``OutOfPages``
        the legacy path always preempts ``req`` itself; the resilience path
        evicts the lowest-class / shortest victim among the live batch
        (possibly ``req``) and retries.  Returns True when the pages were
        grown, False when ``req`` left the live batch."""
        while True:
            try:
                self.pool.append_tokens(req.rid, need)
                return True
            except OutOfPages:
                victim = req
                if self.resilience:
                    # lowest class first (largest priority index), then the
                    # shortest context (cheapest restart), then rid; a
                    # higher-class request is never evicted to grow a
                    # lower-class one
                    victim = min(
                        (
                            r
                            for r in self.live
                            if r.rid not in evicted
                            and r.rid not in finished
                            and (r is req or r.priority >= req.priority)
                        ),
                        key=lambda r: (-r.priority, r.context_len, r.rid),
                    )
                if victim is req:
                    # preempt self: pages released, resume later with
                    # generated tokens folded into the context (engine
                    # semantics); a resume needs context + 1 <= max_len to
                    # re-admit — at the cap there is no room, finish
                    # truncated like the engine's max_len path
                    if req.context_len + 1 > self.knobs.max_len:
                        finished.add(req.rid)
                        self._finish(req, metrics)
                        return False
                    self.pool.release(req.rid)
                    req.preemptions += 1
                    metrics.preemptions[req.service] += 1
                    if metrics.recorder is not None:
                        metrics.recorder.note(
                            req.rid,
                            "preempted",
                            self.clock,
                            uid=self.uid,
                            cause="kv_pressure",
                        )
                    resumed.append(req)
                    # mark it out of the live batch: a later request's
                    # victim search this same step must not pick it again
                    # (its pages are gone; a second resume would duplicate
                    # the request in its queue)
                    evicted.add(req.rid)
                    return False
                evicted.add(victim.rid)
                if victim in still_live:
                    still_live.remove(victim)
                if victim.context_len + 1 > self.knobs.max_len:
                    finished.add(victim.rid)
                    self._finish(victim, metrics)
                    continue
                self.pool.release(victim.rid)
                victim.preemptions += 1
                metrics.preemptions[victim.service] += 1
                if metrics.recorder is not None:
                    metrics.recorder.note(
                        victim.rid,
                        "preempted",
                        self.clock,
                        uid=self.uid,
                        cause="evicted_for_higher_class",
                    )
                resumed.append(victim)

    def _finish(self, req: TokenRequest, metrics: "TokenMetrics") -> None:
        req.finish_s = self.clock
        self.pool.release(req.rid)
        if req.generated > 1:
            metrics.tpot_s[req.service].append(
                (req.finish_s - req.first_token_s) / (req.generated - 1)
            )
        metrics.completed_at[req.service].append(req.finish_s)
        metrics.class_completed[req.priority] += 1
        if req.finish_s <= req.deadline_s:
            metrics.class_goodput[req.priority] += 1
        if metrics.recorder is not None:
            # a request that hit the context cap before its decode budget
            # finished truncated, like the engine's max_len path
            metrics.recorder.close(
                req.rid,
                "completed" if req.done else "truncated",
                self.clock,
                cause="" if req.done else "context cap",
            )

    # -- one traffic bin --------------------------------------------------------
    def run_until(self, t_end: float, metrics: "TokenMetrics") -> None:
        """Advance this instance's clock to ``t_end``, admitting and
        decoding.  The clock may overrun ``t_end`` by a fraction of a step —
        the remainder carries into the next bin, like a real engine whose
        step straddles a metrics-bin edge."""
        while self.clock < t_end - 1e-12:
            if self.resilience:
                self._admit_pass_priority(metrics)
            else:
                self._admit_pass(metrics)
            if not self.live:
                # idle: jump to the next queued arrival or backoff expiry
                # (an empty pool can always admit an arrived, non-backing-
                # off request, so nothing is admittable right now)
                nxt = [
                    r.arrival_s
                    for q in self.queues
                    for r in q
                    if r.arrival_s > self.clock + 1e-12
                ]
                if self.backoff:
                    nxt.append(self.backoff[0][0])
                self.clock = min(min(nxt), t_end) if nxt else t_end
                continue
            self._decode_step(metrics)

    def drain(self) -> List[TokenRequest]:
        """Evict everything (the instance vanished mid-transition): queued
        and in-flight requests spill back to the service level; in-flight
        ones resume elsewhere with their generated tokens (a migration is a
        preemption from the request's point of view)."""
        for req in self.live:
            self.pool.release(req.rid)
            req.preemptions += 1
        out = list(self.live)
        for q in self.queues:
            out.extend(q)
        for _, _, req in sorted(self.backoff):
            out.append(req)
        self.live = []
        self.queues = [[] for _ in PRIORITY_CLASSES]
        self.backoff = []
        return out

    def crash(
        self, now: float, metrics: "TokenMetrics"
    ) -> Tuple[List[TokenRequest], List[TokenRequest]]:
        """The instance's process died mid-decode (the ISSUE 7 serving-path
        fault family): every in-flight request loses its KV cache *and* its
        generated tokens (the sampled outputs lived in the dead process) and
        must restart from the prompt; queued and backing-off requests spill
        intact.  The replacement process starts with a cold, empty page
        pool.  Returns ``(inflight, queued)`` spill lists."""
        self.clock = max(self.clock, now)
        inflight: List[TokenRequest] = []
        for req in self.live:
            req.preemptions += 1
            metrics.preemptions[req.service] += 1
            req.generated = 0  # KV and sampled tokens are gone
            if metrics.recorder is not None:
                metrics.recorder.note(
                    req.rid,
                    "crashed",
                    self.clock,
                    uid=self.uid,
                    cause="instance process died mid-decode",
                )
            inflight.append(req)
        queued: List[TokenRequest] = []
        for q in self.queues:
            queued.extend(q)
        for _, _, req in sorted(self.backoff):
            queued.append(req)
        self.live = []
        self.queues = [[] for _ in PRIORITY_CLASSES]
        self.backoff = []
        self.pool = PagePool(
            self.knobs.num_pages(self.size),
            self.knobs.page_size,
            self.knobs.max_pages_per_req,
        )
        return inflight, queued

    @property
    def in_system(self) -> int:
        return (
            len(self.live)
            + sum(len(q) for q in self.queues)
            + len(self.backoff)
        )


@dataclasses.dataclass
class TokenMetrics:
    """Per-service observation streams the report's summaries derive from."""

    services: List[str]
    ttft_s: Dict[str, List[float]] = dataclasses.field(default_factory=dict)
    tpot_s: Dict[str, List[float]] = dataclasses.field(default_factory=dict)
    queue_delay_s: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict
    )
    completed_at: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict
    )
    # per-service running event counts (a refusal is one OutOfPages
    # admission attempt; the same request may be refused many times)
    preemptions: Dict[str, int] = dataclasses.field(default_factory=dict)
    refusals: Dict[str, int] = dataclasses.field(default_factory=dict)
    # resilience-path per-service drop counts (stay zero without a mix)
    deadline_dropped: Dict[str, int] = dataclasses.field(default_factory=dict)
    retry_dropped: Dict[str, int] = dataclasses.field(default_factory=dict)
    # per-priority-class conservation counters, indexed by PRIORITY_CLASSES;
    # goodput = completions that beat their deadline, retries = backoff
    # retry attempts charged (refusals + crash/migration spills)
    class_arrivals: List[int] = dataclasses.field(
        default_factory=lambda: [0] * len(PRIORITY_CLASSES)
    )
    class_completed: List[int] = dataclasses.field(
        default_factory=lambda: [0] * len(PRIORITY_CLASSES)
    )
    class_goodput: List[int] = dataclasses.field(
        default_factory=lambda: [0] * len(PRIORITY_CLASSES)
    )
    class_deadline_dropped: List[int] = dataclasses.field(
        default_factory=lambda: [0] * len(PRIORITY_CLASSES)
    )
    class_retry_dropped: List[int] = dataclasses.field(
        default_factory=lambda: [0] * len(PRIORITY_CLASSES)
    )
    class_shed: List[int] = dataclasses.field(
        default_factory=lambda: [0] * len(PRIORITY_CLASSES)
    )
    class_retries: List[int] = dataclasses.field(
        default_factory=lambda: [0] * len(PRIORITY_CLASSES)
    )
    # flight-recorder observability (SimConfig.observability=True only):
    # every lifecycle site guards on ``recorder is not None``, so the None
    # default keeps the hot path — and all token goldens — byte-identical
    recorder: Optional[FlightRecorder] = None

    def __post_init__(self):
        for svc in self.services:
            self.ttft_s.setdefault(svc, [])
            self.tpot_s.setdefault(svc, [])
            self.queue_delay_s.setdefault(svc, [])
            self.completed_at.setdefault(svc, [])
            self.preemptions.setdefault(svc, 0)
            self.refusals.setdefault(svc, 0)
            self.deadline_dropped.setdefault(svc, 0)
            self.retry_dropped.setdefault(svc, 0)


def _summary(vals: List[float], prefix: str) -> Dict[str, float]:
    # the shared repro.obs helper computes the exact same bytes; the serve
    # CLI's --stats-json reuses it so the real engine emits this schema too
    return percentile_summary(vals, prefix, _PCTS)


class TokenServingState:
    """The simulator-side owner of the token model: one
    :class:`InstanceModel` per live instance, service-level spill queues,
    and the latency/preemption observation streams.

    ``step_time_for`` closes over the simulator's profile: per-token step
    time at occupancy ``b`` is ``latency_ms(svc, size, b) / 1000 /
    decode_tokens`` (corrected profiles — §8.3 ``MeasuredProfile`` — flow
    through unchanged, which is the calibration loop).
    """

    def __init__(
        self,
        services: List[str],
        profile,
        latency_slo_for: Callable[[str], float],
        knobs: Optional[TokenKnobs] = None,
        mix: Optional[PriorityMix] = None,
        recorder: Optional[FlightRecorder] = None,
    ):
        self.knobs = knobs or TokenKnobs()
        self.profile = profile
        self.latency_slo_for = latency_slo_for
        self.mix = mix
        self.metrics = TokenMetrics(list(services), recorder=recorder)
        self.instances: Dict[int, InstanceModel] = {}
        self.spill: Dict[str, List[TokenRequest]] = {s: [] for s in services}
        self._next_rid = 0

    @property
    def resilience(self) -> bool:
        """Priority/deadline/backoff semantics are active iff a mix is."""
        return self.mix is not None

    # -- construction helpers ---------------------------------------------------
    def step_time_for(
        self, svc: str, size: int, noise: float = 1.0
    ) -> Callable[[int], float]:
        knobs = self.knobs
        cache: Dict[int, float] = {}  # profile is fixed for the model's life

        def step_time_s(b: int) -> float:
            b = max(b, 1)
            v = cache.get(b)
            if v is None:
                lat = self.profile.latency_ms(svc, size, b)
                v = cache[b] = (
                    lat / 1000.0 / knobs.step_decode_tokens
                ) / noise
            return v

        return step_time_s

    def slots_for(self, svc: str, size: int) -> int:
        """§7: the largest SLO-compliant batch is the engine's slot count."""
        return max(
            self.profile.best_batch(svc, size, self.latency_slo_for(svc)), 1
        )

    def make_request(
        self, svc: str, arrival_s: float, rng: np.random.Generator
    ) -> TokenRequest:
        knobs = self.knobs
        prompt = int(rng.integers(1, 2 * knobs.prompt_tokens))
        decode = int(rng.integers(1, 2 * knobs.decode_tokens))
        # clamp so prompt + decode fits the context cap (no unservable reqs)
        prompt = min(prompt, knobs.max_len - 2)
        decode = min(decode, knobs.max_len - 1 - prompt)
        rid = self._next_rid
        self._next_rid += 1
        req = TokenRequest(rid, svc, arrival_s, prompt, max(decode, 1))
        if self.mix is not None:
            # the class draw comes AFTER the shape draws so the no-mix rng
            # stream (and its goldens) stays byte-identical
            cls = self.mix.class_of(svc, rng)
            req.priority = cls
            req.deadline_s = arrival_s + self.mix.deadline_s[cls]
        self.metrics.class_arrivals[req.priority] += 1
        if self.metrics.recorder is not None:
            self.metrics.recorder.arrival(
                rid, svc, arrival_s,
                priority=req.priority, deadline_s=req.deadline_s,
            )
        return req

    def record_shed(self, req: TokenRequest) -> None:
        """Charge one admission-control shed against the request's class
        (the per-service shed series is charged by the simulator)."""
        self.metrics.class_shed[req.priority] += 1
        if self.metrics.recorder is not None:
            self.metrics.recorder.close(
                req.rid,
                "shed",
                req.arrival_s,
                cause="degraded-mode admission control",
            )

    def retry_or_drop(self, req: TokenRequest, now: float) -> bool:
        """Charge one backoff retry for a spilled in-flight request; False
        when the retry budget is exhausted (the request is dropped and
        counted ``retry_dropped``)."""
        m = self.metrics
        req.retries += 1
        m.class_retries[req.priority] += 1
        if req.retries > self.knobs.retry_budget:
            m.retry_dropped[req.service] += 1
            m.class_retry_dropped[req.priority] += 1
            if m.recorder is not None:
                m.recorder.close(
                    req.rid,
                    "retry_dropped",
                    now,
                    cause="retry budget exhausted after spill",
                )
            return False
        req.next_try_s = now + self.knobs.retry_backoff_s(req.retries)
        if m.recorder is not None:
            m.recorder.note(req.rid, "backoff", now, next_try_s=req.next_try_s)
        return True

    # -- instance-set sync -------------------------------------------------------
    def sync_instances(
        self, live: InstanceSet, noise_of: Callable[[int], float], now: float
    ) -> None:
        """Reconcile the per-uid models with this bin's instance set: new
        uids get fresh models, vanished uids spill their requests back to
        the service level (re-routed this bin)."""
        for uid in [u for u in self.instances if u not in live]:
            inst = self.instances.pop(uid)
            inflight = {id(r) for r in inst.live}
            for req in inst.live:
                self.metrics.preemptions[req.service] += 1
            for req in inst.drain():
                if self.metrics.recorder is not None and id(req) in inflight:
                    # a migration is a preemption from the request's view
                    self.metrics.recorder.note(
                        req.rid, "migrated", now, uid=uid
                    )
                if (
                    self.resilience
                    and id(req) in inflight
                    and not self.retry_or_drop(req, now)
                ):
                    continue  # migration-spill retry budget exhausted
                self.spill[req.service].append(req)
        for uid in sorted(live):
            if uid in self.instances:
                continue
            svc, size, _tput = live[uid]
            self.instances[uid] = InstanceModel(
                uid,
                svc,
                size,
                self.slots_for(svc, size),
                self.knobs,
                self.step_time_for(svc, size, noise_of(uid)),
                now,
                resilience=self.resilience,
            )

    def crash_instance(self, uid: int, now: float) -> int:
        """Apply an ``instance_crash`` fault: the uid's model loses its
        process (in-flight KV + outputs gone, cold page pool); spilled
        requests re-route this bin, in-flight ones under the retry budget.
        Returns the number of in-flight requests spilled."""
        inst = self.instances.get(uid)
        if inst is None:
            return 0
        inflight, queued = inst.crash(now, self.metrics)
        for req in inflight:
            if self.resilience and not self.retry_or_drop(req, now):
                continue  # crash-spill retry budget exhausted
            self.spill[req.service].append(req)
        for req in queued:
            self.spill[req.service].append(req)
        return len(inflight)

    # -- per-bin serving ---------------------------------------------------------
    def dispatch(
        self,
        svc: str,
        members: List[int],
        pick: Callable[[], int],
        new_requests: List[TokenRequest],
    ) -> None:
        """Route spilled + newly arrived requests over the service's
        instances (spill first: those arrived earlier).  ``pick`` is the
        service's smooth-WRR router returning a uid."""
        pending = self.spill[svc] + new_requests
        self.spill[svc] = []
        if not members:
            self.spill[svc] = pending
            return
        for req in pending:
            uid = pick()
            self.instances[uid].enqueue(req)
            if self.metrics.recorder is not None:
                self.metrics.recorder.note(
                    req.rid, "queued", req.arrival_s, uid=uid
                )

    def serve_bin(self, t_end: float) -> None:
        for uid in sorted(self.instances):
            self.instances[uid].run_until(t_end, self.metrics)

    # -- accounting ---------------------------------------------------------------
    def completed_in(self, svc: str, t0: float, t1: float) -> int:
        return sum(
            1 for t in self.metrics.completed_at[svc] if t0 <= t < t1
        )

    def in_system(self, svc: str) -> int:
        return len(self.spill[svc]) + sum(
            i.in_system for i in self.instances.values() if i.service == svc
        )

    def latency_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-service TTFT/TPOT/queue-delay p50/p95/p99 plus conservation
        counts — the report extension serialized only in token mode."""
        m = self.metrics
        out: Dict[str, Dict[str, float]] = {}
        for svc in sorted(m.services):
            entry: Dict[str, float] = {}
            entry.update(_summary(m.ttft_s[svc], "ttft"))
            entry.update(_summary(m.tpot_s[svc], "tpot"))
            entry.update(_summary(m.queue_delay_s[svc], "queue_delay"))
            entry["completed"] = len(m.completed_at[svc])
            entry["in_system"] = self.in_system(svc)
            out[svc] = entry
        out["_totals"] = {
            "preemptions": sum(m.preemptions.values()),
            "refusals": sum(m.refusals.values()),
            "completed": sum(len(v) for v in m.completed_at.values()),
        }
        return out

    def _in_system_by_class(self) -> List[int]:
        counts = [0] * len(PRIORITY_CLASSES)
        for reqs in self.spill.values():
            for r in reqs:
                counts[r.priority] += 1
        for inst in self.instances.values():
            for r in inst.live:
                counts[r.priority] += 1
            for q in inst.queues:
                for r in q:
                    counts[r.priority] += 1
            for _, _, r in inst.backoff:
                counts[r.priority] += 1
        return counts

    def priority_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-priority-class goodput / SLO-attainment / drop / retry
        totals — the report extension serialized only when a mix is active.
        Conservation holds exactly per class:
        ``arrivals == completed + deadline_dropped + retry_dropped + shed +
        in_system``."""
        m = self.metrics
        in_sys = self._in_system_by_class()
        out: Dict[str, Dict[str, float]] = {}
        for c, name in enumerate(PRIORITY_CLASSES):
            arr = m.class_arrivals[c]
            good = m.class_goodput[c]
            out[name] = {
                "arrivals": arr,
                "completed": m.class_completed[c],
                "goodput": good,
                "deadline_dropped": m.class_deadline_dropped[c],
                "retry_dropped": m.class_retry_dropped[c],
                "shed": m.class_shed[c],
                "retries": m.class_retries[c],
                "in_system": in_sys[c],
                "slo_attainment": (good / arr) if arr else 1.0,
            }
        return out
