"""Token-level serving model: a discrete, numpy-only twin of the Engine.

The fluid bin model in :mod:`repro.sim.simulator` serves at profile rates —
it cannot represent queueing delay, TTFT/TPOT latency, preemption storms, or
KV-pressure collapse, exactly the effects the paper's SLO story (§7 "largest
batch size possible, as far as the inference latency is smaller than what
required by SLOs", §8.3 measured-profile feedback) hinges on.  This module
is the drop-in alternative (``SimConfig.serving_model = "token"``): every
request is a discrete object with a per-token clock, and every simulated
instance is an :class:`InstanceModel` that mirrors the real
:class:`repro.serving.engine.Engine` state machine —

  * a fixed number of batch *slots* (the §7 rule: the profile's best
    SLO-compliant batch),
  * paged-KV accounting through the *same* :class:`PagePool` /
    :func:`page_bytes` math the engine uses (a slice's HBM budget maps to
    ``num_pages``),
  * admission = reserve ``context + 1`` page-tokens, pay a prefill charge,
    emit the first output token (the engine samples it from the prefill
    logits); :class:`OutOfPages` *refuses* admission,
  * decode = one step advances every live slot by one token; a slot that
    cannot grow its pages mid-decode is *preempted* — pages released,
    request resumed later with its generated tokens folded into the context,
  * per-token step time comes from the profile:
    ``latency_ms(svc, size, b) / 1000 / profiled_decode_tokens`` — the
    profile's request latency at batch ``b`` is the time to decode the
    *profiled* token budget at that occupancy, so when the workload's drawn
    budgets match the profiled one, a full batch sustains the profile's
    throughput (and when they are longer, capacity falls short of the
    planner's rate math — the fidelity gap the fluid model hides).  Running
    the simulation on a
    :class:`repro.core.online_profiles.MeasuredProfile` (fed by the real
    engine's ``run_closed_loop(measured=...)`` §8.3 loop) calibrates the
    per-token rates to *measured* throughput.

Everything is numpy-only (the ``repro.sim`` jax-free contract) and
seed-deterministic: request shapes are drawn from the simulator's single
seeded rng, instances advance in sorted-uid order, and queues are FIFO with
preempted requests resumed first — same seed, byte-identical
:meth:`repro.sim.report.SimReport.to_json`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import OutOfPages, PagePool, page_bytes

# uid -> (service, size, throughput); mirrors repro.sim.reoptimize.InstanceSet
InstanceSet = Dict[int, Tuple[str, int, float]]

# how many queued requests one admission pass may scan past a refusal: the
# engine's run_closed_loop scans its whole pending list (first-fit), but a
# simulated flash crowd can queue thousands of requests per instance — a
# bounded head-of-line window keeps the per-step cost O(slots)
ADMIT_SCAN = 4

# percentiles the latency summaries report (ISSUE: p50/p95/p99)
_PCTS = (50.0, 95.0, 99.0)


@dataclasses.dataclass
class TokenRequest:
    """One discrete request moving through the token-level model."""

    rid: int
    service: str
    arrival_s: float
    prompt_tokens: int
    decode_tokens: int  # output-token budget
    generated: int = 0  # survives preemption (engine folds them into ctx)
    admit_s: float = -1.0  # first successful admission
    first_token_s: float = -1.0
    finish_s: float = -1.0
    preemptions: int = 0

    @property
    def context_len(self) -> int:
        return self.prompt_tokens + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.decode_tokens


@dataclasses.dataclass
class TokenKnobs:
    """Shape of the modeled requests and of the per-instance KV budget.

    The KV geometry (heads / head_dim / layers / page size) feeds the same
    :func:`page_bytes` math the engine's ``page_hbm_bytes`` uses, so an
    instance of MIG size ``s`` gets ``s * hbm_gb_per_unit`` GB of page pool.
    Defaults are sized so a flash crowd actually produces KV pressure
    (refusals/preemptions) at the curated ``micro`` scenario scale.
    """

    prompt_tokens: int = 24  # mean prompt length (uniform in [1, 2*mean))
    decode_tokens: int = 16  # mean output budget (uniform in [1, 2*mean))
    # decode budget the profile's latency numbers assumed: per-token step
    # time is latency_ms / 1000 / profiled_decode_tokens.  When the drawn
    # budgets (decode_tokens) exceed this, requests take longer than the
    # profile's request latency and real capacity falls short of the
    # planner's rate math — the fidelity gap the token model exists to show.
    # None -> equal to decode_tokens (profile matches the workload).
    profiled_decode_tokens: Optional[int] = None
    max_len: int = 96  # context cap, like Engine.max_len
    page_size: int = 16
    kv_heads: int = 8
    head_dim: int = 128
    n_layers: int = 32
    hbm_gb_per_unit: float = 0.020  # page-pool GB per MIG size unit
    prefill_chunk: int = 32  # prompt tokens prefilled per step-equivalent

    def num_pages(self, size: int) -> int:
        """A slice's HBM budget -> page count (engine's page_hbm_bytes math),
        floored so one max-context request always fits (no livelock)."""
        per_page = page_bytes(
            self.page_size, self.kv_heads, self.head_dim, self.n_layers
        )
        budget = int(size * self.hbm_gb_per_unit * 2**30)
        return max(budget // per_page, self.max_pages_per_req)

    @property
    def step_decode_tokens(self) -> int:
        """Decode budget behind the profile's latency numbers (the per-token
        step-time denominator)."""
        if self.profiled_decode_tokens is not None:
            return self.profiled_decode_tokens
        return self.decode_tokens

    @property
    def max_pages_per_req(self) -> int:
        # context cap + the one-ahead decode write the engine reserves
        return -(-(self.max_len + 1) // self.page_size)


class InstanceModel:
    """Twin of one Engine: slots + page pool + a per-token clock.

    ``step_time_s(b)`` is the seconds one ragged decode step takes with
    ``b`` live slots; admission charges ``ceil(context / prefill_chunk)``
    step-equivalents serially (the engine's jit'd batch-1 prefill blocks the
    decode loop the same way).
    """

    def __init__(
        self,
        uid: int,
        service: str,
        size: int,
        slots: int,
        knobs: TokenKnobs,
        step_time_s: Callable[[int], float],
        now: float,
    ):
        self.uid = uid
        self.service = service
        self.size = size
        self.slots = max(int(slots), 1)
        self.knobs = knobs
        self.step_time_s = step_time_s
        self.clock = now
        self.pool = PagePool(
            knobs.num_pages(size), knobs.page_size, knobs.max_pages_per_req
        )
        self.live: List[TokenRequest] = []
        self.queue: List[TokenRequest] = []  # FIFO; preempted resume first

    # -- admission (mirrors Engine.admit) -------------------------------------
    def _try_admit(self, req: TokenRequest, metrics: "TokenMetrics") -> bool:
        L = req.context_len
        self.pool.admit(req.rid)
        try:
            # context + room for the first decode write, like the engine
            self.pool.append_tokens(req.rid, L + 1)
        except OutOfPages:
            self.pool.release(req.rid)
            metrics.refusals[req.service] += 1
            return False
        if req.admit_s < 0.0:
            req.admit_s = self.clock
            metrics.queue_delay_s[req.service].append(
                self.clock - req.arrival_s
            )
        # serialized prefill charge, then the first token from its logits
        steps = -(-max(L, 1) // self.knobs.prefill_chunk)
        self.clock += steps * self.step_time_s(len(self.live) + 1)
        req.generated += 1
        if req.first_token_s < 0.0:
            req.first_token_s = self.clock
            metrics.ttft_s[req.service].append(self.clock - req.arrival_s)
        if req.done or req.context_len >= self.knobs.max_len:
            self._finish(req, metrics)
        else:
            self.live.append(req)
        return True

    def _admit_pass(self, metrics: "TokenMetrics") -> None:
        """First-fit over the arrived head of the queue (bounded scan), like
        the engine's run_closed_loop: a request the pool cannot hold must
        not head-of-line block admittable requests right behind it."""
        scanned = 0
        i = 0
        while i < len(self.queue) and len(self.live) < self.slots:
            req = self.queue[i]
            if req.arrival_s > self.clock + 1e-12 or scanned >= ADMIT_SCAN:
                break
            if self._try_admit(req, metrics):
                self.queue.pop(i)
            else:
                scanned += 1
                i += 1

    # -- decode (mirrors Engine.step) ------------------------------------------
    def _decode_step(self, metrics: "TokenMetrics") -> None:
        dt = self.step_time_s(len(self.live))
        self.clock += dt
        still_live: List[TokenRequest] = []
        resumed: List[TokenRequest] = []
        for req in self.live:
            # grow pages to cover this step's cache write (the engine keeps
            # pool length == written positions + the sampled-but-unwritten
            # token: exactly context_len), so the first post-admission step
            # needs no growth — the admission reserved one slot ahead
            need = req.context_len - self.pool.request(req.rid).length
            if need > 0:
                try:
                    self.pool.append_tokens(req.rid, need)
                except OutOfPages:
                    # preempt: pages released, resume later with generated
                    # tokens folded into the context (engine semantics); a
                    # resume needs context + 1 <= max_len to re-admit — at
                    # the cap there is no room, finish truncated like the
                    # engine's max_len path
                    if req.context_len + 1 > self.knobs.max_len:
                        self._finish(req, metrics)
                        continue
                    self.pool.release(req.rid)
                    req.preemptions += 1
                    metrics.preemptions[req.service] += 1
                    resumed.append(req)
                    continue
            req.generated += 1
            if req.done or req.context_len >= self.knobs.max_len:
                self._finish(req, metrics)
            else:
                still_live.append(req)
        self.live = still_live
        # preempted requests resume first, like run_closed_loop's re-queue
        self.queue[:0] = resumed

    def _finish(self, req: TokenRequest, metrics: "TokenMetrics") -> None:
        req.finish_s = self.clock
        self.pool.release(req.rid)
        if req.generated > 1:
            metrics.tpot_s[req.service].append(
                (req.finish_s - req.first_token_s) / (req.generated - 1)
            )
        metrics.completed_at[req.service].append(req.finish_s)

    # -- one traffic bin --------------------------------------------------------
    def run_until(self, t_end: float, metrics: "TokenMetrics") -> None:
        """Advance this instance's clock to ``t_end``, admitting and
        decoding.  The clock may overrun ``t_end`` by a fraction of a step —
        the remainder carries into the next bin, like a real engine whose
        step straddles a metrics-bin edge."""
        while self.clock < t_end - 1e-12:
            self._admit_pass(metrics)
            if not self.live:
                # idle: jump to the next queued arrival (an empty pool can
                # always admit an arrived request, so nothing arrived yet)
                nxt = [
                    r.arrival_s
                    for r in self.queue
                    if r.arrival_s > self.clock + 1e-12
                ]
                self.clock = min(min(nxt), t_end) if nxt else t_end
                continue
            self._decode_step(metrics)

    def drain(self) -> List[TokenRequest]:
        """Evict everything (the instance vanished mid-transition): queued
        and in-flight requests spill back to the service level; in-flight
        ones resume elsewhere with their generated tokens (a migration is a
        preemption from the request's point of view)."""
        for req in self.live:
            self.pool.release(req.rid)
            req.preemptions += 1
        out = self.live + self.queue
        self.live, self.queue = [], []
        return out

    @property
    def in_system(self) -> int:
        return len(self.live) + len(self.queue)


@dataclasses.dataclass
class TokenMetrics:
    """Per-service observation streams the report's summaries derive from."""

    services: List[str]
    ttft_s: Dict[str, List[float]] = dataclasses.field(default_factory=dict)
    tpot_s: Dict[str, List[float]] = dataclasses.field(default_factory=dict)
    queue_delay_s: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict
    )
    completed_at: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict
    )
    # per-service running event counts (a refusal is one OutOfPages
    # admission attempt; the same request may be refused many times)
    preemptions: Dict[str, int] = dataclasses.field(default_factory=dict)
    refusals: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for svc in self.services:
            self.ttft_s.setdefault(svc, [])
            self.tpot_s.setdefault(svc, [])
            self.queue_delay_s.setdefault(svc, [])
            self.completed_at.setdefault(svc, [])
            self.preemptions.setdefault(svc, 0)
            self.refusals.setdefault(svc, 0)


def _summary(vals: List[float], prefix: str) -> Dict[str, float]:
    if not vals:
        return {f"{prefix}_p{int(p)}_s": 0.0 for p in _PCTS}
    a = np.asarray(vals, dtype=np.float64)
    return {
        f"{prefix}_p{int(p)}_s": float(np.percentile(a, p)) for p in _PCTS
    }


class TokenServingState:
    """The simulator-side owner of the token model: one
    :class:`InstanceModel` per live instance, service-level spill queues,
    and the latency/preemption observation streams.

    ``step_time_for`` closes over the simulator's profile: per-token step
    time at occupancy ``b`` is ``latency_ms(svc, size, b) / 1000 /
    decode_tokens`` (corrected profiles — §8.3 ``MeasuredProfile`` — flow
    through unchanged, which is the calibration loop).
    """

    def __init__(
        self,
        services: List[str],
        profile,
        latency_slo_for: Callable[[str], float],
        knobs: Optional[TokenKnobs] = None,
    ):
        self.knobs = knobs or TokenKnobs()
        self.profile = profile
        self.latency_slo_for = latency_slo_for
        self.metrics = TokenMetrics(list(services))
        self.instances: Dict[int, InstanceModel] = {}
        self.spill: Dict[str, List[TokenRequest]] = {s: [] for s in services}
        self._next_rid = 0

    # -- construction helpers ---------------------------------------------------
    def step_time_for(
        self, svc: str, size: int, noise: float = 1.0
    ) -> Callable[[int], float]:
        knobs = self.knobs
        cache: Dict[int, float] = {}  # profile is fixed for the model's life

        def step_time_s(b: int) -> float:
            b = max(b, 1)
            v = cache.get(b)
            if v is None:
                lat = self.profile.latency_ms(svc, size, b)
                v = cache[b] = (
                    lat / 1000.0 / knobs.step_decode_tokens
                ) / noise
            return v

        return step_time_s

    def slots_for(self, svc: str, size: int) -> int:
        """§7: the largest SLO-compliant batch is the engine's slot count."""
        return max(
            self.profile.best_batch(svc, size, self.latency_slo_for(svc)), 1
        )

    def make_request(
        self, svc: str, arrival_s: float, rng: np.random.Generator
    ) -> TokenRequest:
        knobs = self.knobs
        prompt = int(rng.integers(1, 2 * knobs.prompt_tokens))
        decode = int(rng.integers(1, 2 * knobs.decode_tokens))
        # clamp so prompt + decode fits the context cap (no unservable reqs)
        prompt = min(prompt, knobs.max_len - 2)
        decode = min(decode, knobs.max_len - 1 - prompt)
        rid = self._next_rid
        self._next_rid += 1
        return TokenRequest(rid, svc, arrival_s, prompt, max(decode, 1))

    # -- instance-set sync -------------------------------------------------------
    def sync_instances(
        self, live: InstanceSet, noise_of: Callable[[int], float], now: float
    ) -> None:
        """Reconcile the per-uid models with this bin's instance set: new
        uids get fresh models, vanished uids spill their requests back to
        the service level (re-routed this bin)."""
        for uid in [u for u in self.instances if u not in live]:
            inst = self.instances.pop(uid)
            for req in inst.live:
                self.metrics.preemptions[req.service] += 1
            for req in inst.drain():
                self.spill[req.service].append(req)
        for uid in sorted(live):
            if uid in self.instances:
                continue
            svc, size, _tput = live[uid]
            self.instances[uid] = InstanceModel(
                uid,
                svc,
                size,
                self.slots_for(svc, size),
                self.knobs,
                self.step_time_for(svc, size, noise_of(uid)),
                now,
            )

    # -- per-bin serving ---------------------------------------------------------
    def dispatch(
        self,
        svc: str,
        members: List[int],
        pick: Callable[[], int],
        new_requests: List[TokenRequest],
    ) -> None:
        """Route spilled + newly arrived requests over the service's
        instances (spill first: those arrived earlier).  ``pick`` is the
        service's smooth-WRR router returning a uid."""
        pending = self.spill[svc] + new_requests
        self.spill[svc] = []
        if not members:
            self.spill[svc] = pending
            return
        for req in pending:
            self.instances[pick()].queue.append(req)

    def serve_bin(self, t_end: float) -> None:
        for uid in sorted(self.instances):
            self.instances[uid].run_until(t_end, self.metrics)

    # -- accounting ---------------------------------------------------------------
    def completed_in(self, svc: str, t0: float, t1: float) -> int:
        return sum(
            1 for t in self.metrics.completed_at[svc] if t0 <= t < t1
        )

    def in_system(self, svc: str) -> int:
        return len(self.spill[svc]) + sum(
            i.in_system for i in self.instances.values() if i.service == svc
        )

    def latency_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-service TTFT/TPOT/queue-delay p50/p95/p99 plus conservation
        counts — the report extension serialized only in token mode."""
        m = self.metrics
        out: Dict[str, Dict[str, float]] = {}
        for svc in sorted(m.services):
            entry: Dict[str, float] = {}
            entry.update(_summary(m.ttft_s[svc], "ttft"))
            entry.update(_summary(m.tpot_s[svc], "tpot"))
            entry.update(_summary(m.queue_delay_s[svc], "queue_delay"))
            entry["completed"] = len(m.completed_at[svc])
            entry["in_system"] = self.in_system(svc)
            out[svc] = entry
        out["_totals"] = {
            "preemptions": sum(m.preemptions.values()),
            "refusals": sum(m.refusals.values()),
            "completed": sum(len(v) for v in m.completed_at.values()),
        }
        return out
