"""Declarative scenario matrix over seven axes.

The RMS framing (§3) makes the paper's pipeline one point in a family of
scheduling algorithms; this module is the harness that compares the family
under diverse workloads.  A :class:`ScenarioCell` names one coordinate of

    trace x scheduler x scale x SLO x fault x serving-model x priority-mix

spelled ``trace:sched:scale:slo[:fault[:serving[:priority]]]`` on the
``benchmarks/bench_scenarios.py --cell`` command line (trailing axes may be
omitted and default to ``none``/``fluid``/``none``).  The axis registries —
:data:`TRACE_SHAPES`, :data:`SCHEDULERS`, :data:`SCALES`,
:data:`SLO_POLICIES`, :data:`repro.controlplane.faults.FAULT_PROFILES`, the
serving models ``("fluid", "token")``, and :data:`PRIORITY_MIXES` — each map
a name to that axis's knobs; ``docs/SCENARIOS.md`` documents every valid
name.  The first four axes run as a full cross-product (pinned to the
historical :data:`FLUID_TRACES` / :data:`FLUID_SCHEDULERS` /
:data:`FLUID_SCALES` sets); faults, token serving, overload/priority, and
warm-start run as curated slices — see :func:`default_matrix`.
:func:`run_cell` runs one cell through the closed-loop simulator
(:class:`repro.sim.simulator.ClusterSimulator`), returning a
:class:`CellResult` with the comparable per-cell metrics:

  * per-service SLO attainment (fraction of bins at >= 100% capacity),
  * GPUs used (final and peak over the run),
  * in-loop reoptimize latency (mean transition parallel makespan — the
    Figure-13c action cost the simulator charges to in-flight capacity),
  * the paper's headline "GPUs saved vs A100-as-is" (§8.1: whole-GPU
    serving of the same peak demand, ``baseline_homogeneous`` at
    ``size=device_size``),
  * modeled power of the final instance set (:class:`repro.core.zoo.PowerModel`),
  * control-plane fault metrics (``fault != "none"`` cells): availability
    (fraction of bins with every service at required rate), recovery time
    to SLO re-attainment after the worst injected fault, reconcile
    convergence iterations, actions retried/abandoned, requests shed by
    degraded-mode admission control,
  * a SHA-256 of the cell's ``SimReport.to_json()`` — the determinism
    contract, per cell.

Everything derives from explicit seeds: :func:`run_matrix` with the same
seed produces a byte-identical JSON document (wall-clock timings are
deliberately *excluded*; ``benchmarks/bench_scenarios.py`` prints them to
stdout instead).

Extending the matrix (ROADMAP "Scenario matrix" / "Control plane"):

  * new trace shape  -> add a generator to :mod:`repro.sim.traffic`, then a
    ``TRACE_SHAPES`` entry mapping peaks+spec+seed to a ``Trace``;
  * new scheduler    -> register it in
    :data:`repro.core.optimizer.FAST_ALGORITHMS`, then add a ``SCHEDULERS``
    entry naming the ``optimizer_kwargs``;
  * new scale        -> a ``SCALES`` entry (service count, rate scale,
    duration, cadence);
  * new SLO policy   -> an ``SLO_POLICIES`` entry mapping sorted service
    names to (default latency, per-service overrides);
  * new fault profile -> ``repro.controlplane.faults.register_fault_profile``
    (seeded; ``default_matrix`` picks it up on the curated fault slice);
  * serving model    -> ``ScenarioCell.serving`` selects
    ``SimConfig.serving_model`` ("fluid" | "token"); token cells also carry
    TTFT/TPOT/queue-delay percentiles and preemption/refusal counts in
    ``CellResult.token_serving``;
  * priority mix     -> a ``PRIORITY_MIXES`` entry naming a
    :class:`repro.sim.traffic.PriorityMix` (per-class traffic weights +
    deadlines; see its docstring) — non-"none" cells run the token model's
    overload-resilience path and carry ``CellResult.priority``;
  * scheduler *variants* (e.g. ``greedy_warm``) -> a ``SCHEDULERS`` entry
    whose dict carries driver-level knobs (``warm_start`` & co.) alongside
    the ``fast`` algorithm name.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.controlplane.faults import FAULT_PROFILES
from repro.core.lower_bound import baseline_homogeneous
from repro.core.mig import a100_rules
from repro.core.profiles import SyntheticPaperProfiles
from repro.core.zoo import PowerModel

from repro.sim.report import SimReport
from repro.sim.servemodel import TokenKnobs
from repro.sim.simulator import ClusterSimulator, SimConfig
from repro.sim.traffic import (
    PriorityMix,
    Trace,
    correlated_surge_trace,
    diurnal_trace,
    flash_crowd_trace,
    poisson_burst_trace,
)


@dataclasses.dataclass(frozen=True)
class ScaleSpec:
    """One point on the matrix's scale axis."""

    n_services: int
    rate_scale: float  # lognormal mean of per-service peak req/s
    duration_s: float
    bin_s: float
    reoptimize_every_s: float
    profile_seed: int = 9


SCALES: Dict[str, ScaleSpec] = {
    "small": ScaleSpec(3, 7.0, 2 * 3600.0, 60.0, 1800.0),
    "medium": ScaleSpec(6, 7.6, 2 * 3600.0, 60.0, 1800.0),
    # request-level scale: rates low enough that the token serving model
    # (every request a discrete object) stays cheap, duration short enough
    # for CI — used by the curated token slice, not the fluid cross-product.
    # profile_seed=2 picks the lowest-throughput synthetic models (so
    # demand can plausibly stress an instance), and rate_scale=3.6 sits
    # just past the point where the flash crowd outruns the deployment:
    # the cell shows a real queueing ramp + KV-pressure preemption storm,
    # then fully drains once the re-optimizer reacts
    "micro": ScaleSpec(2, 3.6, 600.0, 30.0, 300.0, profile_seed=2),
}

# peaks are per-service peak req/s; generators down-scale them to base rates
# where the shape multiplies upward, so all shapes stress comparable demand
TRACE_SHAPES: Dict[str, Callable[[Mapping[str, float], ScaleSpec, int], Trace]] = {
    "diurnal": lambda peaks, spec, seed: diurnal_trace(
        peaks, duration_s=spec.duration_s, bin_s=spec.bin_s,
        night_frac=0.25, seed=seed,
    ),
    "burst": lambda peaks, spec, seed: poisson_burst_trace(
        {s: p / 3.0 for s, p in peaks.items()},
        duration_s=spec.duration_s, bin_s=spec.bin_s,
        burst_mult=3.0, burst_prob=0.05, burst_len_bins=5, seed=seed,
    ),
    "surge": lambda peaks, spec, seed: correlated_surge_trace(
        {s: p / 4.0 for s, p in peaks.items()},
        duration_s=spec.duration_s, bin_s=spec.bin_s,
        surge_mult=4.0, n_surges=2, surge_len_bins=15, ramp_bins=3,
        correlation=0.8, seed=seed,
    ),
    "flash": lambda peaks, spec, seed: flash_crowd_trace(
        {s: p / 5.0 for s, p in peaks.items()},
        duration_s=spec.duration_s, at_s=spec.duration_s / 3.0,
        bin_s=spec.bin_s, mult=5.0, ramp_s=2 * spec.bin_s, decay_s=600.0,
    ),
}

# the fluid cross-product is pinned to its historical axes: "flash" and
# "micro" exist for the curated token slice (a flash crowd is exactly the
# queueing/KV-pressure event the fluid model cannot represent), and folding
# them into the 4-way product would add a page of redundant fluid cells
FLUID_TRACES = ("burst", "diurnal", "surge")
FLUID_SCALES = ("medium", "small")

# scheduler name -> optimizer_kwargs routed through the ReoptimizeDriver:
# "fast" selects a repro.core.optimizer.FAST_ALGORITHMS entry; driver-level
# knobs (warm_start, warm_divergence, warm_edit_frac, time_budget_s) are
# popped by the driver before the rest reaches TwoPhaseOptimizer
SCHEDULERS: Dict[str, Dict] = {
    "greedy": {"fast": "greedy"},
    "beam": {"fast": "beam"},
    "frag": {"fast": "frag"},
    "energy": {"fast": "energy"},
    # warm-start incremental reoptimization: the paper greedy seeded from
    # the incumbent deployment (rebound ConfigSpace + delta repair + bounded
    # edit distance).  Runs on the curated WARM_SLICE, not the fluid
    # cross-product — FLUID_SCHEDULERS pins the historical product.  The
    # thresholds are wider than the core defaults because the matrix's
    # traces swing 3-4x between 1800 s reoptimize checks: divergence 4.0
    # admits those swings, edit budget 1.0 x incumbent still bounds the
    # transition to half a full rebuild's device churn.
    "greedy_warm": {
        "fast": "greedy",
        "warm_start": True,
        "warm_divergence": 4.0,
        "warm_edit_frac": 1.0,
    },
}

# the fluid cross-product is pinned to the historical scheduler set;
# "greedy_warm" compares against its "greedy" twin on the curated warm
# slice instead of quadrupling the product with near-duplicate cells
FLUID_SCHEDULERS = ("beam", "energy", "frag", "greedy")

# policy name -> (sorted service names -> (default latency ms, overrides))
SLO_POLICIES: Dict[
    str, Callable[[List[str]], Tuple[float, Optional[Dict[str, float]]]]
] = {
    "uniform": lambda names: (100.0, None),
    # alternate interactive (50 ms) / batchy (200 ms) services
    "tiered": lambda names: (
        100.0,
        {n: (50.0 if i % 2 == 0 else 200.0) for i, n in enumerate(names)},
    ),
}


@dataclasses.dataclass(frozen=True)
class ScenarioCell:
    """One coordinate of the scenario matrix."""

    trace: str
    scheduler: str
    scale: str
    slo: str = "uniform"
    fault: str = "none"  # FAULT_PROFILES name; != "none" => control plane
    serving: str = "fluid"  # SimConfig.serving_model: "fluid" | "token"
    priority: str = "none"  # PRIORITY_MIXES name; != "none" => resilience

    @property
    def name(self) -> str:
        # the serving/priority suffixes appear only off their defaults, so
        # every pre-existing cell keeps its exact historical name (and the
        # report documents keyed by it stay comparable)
        return (
            f"{self.trace}/{self.scheduler}/{self.scale}/{self.slo}"
            f"/{self.fault}"
            + (f"/{self.serving}" if self.serving != "fluid" else "")
            + (f"/{self.priority}" if self.priority != "none" else "")
        )


# the fault axis is curated rather than fully crossed: every registered
# profile runs against the surge trace at small scale under the paper
# greedy and the fragmentation-aware packer — fault dynamics (recovery,
# availability) vary with the profile and the scheduler's packing style,
# not with every trace/SLO combination, and the full 5-way product would
# triple the benchmark's wall clock for redundant cells
FAULT_SLICE_SCHEDULERS = ("frag", "greedy")

# the serving axis is curated like the fault axis: the token model runs the
# two traces whose request-level dynamics the fluid model cannot represent
# (a flash crowd's queueing ramp, a correlated surge's KV-pressure spike) at
# the request-level scale
TOKEN_SLICE_TRACES = ("flash", "surge")

# knobs of the token slice: drawn decode budgets are 4x the budget the
# profile's latency numbers assumed, so real per-request service time is
# ~4x the profiled request latency and the planner's rate math (which the
# fluid model serves at face value) over-promises capacity — the slice's
# flash crowd then actually outruns the deployment between re-optimization
# points, producing the queueing/preemption dynamics the cell exists to show
TOKEN_SLICE_KNOBS = TokenKnobs(profiled_decode_tokens=4)

# priority-mix registry (the seventh axis): "none" keeps every historical
# code path; "mixed" is the curated overload mix — a fifth of traffic is
# latency-critical with a tight deadline, most is standard, the tail is
# deadline-less batch.  Deadlines are sized against the micro-scale token
# cells' TTFT distribution so an overloaded bin produces real deadline
# drops without collapsing goodput outright.
PRIORITY_MIXES: Dict[str, Optional[PriorityMix]] = {
    "none": None,
    "mixed": PriorityMix(
        weights=(0.2, 0.6, 0.2),
        deadline_s=(3.0, 12.0, float("inf")),
    ),
}

# the overload slice (curated like the fault and token slices): adversarial
# traffic x the "mixed" priority load x a serving-path fault, at the
# request-level scale.  The instance-crash cells put the crash-spill /
# retry-backoff path under KV pressure; the gpu_loss cell exercises
# priority-aware (lowest-class-first) shedding during a real capacity
# outage, which an in-place crash never triggers.
OVERLOAD_SLICE = (
    ("flash", "instance_crash"),
    ("surge", "instance_crash"),
    ("flash", "gpu_loss"),
)

# the warm-start slice: greedy_warm against the two trace/scale points where
# reoptimization fires most — a diurnal swing at medium scale (many gradual
# drifts: the warm path's home turf) and a correlated surge at small scale
# (sharp rate jumps probing the divergence fallback).  Each cell reads
# against its "greedy" twin in the fluid product.
WARM_SLICE = (("diurnal", "medium"), ("surge", "small"))


def _validate_axis(value: str, registry, axis: str) -> None:
    """Fail fast with the registry's valid names — not a KeyError mid-run."""
    if value not in registry:
        raise ValueError(
            f"unknown {axis} {value!r}; valid {axis} names: "
            f"{sorted(registry)}"
        )


def default_matrix() -> List[ScenarioCell]:
    """The published matrix: the full 4-axis cross-product under the
    ``none`` profile (historical fluid axes only), plus the curated fault
    and token-serving slices."""
    cells = [
        ScenarioCell(trace, sched, scale, slo)
        for trace in sorted(FLUID_TRACES)
        for sched in sorted(FLUID_SCHEDULERS)
        for scale in sorted(FLUID_SCALES)
        for slo in sorted(SLO_POLICIES)
    ]
    cells += [
        ScenarioCell("surge", sched, "small", "uniform", fault)
        for fault in sorted(FAULT_PROFILES)
        if fault != "none"
        for sched in FAULT_SLICE_SCHEDULERS
    ]
    cells += [
        ScenarioCell(trace, "greedy", "micro", "uniform", serving="token")
        for trace in TOKEN_SLICE_TRACES
    ]
    cells += [
        ScenarioCell(
            trace, "greedy", "micro", "uniform", fault,
            serving="token", priority="mixed",
        )
        for trace, fault in OVERLOAD_SLICE
    ]
    cells += [
        ScenarioCell(trace, "greedy_warm", scale, "uniform")
        for trace, scale in WARM_SLICE
    ]
    return cells


def smoke_matrix() -> List[ScenarioCell]:
    """Tiny CI matrix: both new zoo schedulers plus the paper greedy, one
    trace per family, small scale only, one fault-profile cell — fast
    enough for every CI run."""
    return [
        ScenarioCell("diurnal", "greedy", "small", "uniform"),
        ScenarioCell("surge", "frag", "small", "uniform"),
        ScenarioCell("surge", "energy", "small", "tiered"),
        ScenarioCell("surge", "greedy", "small", "uniform", "gpu_loss"),
        ScenarioCell("flash", "greedy", "micro", "uniform", serving="token"),
        ScenarioCell(
            "flash", "greedy", "micro", "uniform", "instance_crash",
            serving="token", priority="mixed",
        ),
        ScenarioCell("surge", "greedy_warm", "small", "uniform"),
    ]


@dataclasses.dataclass
class CellResult:
    """Comparable metrics of one scenario cell (all seed-deterministic)."""

    cell: ScenarioCell
    slo_satisfaction: Dict[str, float]  # svc -> fraction of bins satisfied
    mean_attainment: float  # mean over services of mean per-bin attainment
    served_fraction: float  # served / arrived, worst service
    gpus_final: int
    gpus_peak: int
    gpus_asis: int  # whole-GPU (A100-as-is) serving of the same peak demand
    gpus_saved: int  # gpus_asis - gpus_peak (the paper's headline, §8.1)
    transitions: int
    reoptimize_checks: int
    reoptimize_latency_s: float  # mean transition parallel makespan
    power_w: float  # modeled power of the final instance set
    transparent: bool  # §6 guarantee held at every trace point
    report_sha256: str  # SHA-256 of the cell's SimReport.to_json()
    # control-plane metrics.  availability is computed for EVERY cell (it
    # is the comparison baseline: a fault cell's availability reads against
    # its none twin's); the remaining fields stay at their zero/None
    # defaults unless the cell ran under a fault profile.
    availability: float = 1.0  # fraction of bins with every svc at required
    fault_events: int = 0  # injected device faults that actually fired
    recovery_time_s: Optional[float] = None  # worst fault -> re-attainment
    reconcile_iterations: int = 0  # transition attempts across all passes
    actions_retried: int = 0  # attempts killed by injected faults
    actions_abandoned: int = 0  # diff items given up on
    shed_requests: float = 0.0  # dropped by degraded-mode admission control
    # token-serving cells only (cell.serving == "token"): the report's
    # per-service TTFT/TPOT/queue-delay percentiles + "_totals" counts
    token_serving: Optional[Dict] = None
    # priority-mix cells only (cell.priority != "none"): the report's
    # per-class goodput / SLO-attainment / drop / retry block
    priority: Optional[Dict] = None

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)  # recurses into the nested cell


def build_cell(
    cell: ScenarioCell, seed: int = 0, observability: bool = False
) -> Tuple[ClusterSimulator, Trace]:
    """Materialize one cell: profiles, trace, config, wired simulator.

    Every axis name is validated up front (ValueError listing the registry's
    valid names) so a typo'd cell fails fast instead of KeyError-ing deep in
    the run."""
    _validate_axis(cell.trace, TRACE_SHAPES, "trace")
    _validate_axis(cell.scheduler, SCHEDULERS, "scheduler")
    _validate_axis(cell.scale, SCALES, "scale")
    _validate_axis(cell.slo, SLO_POLICIES, "SLO policy")
    _validate_axis(cell.fault, FAULT_PROFILES, "fault profile")
    _validate_axis(cell.serving, ("fluid", "token"), "serving model")
    _validate_axis(cell.priority, PRIORITY_MIXES, "priority mix")
    spec = SCALES[cell.scale]
    prof = SyntheticPaperProfiles(n_models=spec.n_services, seed=spec.profile_seed)
    rng = np.random.default_rng((seed, spec.n_services, spec.profile_seed))
    peaks = {m: float(rng.lognormal(spec.rate_scale, 0.5)) for m in prof.services()}
    trace = TRACE_SHAPES[cell.trace](peaks, spec, seed)
    default_lat, targets = SLO_POLICIES[cell.slo](sorted(trace.services))
    cfg = SimConfig(
        reoptimize_every_s=spec.reoptimize_every_s,
        latency_slo_ms=default_lat,
        latency_targets=targets,
        seed=seed,
        fault_profile=cell.fault,
        control_plane=cell.fault != "none",
        serving_model=cell.serving,
        token_knobs=(
            TOKEN_SLICE_KNOBS if cell.serving == "token" else None
        ),
        priority_mix=PRIORITY_MIXES[cell.priority],
        observability=observability,
    )
    sim = ClusterSimulator(
        a100_rules(), prof, trace, cfg,
        optimizer_kwargs=dict(SCHEDULERS[cell.scheduler]),
    )
    return sim, trace


def run_cell(
    cell: ScenarioCell, seed: int = 0, observability: bool = False
) -> Tuple[CellResult, SimReport]:
    sim, trace = build_cell(cell, seed, observability=observability)
    rep = sim.run()
    return _cell_result(cell, sim, trace, rep), rep


def run_cell_obs(
    cell: ScenarioCell, seed: int = 0, record_limit: int = 256
) -> Tuple[CellResult, SimReport, str]:
    """Run one cell with the flight recorder on; additionally returns the
    tracer's Chrome trace-event JSON (Perfetto-loadable, deterministic —
    same seed, byte-identical export).  Note ``report_sha256`` hashes the
    obs-bearing report, so it differs from the cell's observability-off SHA
    by design (the byte-identity contract covers observability *off*)."""
    sim, trace = build_cell(cell, seed, observability=True)
    sim.config.obs_record_limit = record_limit
    if record_limit != 256:
        # the recorder was sized at construction; re-limit before running
        sim.obs.flight.record_limit = record_limit
    rep = sim.run()
    return _cell_result(cell, sim, trace, rep), rep, sim.obs.tracer.export_json()


def _cell_result(
    cell: ScenarioCell, sim: ClusterSimulator, trace: Trace, rep: SimReport
) -> CellResult:
    gpus_peak = max(
        [rep.final_gpus]
        + [t.gpus_before for t in rep.transitions]
        + [t.gpus_after for t in rep.transitions]
    )
    # A100-as-is: whole GPUs only, sized for the same peak demand under the
    # same headroom/SLO policy the cell's driver applies
    rules = sim.rules
    peak_rates = {svc: float(trace.rates[svc].max()) for svc in trace.services}
    asis_wl = sim.driver.workload_for(peak_rates)
    gpus_asis = baseline_homogeneous(rules, sim.profile, asis_wl, rules.device_size)
    parallel = [t.parallel_seconds for t in rep.transitions]
    power = PowerModel().instances_power(
        sim.cluster.busy_instances().values(), sim.cluster.gpus_in_use()
    )
    reconciles = [t.reconcile for t in rep.transitions if t.reconcile]
    return CellResult(
        cell=cell,
        slo_satisfaction={s: rep.slo_satisfaction(s) for s in rep.services},
        mean_attainment=float(
            np.mean([rep.mean_attainment(s) for s in rep.services])
        ),
        served_fraction=min(rep.served_fraction(s) for s in rep.services),
        gpus_final=rep.final_gpus,
        gpus_peak=gpus_peak,
        gpus_asis=gpus_asis,
        gpus_saved=gpus_asis - gpus_peak,
        transitions=len(rep.transitions),
        reoptimize_checks=rep.reoptimize_checks,
        reoptimize_latency_s=float(np.mean(parallel)) if parallel else 0.0,
        power_w=power,
        transparent=rep.transparent,
        report_sha256=hashlib.sha256(rep.to_json().encode()).hexdigest(),
        availability=rep.availability(),
        fault_events=len(rep.faults),
        recovery_time_s=rep.recovery_time_s(),
        reconcile_iterations=sum(r["iterations"] for r in reconciles),
        actions_retried=sum(r["retried"] for r in reconciles),
        actions_abandoned=sum(r["abandoned"] for r in reconciles),
        shed_requests=rep.shed_total(),
        token_serving=rep.latency,
        priority=rep.priority,
    )


def matrix_doc(
    cells: List[ScenarioCell], results: Dict[str, Dict], seed: int
) -> Dict:
    """The report document schema — the single source of truth shared by
    :func:`run_matrix` and ``benchmarks/bench_scenarios.py``."""
    return {
        "schema": 1,
        "seed": seed,
        "axes": {
            "traces": sorted({c.trace for c in cells}),
            "schedulers": sorted({c.scheduler for c in cells}),
            "scales": sorted({c.scale for c in cells}),
            "slo_policies": sorted({c.slo for c in cells}),
            "fault_profiles": sorted({c.fault for c in cells}),
            "serving_models": sorted({c.serving for c in cells}),
            "priority_mixes": sorted({c.priority for c in cells}),
        },
        "cells": results,
    }


def run_matrix(cells: List[ScenarioCell], seed: int = 0) -> Dict:
    """Run every cell; returns the deterministic report document.

    Same ``cells`` + same ``seed`` => byte-identical
    ``json.dumps(doc, sort_keys=True)`` — wall-clock never enters the doc.
    """
    results: Dict[str, Dict] = {}
    for cell in cells:
        res, _ = run_cell(cell, seed)
        results[cell.name] = res.to_dict()
    return matrix_doc(cells, results, seed)
