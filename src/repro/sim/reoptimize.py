"""The closed loop's actuator: observe load, re-optimize, transition.

This is the piece that turns the repo's isolated components into the paper's
system: a :class:`ReoptimizeDriver` periodically takes the observed
per-service arrival rates, builds a workload (SLO throughput = observed rate
x headroom), runs the phase-1/phase-2 optimizer pipeline
(:class:`repro.core.optimizer.TwoPhaseOptimizer`), and — when the demand
moved enough — executes the resulting target deployment through the
exchange-and-compact controller (§6).

The controller applies actions against :class:`SimulatedCluster`
synchronously; serving, however, must pay the paper's Figure-13c action
latencies.  The driver therefore converts the cluster's instance-level
action trace into a :class:`PendingTransition`: a timeline of instance-set
snapshots placed at list-scheduled times compressed to the dependency-aware
parallel makespan.  The simulator serves from this timeline while the
transition is in flight, so creates only add capacity once their 62 s have
elapsed, and the §6 transparency margin is measured at every trace point.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from repro.controlplane.reconciler import ControlPlane, ReconcileStats
from repro.controlplane.spec import DesiredState
from repro.core.cluster import SimulatedCluster
from repro.core.controller import Controller, TransitionReport
from repro.core.deployment import Deployment, IndexedDeployment, Workload
from repro.core.optimizer import OptimizeReport, TwoPhaseOptimizer
from repro.core.profiles import PerfProfile
from repro.core.rms import SLO, ReconfigRules

from repro.sim.report import TransitionRecord

# uid -> (service, size, throughput)
InstanceSet = Dict[int, Tuple[str, int, float]]


@dataclasses.dataclass
class PendingTransition:
    """A transition whose actions are still paying their latencies."""

    start_s: float
    end_s: float
    # (sim time, busy instances after that action), ascending in time
    timeline: List[Tuple[float, InstanceSet]]
    record: TransitionRecord

    def instances_at(self, t: float) -> InstanceSet:
        """The serving instance set at sim time ``t`` (last snapshot <= t)."""
        current = self.timeline[0][1]
        for ts, snap in self.timeline:
            if ts <= t + 1e-9:
                current = snap
            else:
                break
        return current


class ReoptimizeDriver:
    """Observe -> optimize -> transition, with explicit seeds throughout."""

    def __init__(
        self,
        rules: ReconfigRules,
        profile: PerfProfile,
        latency_slo_ms: float = 100.0,
        headroom: float = 1.1,
        change_threshold: float = 0.15,
        use_phase2: bool = False,
        seed: int = 0,
        optimizer_kwargs: Optional[Dict] = None,
        latency_targets: Optional[Mapping[str, float]] = None,
        control_plane: Optional[ControlPlane] = None,
        warm_start: bool = False,
        warm_divergence: float = 0.5,
        warm_edit_frac: float = 0.5,
        time_budget_s: Optional[float] = None,
    ):
        self.rules = rules
        self.profile = profile
        self.controller = Controller(rules, profile)
        # control_plane= mode (repro.controlplane): transitions route through
        # the level-triggered reconciler instead of one direct
        # Controller.transition, and divergence (device faults) triggers
        # repair passes even when demand did not move.  None = the direct
        # path, bit-for-bit identical to the pre-control-plane behavior.
        self.control_plane = control_plane
        self.desired: Optional[DesiredState] = None  # reconciler's target
        self.latency_slo_ms = latency_slo_ms
        # per-service latency SLOs (an interactive service can demand 50 ms
        # while a batchy one tolerates 200 ms); services absent from the map
        # fall back to the uniform latency_slo_ms
        self.latency_targets = dict(latency_targets or {})
        self.headroom = headroom
        self.change_threshold = change_threshold
        self.use_phase2 = use_phase2
        self.seed = seed
        self.optimizer_kwargs = dict(optimizer_kwargs or {})
        # Driver-level knobs may also arrive through optimizer_kwargs — the
        # scenario matrix's SCHEDULERS registry reaches the driver only that
        # way — so pop them before the dict is forwarded to the optimizer.
        self.warm_start = bool(self.optimizer_kwargs.pop("warm_start", warm_start))
        self.warm_divergence = float(
            self.optimizer_kwargs.pop("warm_divergence", warm_divergence)
        )
        self.warm_edit_frac = float(
            self.optimizer_kwargs.pop("warm_edit_frac", warm_edit_frac)
        )
        self.time_budget_s = self.optimizer_kwargs.pop("time_budget_s", time_budget_s)
        # warm-start state: the last solve's ConfigSpace and winning indexed
        # deployment, carried cycle to cycle.  Populated only when warm_start
        # is on, so the cold path's behavior (and bytes) cannot shift.
        self._warm_space = None
        self._incumbent: Optional[IndexedDeployment] = None
        self._incumbent_workload: Optional[Workload] = None
        self.workload: Optional[Workload] = None  # currently deployed target
        # wall-clock of the most recent optimizer pipeline run; optimizer
        # latency sits on the serving hot path (every reoptimize fires the
        # full greedy/GA/MCTS stack), so the closed loop exposes it for
        # benchmarks without touching the deterministic SimReport bytes
        self.last_optimize_report: Optional[OptimizeReport] = None
        # flight-recorder observability (repro.obs.Observability): installed
        # by ClusterSimulator only when SimConfig.observability is on, so the
        # default path pays one None check per cycle and nothing else
        self.obs = None

    # -- observation --------------------------------------------------------------
    def workload_for(self, observed_rates: Mapping[str, float]) -> Workload:
        """SLO throughput = observed rate x headroom (floored at 1 req/s so
        the optimizer's per-service normalization stays finite); latency =
        the service's entry in ``latency_targets``, else ``latency_slo_ms``."""
        return Workload.make(
            {
                svc: SLO(
                    max(rate * self.headroom, 1.0),
                    self.latency_targets.get(svc, self.latency_slo_ms),
                )
                for svc, rate in sorted(observed_rates.items())
            }
        )

    def demand_moved(self, new: Workload) -> bool:
        """Did any service's required throughput move more than the
        threshold relative to the deployed target?"""
        if self.workload is None:
            return True
        old = {s.name: s.slo.throughput for s in self.workload.services}
        for s in new.services:
            base = max(old.get(s.name, 1.0), 1.0)
            if abs(s.slo.throughput - base) / base > self.change_threshold:
                return True
        return False

    # -- optimization -------------------------------------------------------------
    def optimize(self, workload: Workload) -> Deployment:
        kwargs = dict(self.optimizer_kwargs)
        if self.time_budget_s is not None:
            kwargs["time_budget_s"] = self.time_budget_s
        if (
            self.warm_start
            and self._warm_space is not None
            and self._incumbent is not None
            and self._warm_space.compatible(workload)
        ):
            # warm start: rebind last cycle's ConfigSpace to the drifted
            # rates (shared enumeration, so incumbent counts carry over
            # index-for-index) and seed the optimizer with the incumbent
            space = self._warm_space.rebind(workload)
            kwargs.update(
                space=space,
                incumbent=IndexedDeployment(
                    space, self._incumbent.counts.copy(), list(self._incumbent.extras)
                ),
                incumbent_workload=self._incumbent_workload,
                warm_divergence=self.warm_divergence,
                warm_edit_frac=self.warm_edit_frac,
            )
        opt = TwoPhaseOptimizer(
            self.rules,
            self.profile,
            workload,
            seed=self.seed,
            **kwargs,
        )
        report = opt.run(skip_phase2=not self.use_phase2)
        self.last_optimize_report = report
        dep = report.best_deployment
        if self.warm_start:
            self._warm_space = opt.space
            self._incumbent = report.best_indexed(opt.space)
            self._incumbent_workload = workload
        if self.control_plane is not None:
            # refresh the reconciler's declarative target (§6's "desired
            # state"): the deployment, its array-native twin, and the
            # required rates it was sized for
            self.desired = DesiredState(
                deployment=dep,
                required={
                    s.name: s.slo.throughput for s in workload.services
                },
                indexed=IndexedDeployment.from_deployment(opt.space, dep),
            )
        return dep

    # -- actuation ----------------------------------------------------------------
    def initial_deploy(
        self, cluster: SimulatedCluster, observed_rates: Mapping[str, float]
    ) -> Deployment:
        workload = self.workload_for(observed_rates)
        dep = self.optimize(workload)
        self.controller.deploy_fresh(cluster, dep)
        # the driver is the sole instance_trace consumer and only ever reads
        # the current transition's tail — drop consumed history so long
        # many-transition runs stay O(one transition) in memory
        cluster.instance_trace.clear()
        self.workload = workload
        return dep

    def reoptimize(
        self,
        cluster: SimulatedCluster,
        observed_rates: Mapping[str, float],
        now: float,
    ) -> Optional[PendingTransition]:
        """Run one observe->optimize->transition step at sim time ``now``.

        Returns ``None`` when demand has not moved enough to act.  In
        ``control_plane=`` mode a steady demand still level-triggers a
        repair pass when the observed cluster diverged from the desired
        state (device faults since the last look).
        """
        new_workload = self.workload_for(observed_rates)
        if not self.demand_moved(new_workload):
            if self.control_plane is not None:
                return self.reconcile_divergence(cluster, now)
            return None
        if self.workload is None:
            raise RuntimeError(
                "reoptimize() before initial_deploy(): the driver has no "
                "deployed workload to transition from"
            )
        cluster.record_instance_trace = True
        old_required = {
            s.name: s.slo.throughput for s in self.workload.services
        }
        new_required = {
            s.name: s.slo.throughput for s in new_workload.services
        }

        new_dep = self.optimize(new_workload)
        if self.obs is not None:
            rep = self.last_optimize_report
            # zero sim-time: the solve is instantaneous in simulation time
            # (its real wall clock lives in OptimizeReport, off the report
            # bytes); warm/cold tells which solver path produced the target
            self.obs.tracer.span(
                "reoptimize",
                "optimize",
                now,
                now,
                args={
                    "warm": bool(getattr(rep, "warm", False)),
                    "phase2": self.use_phase2,
                },
            )
        pre_instances = cluster.busy_instances()
        gpus_before = cluster.gpus_in_use()
        n0 = len(cluster.instance_trace)
        na0 = len(cluster.actions_applied)
        clock0 = cluster.clock
        report, stats = self._execute_transition(cluster, new_dep)
        self.workload = new_workload

        pending = self._build_pending(
            now, pre_instances, cluster, n0, clock0, report,
            old_required, new_required, gpus_before,
            trigger="demand", stats=stats, na0=na0,
        )
        cluster.instance_trace.clear()  # consumed; see initial_deploy
        return pending

    def _execute_transition(
        self, cluster: SimulatedCluster, new_dep: Deployment
    ) -> Tuple[TransitionReport, Optional[ReconcileStats]]:
        """Direct §6 transition, or the reconciler in control-plane mode.

        Reconcile stats surface only under a fault profile, so the ``none``
        profile's reports keep their exact direct-path bytes.  When the warm
        optimizer actually produced the target (``report.warm``), the edit
        distance to the running deployment is bounded, so the delta-aware
        :meth:`Controller.transition_incremental` applies O(edits) actions
        instead of exchange-and-compact's O(cluster) scans; cold solves —
        including warm-path divergence/edit-budget fallbacks — keep the full
        §6 path, so every warm-off byte is untouched."""
        if self.control_plane is None:
            if (
                self.warm_start
                and self.last_optimize_report is not None
                and self.last_optimize_report.warm
            ):
                return self.controller.transition_incremental(cluster, new_dep), None
            return self.controller.transition(cluster, new_dep), None
        if self.desired is None:
            raise RuntimeError(
                "control-plane transition without a desired state: "
                "optimize() must set the reconciler target first"
            )
        report, stats = self.control_plane.reconciler.reconcile(
            cluster, self.desired
        )
        return report, (stats if self.control_plane.fault_mode else None)

    def reconcile_divergence(
        self, cluster: SimulatedCluster, now: float
    ) -> Optional[PendingTransition]:
        """Level-triggered repair: if observed state diverged from the
        standing desired state (a device failed, a node is draining), run a
        reconcile pass toward the unchanged target.  Returns ``None`` when
        already converged."""
        if self.control_plane is None:
            raise RuntimeError(
                "reconcile_divergence() requires control_plane= mode"
            )
        if (
            self.desired is None
            or self.workload is None
            or not self.control_plane.reconciler.diverged(cluster, self.desired)
        ):
            return None
        cluster.record_instance_trace = True
        required = {s.name: s.slo.throughput for s in self.workload.services}
        pre_instances = cluster.busy_instances()
        gpus_before = cluster.gpus_in_use()
        n0 = len(cluster.instance_trace)
        na0 = len(cluster.actions_applied)
        clock0 = cluster.clock
        report, stats = self.control_plane.reconciler.reconcile(
            cluster, self.desired
        )
        if not report.actions:
            cluster.instance_trace.clear()
            return None
        pending = self._build_pending(
            now, pre_instances, cluster, n0, clock0, report,
            required, required, gpus_before,
            trigger="fault",
            stats=stats if self.control_plane.fault_mode else None,
            na0=na0,
        )
        cluster.instance_trace.clear()
        return pending

    def _build_pending(
        self,
        now: float,
        pre_instances: InstanceSet,
        cluster: SimulatedCluster,
        n0: int,
        clock0: float,
        report: TransitionReport,
        old_required: Dict[str, float],
        new_required: Dict[str, float],
        gpus_before: int,
        trigger: str = "demand",
        stats: Optional[ReconcileStats] = None,
        na0: int = 0,
    ) -> PendingTransition:
        # The cluster trace advances serially (one action at a time); real
        # wall clock is the dependency-aware parallel makespan.  Compress
        # serial offsets onto the parallel window — ordering (hence the §6
        # guarantee, which the controller enforces on the serial trace) is
        # preserved.
        serial = max(report.serial_seconds, 1e-9)
        scale = report.parallel_seconds / serial
        timeline: List[Tuple[float, InstanceSet]] = [(now, dict(pre_instances))]
        # sorted: the margin dict feeds TransitionRecord serialization, so
        # its construction must never depend on set hash order
        margin = {
            svc: float("inf")
            for svc in sorted(set(old_required) | set(new_required))
        }

        def note_margin(instances: InstanceSet) -> None:
            provided: Dict[str, float] = {}
            for svc, _size, tput in instances.values():
                provided[svc] = provided.get(svc, 0.0) + tput
            for svc in margin:
                floor = min(
                    old_required.get(svc, 0.0), new_required.get(svc, 0.0)
                )
                margin[svc] = min(margin[svc], provided.get(svc, 0.0) - floor)

        note_margin(pre_instances)
        for clock, snap in cluster.instance_trace[n0:]:
            t = now + (clock - clock0) * scale
            timeline.append((t, dict(snap)))
            note_margin(snap)

        end = now + report.parallel_seconds
        record = TransitionRecord(
            start_s=now,
            end_s=end,
            serial_seconds=report.serial_seconds,
            parallel_seconds=report.parallel_seconds,
            action_counts=dict(report.action_counts),
            old_required=dict(sorted(old_required.items())),
            new_required=dict(sorted(new_required.items())),
            gpus_before=gpus_before,
            gpus_after=report.final_gpus_busy,
            transparency_margin=dict(sorted(margin.items())),
            trigger=trigger,
            reconcile=stats.to_dict() if stats is not None else None,
        )
        if self.obs is not None:
            self._trace_transition(now, cluster, n0, na0, clock0, scale, record, stats)
        return PendingTransition(now, end, timeline, record)

    def _trace_transition(
        self,
        now: float,
        cluster: SimulatedCluster,
        n0: int,
        na0: int,
        clock0: float,
        scale: float,
        record: TransitionRecord,
        stats: Optional[ReconcileStats],
    ) -> None:
        """Emit the plan/execute spans for one transition, one span per
        applied §6 action, and the reconcile counters.  Called only when the
        simulator installed an :class:`repro.obs.Observability` on the
        driver, so the default path never reaches this."""
        tracer = self.obs.tracer
        tracer.span(
            "reoptimize",
            "plan",
            now,
            now,
            args={
                "trigger": record.trigger,
                "actions": {
                    k: v for k, v in sorted(record.action_counts.items())
                },
            },
        )
        tracer.span(
            "reoptimize",
            "execute",
            now,
            record.end_s,
            args={
                "serial_s": round(record.serial_seconds, 6),
                "parallel_s": round(record.parallel_seconds, 6),
                "gpus_before": record.gpus_before,
                "gpus_after": record.gpus_after,
            },
        )
        # each applied action's serial window, compressed by the same factor
        # as the instance-set timeline.  instance_trace entries pair 1:1 with
        # actions_applied while record_instance_trace is on (apply() appends
        # both), so the action's completion clock comes from the trace entry
        # — robust to fault hooks stretching or wasting wall clock between
        # attempts — and its start backs off by the charged seconds.
        trace_tail = cluster.instance_trace[n0:]
        actions = cluster.actions_applied[na0:]
        seconds = cluster.applied_seconds[na0:]
        for (clock, _snap), action, dur in zip(trace_tail, actions, seconds):
            t1 = now + (clock - clock0) * scale
            t0 = now + (clock - dur - clock0) * scale
            args = {"gpu": action.gpu}
            if action.service:
                args["service"] = action.service
            if action.size:
                args["size"] = action.size
            if action.kind == "migrate":
                args["dst_gpu"] = action.dst_gpu
            tracer.span("actions", action.kind, t0, t1, args=args)
        m = self.obs.metrics
        m.counter("transitions").inc(1.0)
        m.histogram("transition.parallel_s").observe(record.parallel_seconds)
        if stats is not None:
            m.counter("reconcile.iterations").inc(float(stats.iterations))
            m.counter("reconcile.retried").inc(float(stats.retried))
            m.counter("reconcile.abandoned").inc(float(stats.abandoned))
            for name in stats.faults:
                tracer.instant(
                    "reconcile", f"fault:{name}", now, args={"trigger": record.trigger}
                )
