"""Closed-loop trace-driven cluster serving simulator.

The loop the paper's headline figures (13-14) measure, in one place:

  traffic arrives (a :class:`repro.sim.traffic.Trace`)
    -> the per-service :class:`WeightedRouter` spreads requests over the
       service's MIG instances proportionally to their profiled throughput
    -> each instance serves at its profile rate; excess queues (fluid backlog)
    -> per-bin SLO-attainment accounting
    -> every ``reoptimize_every_s`` the :class:`ReoptimizeDriver` re-runs the
       optimizer pipeline on the observed load and, when demand moved,
       executes a transparent exchange-and-compact transition whose
       Figure-13c action latencies are charged to in-flight capacity.

Everything is driven by the deterministic event queue in
:mod:`repro.sim.events`, and all randomness (Poisson arrivals, serving
noise) flows from the single ``SimConfig.seed`` — the same seed yields a
byte-identical :class:`SimReport`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.controlplane.faults import FAULT_PROFILES, DeviceFault
from repro.controlplane.reconciler import ControlPlane, build_control_plane
from repro.controlplane.spec import ClusterSpec
from repro.core.cluster import SimulatedCluster
from repro.core.profiles import PerfProfile
from repro.core.rms import ReconfigRules
from repro.obs import Observability
from repro.serving.router import InstanceHandle, WeightedRouter

from repro.sim.events import (
    BIN_TICK,
    END,
    FAULT,
    RECONCILE,
    REOPTIMIZE,
    TRANSITION_DONE,
    Clock,
    EventQueue,
)
from repro.sim.reoptimize import InstanceSet, PendingTransition, ReoptimizeDriver
from repro.sim.report import (
    FaultRecord,
    ServiceTimeline,
    SimReport,
    TransitionRecord,
)
from repro.sim.servemodel import TokenKnobs, TokenServingState
from repro.sim.traffic import PRIORITY_CLASSES, PriorityMix, Trace


@dataclasses.dataclass
class SimConfig:
    """Knobs of one simulation run (all defaults paper-flavored)."""

    reoptimize_every_s: float = 1800.0  # observe->optimize cadence
    latency_slo_ms: float = 100.0  # per-request latency SLO (§8)
    # per-service latency SLO overrides (svc -> ms); unlisted services use
    # latency_slo_ms.  This is the "richer SLO policy" knob from the ROADMAP.
    latency_targets: Optional[Dict[str, float]] = None
    headroom: float = 1.1  # required = observed rate x headroom
    change_threshold: float = 0.15  # demand move that triggers a transition
    use_phase2: bool = False  # run the GA/MCTS phase (slower, fewer GPUs)
    arrivals: str = "poisson"  # "poisson" | "fluid" (exact rate x dt)
    max_picks_per_bin: int = 256  # router picks per (service, bin); arrivals
    # beyond this are dispatched in equal chunks through the same picks
    throughput_noise: float = 0.0  # serving-vs-profiling variance (Fig. 14)
    seed: int = 0
    initial_gpus: int = 1  # cluster grows on demand past this
    # control plane (repro.controlplane): route transitions through the
    # level-triggered reconciler.  With fault_profile="none" this is
    # bit-for-bit identical to the direct path (the tests pin it); a real
    # fault profile implies control_plane=True.
    control_plane: bool = False
    fault_profile: str = "none"  # a repro.controlplane FAULT_PROFILES name
    # serving model: "fluid" (per-bin rate arithmetic, the historical
    # default) or "token" (repro.sim.servemodel: discrete requests with
    # per-token clocks, paged-KV pressure, preemption, TTFT/TPOT metrics)
    serving_model: str = "fluid"
    token_knobs: Optional[TokenKnobs] = None  # None -> TokenKnobs() defaults
    # overload resilience (token mode only): when set, requests carry a
    # priority class + SLO deadline, the token model runs its resilience
    # path (priority admission, deadline drops, victim eviction, retry
    # backoff), admission control sheds lowest-class-first, and the report
    # gains the per-class priority block.  None keeps every historical code
    # path (and its goldens) byte-identical.
    priority_mix: Optional[PriorityMix] = None
    # warm-start incremental reoptimization: seed each reoptimize from the
    # incumbent deployment (rebound ConfigSpace + greedy delta repair +
    # bounded edit distance) instead of re-solving from scratch.  Off by
    # default — every historical report stays byte-identical.
    warm_start: bool = False
    # flight-recorder observability (repro.obs): sim-time span tracing, a
    # per-bin-sampled metrics registry, and (token mode) the per-request
    # flight recorder, all surfaced through SimReport.obs and the tracer's
    # Chrome trace-event export.  Off by default — every historical report
    # (and all 67 BENCH cell SHAs) stays byte-identical.
    observability: bool = False
    obs_record_limit: int = 256  # flight-recorder request cap (token mode)

    def __post_init__(self):
        # fail fast with the valid names — not a deep KeyError mid-run
        if self.arrivals not in ("poisson", "fluid"):
            raise ValueError(
                f"unknown arrivals mode {self.arrivals!r}; "
                "valid: ['fluid', 'poisson']"
            )
        if self.fault_profile not in FAULT_PROFILES:
            raise ValueError(
                f"unknown fault profile {self.fault_profile!r}; "
                f"registered profiles: {sorted(FAULT_PROFILES)}"
            )
        if self.serving_model not in ("fluid", "token"):
            raise ValueError(
                f"unknown serving model {self.serving_model!r}; "
                "valid: ['fluid', 'token']"
            )
        if self.serving_model == "token" and self.arrivals != "poisson":
            # discrete requests need integer arrivals
            raise ValueError(
                "serving_model='token' requires arrivals='poisson'"
            )
        if self.priority_mix is not None and self.serving_model != "token":
            raise ValueError(
                "priority_mix requires serving_model='token' (the fluid "
                "model has no per-request priority semantics)"
            )
        if self.obs_record_limit < 0:
            raise ValueError(
                f"obs_record_limit must be >= 0, got {self.obs_record_limit}"
            )
        if self.fault_profile != "none":
            self.control_plane = True


class ClusterSimulator:
    """Wires trace -> router -> instances -> SLO accounting -> re-optimizer."""

    def __init__(
        self,
        rules: ReconfigRules,
        profile: PerfProfile,
        trace: Trace,
        config: Optional[SimConfig] = None,
        optimizer_kwargs: Optional[Dict] = None,
    ):
        self.rules = rules
        self.profile = profile
        self.trace = trace
        self.config = config or SimConfig()
        self.driver = ReoptimizeDriver(
            rules,
            profile,
            latency_slo_ms=self.config.latency_slo_ms,
            headroom=self.config.headroom,
            change_threshold=self.config.change_threshold,
            use_phase2=self.config.use_phase2,
            seed=self.config.seed,
            optimizer_kwargs=optimizer_kwargs,
            latency_targets=self.config.latency_targets,
            warm_start=self.config.warm_start,
        )
        self.cluster = SimulatedCluster(rules, self.config.initial_gpus)
        # flight-recorder observability: null implementations when off, so
        # every instrumentation site costs one attribute check and the
        # historical report bytes cannot shift
        self.obs = (
            Observability.on(self.config.obs_record_limit)
            if self.config.observability
            else Observability.off()
        )
        if self.obs.enabled:
            self.driver.obs = self.obs
        # the control plane (None in direct mode): reconciler + fault
        # injector + degraded-mode admission control under one profile
        self.control_plane: Optional[ControlPlane] = None
        if self.config.control_plane:
            self.control_plane = build_control_plane(
                self.driver.controller,
                self.config.fault_profile,
                self.config.seed,
                trace.duration_s,
            )
            self.driver.control_plane = self.control_plane
        # serving state
        self._pending: Optional[PendingTransition] = None
        self._routers: Dict[str, Tuple[Tuple, WeightedRouter]] = {}
        self._backlog: Dict[int, float] = {}  # uid -> queued requests
        self._backlog_svc: Dict[int, str] = {}  # uid -> owning service
        self._spill: Dict[str, float] = {}  # requeued load of vanished uids
        self._noise: Dict[int, float] = {}  # uid -> serving noise factor
        self._dead_uids: set = set()  # instances lost to device failures
        self._faults: List[FaultRecord] = []  # injected device faults
        # token serving model (None in fluid mode — the fluid path is
        # untouched, so fluid reports keep their exact bytes)
        self._token: Optional[TokenServingState] = None
        if self.config.serving_model == "token":
            targets = self.config.latency_targets or {}
            default_slo = self.config.latency_slo_ms
            self._token = TokenServingState(
                trace.services,
                profile,
                lambda svc: targets.get(svc, default_slo),
                self.config.token_knobs,
                mix=self.config.priority_mix,
                recorder=self.obs.flight,  # None when observability is off
            )
            # per-service [preemptions, refusals, deadline_dropped,
            # retry_dropped] seen through the prior bin, for the per-bin
            # delta series (the last two only serialize under a mix)
            self._tok_prev: Dict[str, List[int]] = {
                svc: [0, 0, 0, 0] for svc in trace.services
            }

    @property
    def _fault_mode(self) -> bool:
        return self.control_plane is not None and self.control_plane.fault_mode

    # -- instance plumbing -------------------------------------------------------
    def _active_instances(self, t: float) -> InstanceSet:
        if self._pending is not None and t < self._pending.end_s:
            insts = self._pending.instances_at(t)
            if self._dead_uids:
                # a device failed while the transition timeline was still
                # paying latencies: its instances are gone, whatever the
                # snapshot says
                insts = {
                    u: v for u, v in insts.items() if u not in self._dead_uids
                }
            return insts
        return self.cluster.busy_instances()

    def _noise_of(self, uid: int) -> float:
        if self.config.throughput_noise <= 0:
            return 1.0
        if uid not in self._noise:
            # one seeded draw per instance lifetime, independent of when the
            # instance first serves (instance-creation order is deterministic)
            sub = np.random.default_rng((self.config.seed, uid))
            self._noise[uid] = float(
                sub.uniform(
                    1.0 - self.config.throughput_noise,
                    1.0 + self.config.throughput_noise,
                )
            )
        return self._noise[uid]

    def _router_for(
        self, svc: str, members: List[Tuple[int, int, float]]
    ) -> WeightedRouter:
        """A persistent smooth-WRR per service, rebuilt only when the
        instance set changes (so WRR state survives across bins)."""
        key = tuple(members)
        cached = self._routers.get(svc)
        if cached is not None and cached[0] == key:
            return cached[1]
        router = WeightedRouter(
            [
                InstanceHandle(instance_id=uid, size=size, throughput=tput)
                for uid, size, tput in members
            ]
        )
        self._routers[svc] = (key, router)
        return router

    # -- one traffic bin ---------------------------------------------------------
    def _process_bin(
        self,
        k: int,
        t: float,
        rng: np.random.Generator,
        out: Dict[str, Dict[str, List[float]]],
    ) -> None:
        if self._token is not None:
            self._process_bin_token(k, t, rng, out)
            return
        dt = self.trace.bin_s
        instances = self._active_instances(t)
        # queued requests of instances that vanished (deleted/migrated away
        # mid-transition) are re-dispatched at the service level this bin
        for uid in [u for u in self._backlog if u not in instances]:
            q = self._backlog.pop(uid)
            svc = self._backlog_svc.pop(uid)
            if q > 0:
                self._spill[svc] = self._spill.get(svc, 0.0) + q
        # uids never recur (itertools.count), so their noise draws are dead
        for uid in [u for u in self._noise if u not in instances]:
            del self._noise[uid]
        by_svc: Dict[str, List[Tuple[int, int, float]]] = {}
        for uid in sorted(instances):
            svc, size, tput = instances[uid]
            by_svc.setdefault(svc, []).append(
                (uid, size, tput * self._noise_of(uid))
            )
        required = {
            s.name: s.slo.throughput for s in self.driver.workload.services
        } if self.driver.workload else {}
        # degraded-mode admission control (repro.controlplane.degraded):
        # engaged only while the control plane is actually in an outage —
        # observed state diverged from desired (a device died, a node is
        # draining) or a fault-triggered repair is still paying its action
        # latencies.  Healthy-cluster bursts, before or after an outage,
        # keep the fluid-queue backlog semantics of the default mode.
        admission = (
            self.control_plane.admission
            if self.control_plane is not None
            else None
        )
        degraded = bool(
            admission is not None
            and self.driver.desired is not None
            and (
                (
                    self._pending is not None
                    and self._pending.record.trigger == "fault"
                )
                or self.control_plane.reconciler.diverged(
                    self.cluster, self.driver.desired
                )
            )
        )

        tot_backlog = 0.0  # observability gauges (cost: two adds per svc)
        tot_shed = 0.0
        for svc in self.trace.services:
            rate = float(self.trace.rates[svc][k])
            if self.config.arrivals == "poisson":
                arrivals = float(rng.poisson(rate * dt))
            else:
                arrivals = rate * dt
            # demand = this bin's true arrivals + requeued spill; only the
            # former is recorded as arrivals (spill was counted on arrival)
            demand = arrivals + self._spill.pop(svc, 0.0)
            members = by_svc.get(svc, [])
            served = 0.0
            capacity_rate = sum(m[2] for m in members)
            shed = 0.0
            req_rate_now = required.get(svc, 0.0)
            if (
                degraded
                and req_rate_now > 0
                and capacity_rate < req_rate_now * (1.0 - 1e-9)
            ):
                # this service is under-provisioned against its SLO (the
                # outage, not a stochastic burst): shed what post-failure
                # capacity cannot absorb.  Shed requests were counted as
                # arrivals and are never served, so the outage charges
                # honestly to the report
                demand, shed = admission.admit(demand, capacity_rate * dt)
            if members:
                router = self._router_for(svc, members)
                load: Dict[int, float] = {}
                if demand > 0:
                    picks = min(
                        int(math.ceil(demand)), self.config.max_picks_per_bin
                    )
                    chunk = demand / picks
                    for _ in range(picks):
                        h = router.pick()
                        load[h.instance_id] = load.get(h.instance_id, 0.0) + chunk
                for uid, _size, tput in members:
                    q = self._backlog.get(uid, 0.0) + load.get(uid, 0.0)
                    s = min(q, tput * dt)
                    self._backlog[uid] = q - s
                    self._backlog_svc[uid] = svc
                    served += s
            elif demand > 0:
                # no capacity this bin: everything queues at the service level
                self._spill[svc] = self._spill.get(svc, 0.0) + demand

            backlog = sum(
                self._backlog.get(m[0], 0.0) for m in members
            ) + self._spill.get(svc, 0.0)

            req_rate = required.get(svc, 0.0)
            series = out[svc]
            series["arrivals"].append(arrivals)
            series["served"].append(served)
            series["capacity"].append(capacity_rate * dt)
            series["backlog"].append(backlog)
            series["required"].append(req_rate * dt)
            series["attainment"].append(
                min(1.0, capacity_rate / req_rate) if req_rate > 0 else 1.0
            )
            if self._fault_mode:
                series["shed"].append(shed)
            tot_backlog += backlog
            tot_shed += shed

        if self.obs.enabled:
            m = self.obs.metrics
            m.gauge("queue.depth").set(tot_backlog)
            if self._fault_mode:
                m.counter("admission.shed").inc(tot_shed)
            m.sample(t + dt)

    def _process_bin_token(
        self,
        k: int,
        t: float,
        rng: np.random.Generator,
        out: Dict[str, Dict[str, List[float]]],
    ) -> None:
        """Token-level serving for one bin: discrete requests through the
        per-instance :class:`repro.sim.servemodel.InstanceModel`s instead of
        fluid backlog arithmetic.  capacity/required/attainment use the same
        math as the fluid path; served and backlog come from actual request
        completions and in-system counts, and two extra series (preempted,
        refused) surface the KV-pressure events the fluid model cannot see.
        """
        dt = self.trace.bin_s
        tok = self._token
        instances = self._active_instances(t)
        # uids never recur (itertools.count), so their noise draws are dead
        for uid in [u for u in self._noise if u not in instances]:
            del self._noise[uid]
        by_svc: Dict[str, List[Tuple[int, int, float]]] = {}
        for uid in sorted(instances):
            svc, size, tput = instances[uid]
            by_svc.setdefault(svc, []).append(
                (uid, size, tput * self._noise_of(uid))
            )
        # vanished instances spill their queued/in-flight requests back to
        # the service level; new instances get fresh engine twins
        tok.sync_instances(instances, self._noise_of, t)
        required = {
            s.name: s.slo.throughput for s in self.driver.workload.services
        } if self.driver.workload else {}
        admission = (
            self.control_plane.admission
            if self.control_plane is not None
            else None
        )
        degraded = bool(
            admission is not None
            and self.driver.desired is not None
            and (
                (
                    self._pending is not None
                    and self._pending.record.trigger == "fault"
                )
                or self.control_plane.reconciler.diverged(
                    self.cluster, self.driver.desired
                )
            )
        )

        # dispatch pass: draw this bin's discrete arrivals and route them
        # through the same persistent smooth-WRR the fluid path uses
        arrived: Dict[str, int] = {}
        shed_by_svc: Dict[str, float] = {}
        for svc in self.trace.services:
            rate = float(self.trace.rates[svc][k])
            n = int(rng.poisson(rate * dt))
            arrived[svc] = n
            members = by_svc.get(svc, [])
            capacity_rate = sum(m[2] for m in members)
            shed = 0.0
            req_rate_now = required.get(svc, 0.0)
            under_capacity = bool(
                degraded
                and req_rate_now > 0
                and capacity_rate < req_rate_now * (1.0 - 1e-9)
            )
            if tok.mix is not None:
                # resilience path: draw ALL arrivals first (each with its
                # class + deadline), then shed lowest-class-first through
                # the priority-aware admission controller, keeping the
                # earliest arrivals within the marginal class
                reqs = [
                    tok.make_request(svc, t + (i + 0.5) * dt / n, rng)
                    for i in range(n)
                ]
                if under_capacity:
                    counts = [0] * len(PRIORITY_CLASSES)
                    for r in reqs:
                        counts[r.priority] += 1
                    plan = admission.admit_by_class(
                        [
                            (c, 1.0, float(counts[c]))
                            for c in range(len(counts))
                        ],
                        capacity_rate * dt,
                    )
                    quota = [int(adm) for adm, _ in plan]
                    kept = []
                    used = [0] * len(PRIORITY_CLASSES)
                    for r in reqs:
                        if used[r.priority] < quota[r.priority]:
                            used[r.priority] += 1
                            kept.append(r)
                        else:
                            tok.record_shed(r)
                    shed = float(len(reqs) - len(kept))
                    reqs = kept
            else:
                n_admit = n
                if under_capacity:
                    kept, _ = admission.admit(float(n), capacity_rate * dt)
                    n_admit = int(kept)
                    shed = float(n - n_admit)
                # deterministic arrival offsets spread through the bin
                reqs = [
                    tok.make_request(svc, t + (i + 0.5) * dt / n_admit, rng)
                    for i in range(n_admit)
                ]
            shed_by_svc[svc] = shed
            if members:
                router = self._router_for(svc, members)
                tok.dispatch(
                    svc,
                    [m[0] for m in members],
                    lambda r=router: r.pick().instance_id,
                    reqs,
                )
            else:
                tok.dispatch(svc, [], lambda: 0, reqs)

        # serving pass: advance every instance's clock to the bin edge
        tok.serve_bin(t + dt)

        # accounting pass; the last bin's window is open-ended so step
        # overrun past the trace end still counts its completions
        t1 = float("inf") if k == self.trace.num_bins - 1 else t + dt
        tot_completed = 0.0
        for svc in self.trace.services:
            members = by_svc.get(svc, [])
            capacity_rate = sum(m[2] for m in members)
            req_rate = required.get(svc, 0.0)
            prev = self._tok_prev[svc]
            pre = tok.metrics.preemptions[svc]
            ref = tok.metrics.refusals[svc]
            dd = tok.metrics.deadline_dropped[svc]
            rd = tok.metrics.retry_dropped[svc]
            done = float(tok.completed_in(svc, t, t1))
            tot_completed += done
            series = out[svc]
            series["arrivals"].append(float(arrived[svc]))
            series["served"].append(done)
            series["capacity"].append(capacity_rate * dt)
            series["backlog"].append(float(tok.in_system(svc)))
            series["required"].append(req_rate * dt)
            series["attainment"].append(
                min(1.0, capacity_rate / req_rate) if req_rate > 0 else 1.0
            )
            series["preempted"].append(float(pre - prev[0]))
            series["refused"].append(float(ref - prev[1]))
            if tok.mix is not None:
                series["deadline_dropped"].append(float(dd - prev[2]))
                series["retry_dropped"].append(float(rd - prev[3]))
            self._tok_prev[svc] = [pre, ref, dd, rd]
            if self._fault_mode:
                series["shed"].append(shed_by_svc[svc])

        if self.obs.enabled:
            m = self.obs.metrics
            tm = tok.metrics
            used = total_pages = backoff_n = 0
            depth = [0] * len(PRIORITY_CLASSES)
            for inst in tok.instances.values():
                used += inst.pool.num_pages - inst.pool.free_pages
                total_pages += inst.pool.num_pages
                backoff_n += len(inst.backoff)
                for cls, q in enumerate(inst.queues):
                    depth[cls] += len(q)
            spilled = sum(len(v) for v in tok.spill.values())
            m.gauge("pages.used").set(float(used))
            m.gauge("pages.total").set(float(total_pages))
            m.gauge("queue.depth").set(float(sum(depth) + spilled))
            if tok.mix is not None:
                for cls, name in enumerate(PRIORITY_CLASSES):
                    m.gauge(f"queue.depth.{name}").set(float(depth[cls]))
                m.gauge("backoff.heap").set(float(backoff_n))
                m.counter("serving.deadline_dropped").inc_to(
                    float(sum(tm.deadline_dropped.values()))
                )
                m.counter("serving.retry_dropped").inc_to(
                    float(sum(tm.retry_dropped.values()))
                )
                m.counter("serving.retries").inc_to(
                    float(sum(tm.class_retries))
                )
            # counters advance to the model's running totals, so per-bin
            # deltas fall out of the sampled series without shadow state
            m.counter("serving.preemptions").inc_to(
                float(sum(tm.preemptions.values()))
            )
            m.counter("serving.refusals").inc_to(
                float(sum(tm.refusals.values()))
            )
            m.counter("serving.completed").inc_to(
                float(sum(len(v) for v in tm.completed_at.values()))
            )
            if self._fault_mode:
                m.counter("admission.shed").inc(sum(shed_by_svc.values()))
            m.sample(t + dt)
            self.obs.tracer.span(
                "serving",
                f"bin{k}",
                t,
                t + dt,
                args={
                    "arrivals": int(sum(arrived.values())),
                    "completed": int(tot_completed),
                },
            )

    # -- main loop ---------------------------------------------------------------
    def run(self) -> SimReport:
        cfg = self.config
        trace = self.trace
        rng = np.random.default_rng(cfg.seed)
        clock = Clock(0.0)
        queue = EventQueue()
        for k in range(trace.num_bins):
            queue.push(k * trace.bin_s, BIN_TICK, k)
        t = cfg.reoptimize_every_s
        while t < trace.duration_s - 1e-9:
            queue.push(t, REOPTIMIZE, None)
            t += cfg.reoptimize_every_s
        queue.push(trace.duration_s, END, None)
        # injected device faults fire as first-class events
        if self._fault_mode and self.control_plane.injector is not None:
            for fault in self.control_plane.injector.device_faults():
                if fault.time_s < trace.duration_s - 1e-9:
                    queue.push(fault.time_s, FAULT, fault)

        # initial deployment sized for the trace's opening rates
        self.driver.initial_deploy(self.cluster, trace.rates_at(0.0))

        series_names = (
            "arrivals", "served", "capacity",
            "backlog", "required", "attainment",
        ) + (("shed",) if self._fault_mode else ()) + (
            ("preempted", "refused") if self._token is not None else ()
        ) + (
            ("deadline_dropped", "retry_dropped")
            if self._token is not None and self._token.mix is not None
            else ()
        )
        out: Dict[str, Dict[str, List[float]]] = {
            svc: {name: [] for name in series_names}
            for svc in trace.services
        }
        transitions: List[TransitionRecord] = []
        checks = 0

        for ev in queue.drain():
            clock.advance_to(ev.time)
            if ev.kind == BIN_TICK:
                self._process_bin(ev.payload, ev.time, rng, out)
            elif ev.kind == REOPTIMIZE:
                checks += 1
                if self._pending is not None and ev.time < self._pending.end_s:
                    continue  # a transition is still paying its latencies
                observed = trace.mean_rates(
                    ev.time - cfg.reoptimize_every_s, ev.time
                )
                if self.obs.enabled:
                    # the observe leg of observe->optimize->plan->execute:
                    # zero-duration (rates are read instantaneously in sim
                    # time), carrying the windowed per-service rates
                    self.obs.tracer.span(
                        "reoptimize",
                        "observe",
                        ev.time,
                        ev.time,
                        args={
                            "window_s": cfg.reoptimize_every_s,
                            "rates": {
                                s: round(float(r), 6)
                                for s, r in sorted(observed.items())
                            },
                        },
                    )
                pending = self.driver.reoptimize(self.cluster, observed, ev.time)
                if pending is not None:
                    self._pending = pending
                    transitions.append(pending.record)
                    queue.push(pending.end_s, TRANSITION_DONE, None)
            elif ev.kind == TRANSITION_DONE:
                if self._pending is not None and ev.time >= self._pending.end_s:
                    self._pending = None
                    self._routers.clear()
            elif ev.kind == FAULT:
                rec = self._apply_device_fault(ev.payload, ev.time)
                if rec is not None:
                    self._faults.append(rec)
                    if self.obs.enabled:
                        self.obs.tracer.instant(
                            "faults",
                            f"inject:{rec.kind}",
                            ev.time,
                            args={
                                "target": rec.target,
                                "fault_domain": rec.fault_domain,
                                "killed_instances": rec.killed_instances,
                            },
                        )
                        self.obs.metrics.counter("faults.injected").inc(1.0)
                    if rec.kind != "instance_crash":
                        self._routers.clear()
                        # the control plane notices after its detection delay
                        delay = self.control_plane.profile.detection_delay_s
                        if self.obs.enabled:
                            # the inject->detect arc: the window where the
                            # cluster is degraded but the plane is blind
                            self.obs.tracer.span(
                                "faults",
                                f"detect:{rec.kind}",
                                ev.time,
                                ev.time + delay,
                                args={"target": rec.target},
                            )
                        queue.push(ev.time + delay, RECONCILE, None)
                    # an instance crash restarts in place: the device is
                    # healthy and the instance set unchanged, so there is
                    # nothing for the reconciler to repair — the cost is
                    # the spilled in-flight work, not a capacity hole
            elif ev.kind == RECONCILE:
                if self._pending is not None and ev.time < self._pending.end_s - 1e-9:
                    # let the in-flight transition settle, then look again
                    queue.push(self._pending.end_s, RECONCILE, None)
                    continue
                pending = self.driver.reconcile_divergence(self.cluster, ev.time)
                if pending is not None:
                    self._pending = pending
                    transitions.append(pending.record)
                    queue.push(pending.end_s, TRANSITION_DONE, None)
                    if self.obs.enabled:
                        # the detect->recover arc closes when the repair
                        # transition finishes paying its action latencies
                        self.obs.tracer.span(
                            "faults",
                            "recover",
                            ev.time,
                            pending.end_s,
                            args={
                                "actions": sum(
                                    pending.record.action_counts.values()
                                ),
                                "gpus_after": pending.record.gpus_after,
                            },
                        )
            elif ev.kind == END:
                break

        times = np.arange(trace.num_bins, dtype=np.float64) * trace.bin_s
        timelines = {
            svc: ServiceTimeline(
                arrivals=np.asarray(series["arrivals"]),
                served=np.asarray(series["served"]),
                capacity=np.asarray(series["capacity"]),
                backlog=np.asarray(series["backlog"]),
                required=np.asarray(series["required"]),
                attainment=np.asarray(series["attainment"]),
                shed=(
                    np.asarray(series["shed"]) if "shed" in series else None
                ),
                preempted=(
                    np.asarray(series["preempted"])
                    if "preempted" in series
                    else None
                ),
                refused=(
                    np.asarray(series["refused"])
                    if "refused" in series
                    else None
                ),
                deadline_dropped=(
                    np.asarray(series["deadline_dropped"])
                    if "deadline_dropped" in series
                    else None
                ),
                retry_dropped=(
                    np.asarray(series["retry_dropped"])
                    if "retry_dropped" in series
                    else None
                ),
            )
            for svc, series in out.items()
        }
        obs_block: Optional[Dict] = None
        if self.obs.enabled:
            self.obs.tracer.assert_well_formed()
            obs_block = {
                "metrics": self.obs.metrics.snapshot(),
                "spans": self.obs.tracer.span_summary(),
            }
            if self.obs.flight is not None and self._token is not None:
                obs_block["flight"] = self.obs.flight.snapshot()
        return SimReport(
            seed=cfg.seed,
            bin_s=trace.bin_s,
            times=times,
            services=trace.services,
            timelines=timelines,
            transitions=transitions,
            reoptimize_checks=checks,
            final_gpus=self.cluster.gpus_in_use(),
            faults=self._faults,
            serving_model=cfg.serving_model,
            latency=(
                self._token.latency_summary()
                if self._token is not None
                else None
            ),
            priority=(
                self._token.priority_summary()
                if self._token is not None and self._token.mix is not None
                else None
            ),
            obs=obs_block,
        )

    # -- device faults -----------------------------------------------------------
    def _apply_device_fault(
        self, fault: DeviceFault, now: float
    ) -> Optional[FaultRecord]:
        """Fire one scheduled device fault; target picked deterministically
        (seeded injector RNG over sorted candidates).  Returns ``None`` when
        no eligible target exists (nothing busy to break)."""
        cluster = self.cluster
        injector = self.control_plane.injector
        if injector is None:
            raise RuntimeError(
                "device fault fired without a fault injector — scheduled "
                "faults require control_plane.injector to be configured"
            )
        spec = ClusterSpec.from_cluster(cluster)
        if fault.kind == "gpu_failure":
            busy = [
                gid for gid, g in cluster.gpus.items()
                if g.busy() and gid not in cluster.failed
            ]
            gid = injector.pick_gpu(busy)
            if gid is None:
                return None
            machine = cluster.gpus[gid].machine
            lost: Dict[str, float] = {}
            for r in cluster.gpus[gid].instances.values():
                if r.service:
                    lost[r.service] = lost.get(r.service, 0.0) + r.throughput
            killed = cluster.fail_gpu(gid)
            # kill every uid that ever lived on this device, not just the
            # live ones: an in-flight transition timeline may still replay
            # snapshots holding instances the plan deletes later, and those
            # must not keep serving from dead hardware
            self._dead_uids.update(
                u for u, g in cluster.uid_gpu.items() if g == gid
            )
            return FaultRecord(
                time_s=now,
                kind="gpu_failure",
                target=gid,
                fault_domain=spec.fault_domain_of(machine),
                killed_instances=len(killed),
                lost_throughput=lost,
            )
        if fault.kind == "instance_crash":
            # serving-path fault: one instance's process dies mid-decode.
            # The device stays healthy and the instance restarts in place
            # with cold state, so no repair transition fires — the damage is
            # the spilled in-flight work (KV lost in token mode, backlog
            # respilled in fluid mode)
            if self._token is not None:
                tok = self._token
                busy = [
                    u
                    for u, inst in tok.instances.items()
                    if inst.in_system > 0
                ]
                uid = injector.pick_instance(busy or sorted(tok.instances))
                if uid is None:
                    return None
                svc = tok.instances[uid].service
                spilled = float(tok.crash_instance(uid, now))
            else:
                busy = [u for u, q in self._backlog.items() if q > 0]
                uid = injector.pick_instance(busy)
                if uid is None:
                    return None
                spilled = float(self._backlog.pop(uid, 0.0))
                svc = self._backlog_svc.pop(uid, "")
                if svc and spilled > 0:
                    self._spill[svc] = self._spill.get(svc, 0.0) + spilled
            gid = cluster.uid_gpu.get(uid)
            domain = (
                spec.fault_domain_of(cluster.gpus[gid].machine)
                if gid is not None and gid in cluster.gpus
                else "unknown"
            )
            return FaultRecord(
                time_s=now,
                kind="instance_crash",
                target=uid,
                fault_domain=domain,
                killed_instances=0,
                lost_throughput={},
                spilled=spilled,
            )
        if fault.kind == "node_drain":
            machines = sorted(
                {
                    g.machine
                    for gid, g in cluster.gpus.items()
                    if g.busy() and gid not in cluster.failed
                }
            )
            machine = injector.pick_machine(machines)
            if machine is None:
                return None
            cluster.drain_machine(machine)
            # a drain kills nothing — its instances keep serving until the
            # reconciler migrates them off the cordoned machine
            return FaultRecord(
                time_s=now,
                kind="node_drain",
                target=machine,
                fault_domain=spec.fault_domain_of(machine),
                killed_instances=0,
                lost_throughput={},
            )
        raise ValueError(fault.kind)
