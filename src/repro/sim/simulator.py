"""Closed-loop trace-driven cluster serving simulator.

The loop the paper's headline figures (13-14) measure, in one place:

  traffic arrives (a :class:`repro.sim.traffic.Trace`)
    -> the per-service :class:`WeightedRouter` spreads requests over the
       service's MIG instances proportionally to their profiled throughput
    -> each instance serves at its profile rate; excess queues (fluid backlog)
    -> per-bin SLO-attainment accounting
    -> every ``reoptimize_every_s`` the :class:`ReoptimizeDriver` re-runs the
       optimizer pipeline on the observed load and, when demand moved,
       executes a transparent exchange-and-compact transition whose
       Figure-13c action latencies are charged to in-flight capacity.

Everything is driven by the deterministic event queue in
:mod:`repro.sim.events`, and all randomness (Poisson arrivals, serving
noise) flows from the single ``SimConfig.seed`` — the same seed yields a
byte-identical :class:`SimReport`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import SimulatedCluster
from repro.core.profiles import PerfProfile
from repro.core.rms import ReconfigRules
from repro.serving.router import InstanceHandle, WeightedRouter

from repro.sim.events import (
    BIN_TICK,
    END,
    REOPTIMIZE,
    TRANSITION_DONE,
    Clock,
    EventQueue,
)
from repro.sim.reoptimize import InstanceSet, PendingTransition, ReoptimizeDriver
from repro.sim.report import ServiceTimeline, SimReport, TransitionRecord
from repro.sim.traffic import Trace


@dataclasses.dataclass
class SimConfig:
    """Knobs of one simulation run (all defaults paper-flavored)."""

    reoptimize_every_s: float = 1800.0  # observe->optimize cadence
    latency_slo_ms: float = 100.0  # per-request latency SLO (§8)
    # per-service latency SLO overrides (svc -> ms); unlisted services use
    # latency_slo_ms.  This is the "richer SLO policy" knob from the ROADMAP.
    latency_targets: Optional[Dict[str, float]] = None
    headroom: float = 1.1  # required = observed rate x headroom
    change_threshold: float = 0.15  # demand move that triggers a transition
    use_phase2: bool = False  # run the GA/MCTS phase (slower, fewer GPUs)
    arrivals: str = "poisson"  # "poisson" | "fluid" (exact rate x dt)
    max_picks_per_bin: int = 256  # router picks per (service, bin); arrivals
    # beyond this are dispatched in equal chunks through the same picks
    throughput_noise: float = 0.0  # serving-vs-profiling variance (Fig. 14)
    seed: int = 0
    initial_gpus: int = 1  # cluster grows on demand past this

    def __post_init__(self):
        assert self.arrivals in ("poisson", "fluid"), self.arrivals


class ClusterSimulator:
    """Wires trace -> router -> instances -> SLO accounting -> re-optimizer."""

    def __init__(
        self,
        rules: ReconfigRules,
        profile: PerfProfile,
        trace: Trace,
        config: Optional[SimConfig] = None,
        optimizer_kwargs: Optional[Dict] = None,
    ):
        self.rules = rules
        self.profile = profile
        self.trace = trace
        self.config = config or SimConfig()
        self.driver = ReoptimizeDriver(
            rules,
            profile,
            latency_slo_ms=self.config.latency_slo_ms,
            headroom=self.config.headroom,
            change_threshold=self.config.change_threshold,
            use_phase2=self.config.use_phase2,
            seed=self.config.seed,
            optimizer_kwargs=optimizer_kwargs,
            latency_targets=self.config.latency_targets,
        )
        self.cluster = SimulatedCluster(rules, self.config.initial_gpus)
        # serving state
        self._pending: Optional[PendingTransition] = None
        self._routers: Dict[str, Tuple[Tuple, WeightedRouter]] = {}
        self._backlog: Dict[int, float] = {}  # uid -> queued requests
        self._backlog_svc: Dict[int, str] = {}  # uid -> owning service
        self._spill: Dict[str, float] = {}  # requeued load of vanished uids
        self._noise: Dict[int, float] = {}  # uid -> serving noise factor

    # -- instance plumbing -------------------------------------------------------
    def _active_instances(self, t: float) -> InstanceSet:
        if self._pending is not None and t < self._pending.end_s:
            return self._pending.instances_at(t)
        return self.cluster.busy_instances()

    def _noise_of(self, uid: int) -> float:
        if self.config.throughput_noise <= 0:
            return 1.0
        if uid not in self._noise:
            # one seeded draw per instance lifetime, independent of when the
            # instance first serves (instance-creation order is deterministic)
            sub = np.random.default_rng((self.config.seed, uid))
            self._noise[uid] = float(
                sub.uniform(
                    1.0 - self.config.throughput_noise,
                    1.0 + self.config.throughput_noise,
                )
            )
        return self._noise[uid]

    def _router_for(
        self, svc: str, members: List[Tuple[int, int, float]]
    ) -> WeightedRouter:
        """A persistent smooth-WRR per service, rebuilt only when the
        instance set changes (so WRR state survives across bins)."""
        key = tuple(members)
        cached = self._routers.get(svc)
        if cached is not None and cached[0] == key:
            return cached[1]
        router = WeightedRouter(
            [
                InstanceHandle(instance_id=uid, size=size, throughput=tput)
                for uid, size, tput in members
            ]
        )
        self._routers[svc] = (key, router)
        return router

    # -- one traffic bin ---------------------------------------------------------
    def _process_bin(
        self,
        k: int,
        t: float,
        rng: np.random.Generator,
        out: Dict[str, Dict[str, List[float]]],
    ) -> None:
        dt = self.trace.bin_s
        instances = self._active_instances(t)
        # queued requests of instances that vanished (deleted/migrated away
        # mid-transition) are re-dispatched at the service level this bin
        for uid in [u for u in self._backlog if u not in instances]:
            q = self._backlog.pop(uid)
            svc = self._backlog_svc.pop(uid)
            if q > 0:
                self._spill[svc] = self._spill.get(svc, 0.0) + q
        # uids never recur (itertools.count), so their noise draws are dead
        for uid in [u for u in self._noise if u not in instances]:
            del self._noise[uid]
        by_svc: Dict[str, List[Tuple[int, int, float]]] = {}
        for uid in sorted(instances):
            svc, size, tput = instances[uid]
            by_svc.setdefault(svc, []).append(
                (uid, size, tput * self._noise_of(uid))
            )
        required = {
            s.name: s.slo.throughput for s in self.driver.workload.services
        } if self.driver.workload else {}

        for svc in self.trace.services:
            rate = float(self.trace.rates[svc][k])
            if self.config.arrivals == "poisson":
                arrivals = float(rng.poisson(rate * dt))
            else:
                arrivals = rate * dt
            # demand = this bin's true arrivals + requeued spill; only the
            # former is recorded as arrivals (spill was counted on arrival)
            demand = arrivals + self._spill.pop(svc, 0.0)
            members = by_svc.get(svc, [])
            served = 0.0
            capacity_rate = sum(m[2] for m in members)
            if members:
                router = self._router_for(svc, members)
                load: Dict[int, float] = {}
                if demand > 0:
                    picks = min(
                        int(math.ceil(demand)), self.config.max_picks_per_bin
                    )
                    chunk = demand / picks
                    for _ in range(picks):
                        h = router.pick()
                        load[h.instance_id] = load.get(h.instance_id, 0.0) + chunk
                for uid, _size, tput in members:
                    q = self._backlog.get(uid, 0.0) + load.get(uid, 0.0)
                    s = min(q, tput * dt)
                    self._backlog[uid] = q - s
                    self._backlog_svc[uid] = svc
                    served += s
            elif demand > 0:
                # no capacity this bin: everything queues at the service level
                self._spill[svc] = self._spill.get(svc, 0.0) + demand

            backlog = sum(
                self._backlog.get(m[0], 0.0) for m in members
            ) + self._spill.get(svc, 0.0)

            req_rate = required.get(svc, 0.0)
            series = out[svc]
            series["arrivals"].append(arrivals)
            series["served"].append(served)
            series["capacity"].append(capacity_rate * dt)
            series["backlog"].append(backlog)
            series["required"].append(req_rate * dt)
            series["attainment"].append(
                min(1.0, capacity_rate / req_rate) if req_rate > 0 else 1.0
            )

    # -- main loop ---------------------------------------------------------------
    def run(self) -> SimReport:
        cfg = self.config
        trace = self.trace
        rng = np.random.default_rng(cfg.seed)
        clock = Clock(0.0)
        queue = EventQueue()
        for k in range(trace.num_bins):
            queue.push(k * trace.bin_s, BIN_TICK, k)
        t = cfg.reoptimize_every_s
        while t < trace.duration_s - 1e-9:
            queue.push(t, REOPTIMIZE, None)
            t += cfg.reoptimize_every_s
        queue.push(trace.duration_s, END, None)

        # initial deployment sized for the trace's opening rates
        self.driver.initial_deploy(self.cluster, trace.rates_at(0.0))

        out: Dict[str, Dict[str, List[float]]] = {
            svc: {
                name: []
                for name in (
                    "arrivals", "served", "capacity",
                    "backlog", "required", "attainment",
                )
            }
            for svc in trace.services
        }
        transitions: List[TransitionRecord] = []
        checks = 0

        for ev in queue.drain():
            clock.advance_to(ev.time)
            if ev.kind == BIN_TICK:
                self._process_bin(ev.payload, ev.time, rng, out)
            elif ev.kind == REOPTIMIZE:
                checks += 1
                if self._pending is not None and ev.time < self._pending.end_s:
                    continue  # a transition is still paying its latencies
                observed = trace.mean_rates(
                    ev.time - cfg.reoptimize_every_s, ev.time
                )
                pending = self.driver.reoptimize(self.cluster, observed, ev.time)
                if pending is not None:
                    self._pending = pending
                    transitions.append(pending.record)
                    queue.push(pending.end_s, TRANSITION_DONE, None)
            elif ev.kind == TRANSITION_DONE:
                if self._pending is not None and ev.time >= self._pending.end_s:
                    self._pending = None
                    self._routers.clear()
            elif ev.kind == END:
                break

        times = np.arange(trace.num_bins, dtype=np.float64) * trace.bin_s
        timelines = {
            svc: ServiceTimeline(
                arrivals=np.asarray(series["arrivals"]),
                served=np.asarray(series["served"]),
                capacity=np.asarray(series["capacity"]),
                backlog=np.asarray(series["backlog"]),
                required=np.asarray(series["required"]),
                attainment=np.asarray(series["attainment"]),
            )
            for svc, series in out.items()
        }
        return SimReport(
            seed=cfg.seed,
            bin_s=trace.bin_s,
            times=times,
            services=trace.services,
            timelines=timelines,
            transitions=transitions,
            reoptimize_checks=checks,
            final_gpus=self.cluster.gpus_in_use(),
        )
