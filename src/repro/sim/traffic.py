"""Traffic traces: per-service arrival rates over time.

The paper's live experiments (§8.2, Figures 13-14) replay real day/night
traffic against the serving cluster; the related MIG-scheduling literature
(arXiv:2606.25082, arXiv:2512.16099) evaluates against time-varying arrival
traces more generally.  This module is the trace vocabulary for the
closed-loop simulator (:mod:`repro.sim.simulator`): a :class:`Trace` is a
binned per-service arrival-rate function, and the generators below produce
the canonical shapes —

  * :func:`diurnal_trace`          — smooth day/night cycle (Figure 13's scenario)
  * :func:`poisson_burst_trace`    — background rate with seeded burst episodes
  * :func:`flash_crowd_trace`      — a sudden flash crowd with ramp up/decay
  * :func:`correlated_surge_trace` — surges hitting *all* services at once
  * :func:`replay_trace`           — replay externally recorded rate arrays

All randomness flows from explicit seeds so a trace (and every simulation
run on it) is reproducible bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

# request importance classes, highest first.  The index IS the priority
# (0 = most important): the token serving model admits lower indices first,
# prefers higher indices as preemption victims, and degraded-mode admission
# control sheds higher indices first.
PRIORITY_CLASSES: Tuple[str, ...] = ("critical", "standard", "batch")
STANDARD_CLASS: int = PRIORITY_CLASSES.index("standard")


@dataclasses.dataclass(frozen=True)
class PriorityMix:
    """How requests acquire a priority class and an SLO deadline.

    Every request drawn under a mix gets a class (index into
    :data:`PRIORITY_CLASSES`) and an *absolute* deadline
    ``arrival + deadline_s[class]`` — the SLO the request is worth serving
    against; a request still queued past its deadline is dropped (goodput,
    not throughput).  Classes are assigned either per-service
    (``per_service`` pins a service's every request to one class, consuming
    no randomness) or by a seeded per-request draw over ``weights``.  All
    draws flow from the simulator's single rng, so a mix keeps the
    byte-identical-report contract.
    """

    # per-class draw weights (critical, standard, batch); normalized
    weights: Tuple[float, ...] = (0.2, 0.6, 0.2)
    # per-class relative SLO deadline in seconds; inf = deadline-less
    deadline_s: Tuple[float, ...] = (3.0, 12.0, math.inf)
    # svc -> class name: pin a whole service to one class (no rng draw)
    per_service: Optional[Mapping[str, str]] = None

    def __post_init__(self):
        # fail fast with actionable messages, not a mid-run IndexError
        n = len(PRIORITY_CLASSES)
        if len(self.weights) != n or len(self.deadline_s) != n:
            raise ValueError(
                f"weights and deadline_s need one entry per class "
                f"{PRIORITY_CLASSES}, got {len(self.weights)} and "
                f"{len(self.deadline_s)}"
            )
        if any(w < 0.0 for w in self.weights) or sum(self.weights) <= 0.0:
            raise ValueError(
                f"weights must be non-negative with a positive sum, "
                f"got {self.weights}"
            )
        if any(d <= 0.0 for d in self.deadline_s):
            raise ValueError(
                f"deadlines must be positive (inf = deadline-less), "
                f"got {self.deadline_s}"
            )
        for svc, name in (self.per_service or {}).items():
            if name not in PRIORITY_CLASSES:
                raise ValueError(
                    f"per_service[{svc!r}] = {name!r} is not a priority "
                    f"class; valid: {list(PRIORITY_CLASSES)}"
                )

    def class_of(self, svc: str, rng: np.random.Generator) -> int:
        """The class index of one request of ``svc``.  Pinned services
        consume no randomness; everything else is one seeded draw."""
        if self.per_service:
            pinned = self.per_service.get(svc)
            if pinned is not None:
                return PRIORITY_CLASSES.index(pinned)
        total = float(sum(self.weights))
        u = float(rng.random()) * total
        acc = 0.0
        for c, w in enumerate(self.weights):
            acc += w
            if u < acc:
                return c
        return len(PRIORITY_CLASSES) - 1


@dataclasses.dataclass(frozen=True)
class Trace:
    """Per-service arrival rates (req/s), piecewise-constant over fixed bins.

    ``rates[svc][k]`` is the arrival rate of ``svc`` during
    ``[k * bin_s, (k+1) * bin_s)``.
    """

    bin_s: float
    rates: Dict[str, np.ndarray]

    def __post_init__(self):
        # input validation as real exceptions, not asserts: these must fire
        # even under ``python -O``, where asserts are compiled away
        if self.bin_s <= 0:
            raise ValueError(f"bin width must be positive, got {self.bin_s}")
        if not self.rates:
            raise ValueError("trace needs at least one service")
        n = {len(r) for r in self.rates.values()}
        if len(n) != 1:
            raise ValueError(
                f"all services must cover the same bins, got lengths {sorted(n)}"
            )

    @property
    def services(self) -> list:
        return sorted(self.rates)

    @property
    def num_bins(self) -> int:
        return len(next(iter(self.rates.values())))

    @property
    def duration_s(self) -> float:
        return self.num_bins * self.bin_s

    def bin_of(self, t: float) -> int:
        """Bin index of time ``t``, clamped to the trace's ends."""
        return max(0, min(int(t // self.bin_s), self.num_bins - 1))

    def rate_at(self, svc: str, t: float) -> float:
        return float(self.rates[svc][self.bin_of(t)])

    def rates_at(self, t: float) -> Dict[str, float]:
        k = self.bin_of(t)
        return {svc: float(r[k]) for svc, r in self.rates.items()}

    def mean_rates(self, t0: float, t1: float) -> Dict[str, float]:
        """Mean per-service rate over the window [t0, t1) — what a
        re-optimizer observes from its metrics backend.

        The mean is time-weighted: a bin only partially covered by the
        window contributes in proportion to the overlap, so a window that is
        not a bin multiple no longer over-weights its edge bins (the bias
        the reoptimizer would otherwise observe whenever
        ``reoptimize_every_s`` is not a multiple of ``bin_s``).  Bin-aligned
        windows take the unweighted path, bit-identical to the historical
        behavior (existing sim goldens depend on those exact bytes)."""
        k0, k1 = self.bin_of(t0), self.bin_of(max(t1 - 1e-9, t0))
        edges = np.arange(k0, k1 + 2, dtype=np.float64) * self.bin_s
        w = np.clip(
            np.minimum(edges[1:], t1) - np.maximum(edges[:-1], t0), 0.0, None
        )
        total = float(w.sum())
        if total <= 0.0 or np.all(w == self.bin_s):
            return {
                svc: float(np.mean(r[k0 : k1 + 1]))
                for svc, r in self.rates.items()
            }
        return {
            svc: float(np.sum(r[k0 : k1 + 1] * w) / total)
            for svc, r in self.rates.items()
        }


def _bins(duration_s: float, bin_s: float) -> int:
    n = int(round(duration_s / bin_s))
    if n < 1:
        raise ValueError(
            f"trace must span at least one bin "
            f"(duration_s={duration_s}, bin_s={bin_s})"
        )
    return n


def diurnal_trace(
    peak_rates: Mapping[str, float],
    duration_s: float,
    bin_s: float = 60.0,
    night_frac: float = 0.3,
    phase_s: float = 0.0,
    period_s: Optional[float] = None,
    jitter: float = 0.0,
    seed: int = 0,
) -> Trace:
    """Day/night cycle: a raised cosine between ``night_frac * peak`` at the
    trough and ``peak`` at midday, with optional multiplicative jitter."""
    if not 0.0 <= night_frac <= 1.0:
        raise ValueError(f"night_frac must be in [0, 1], got {night_frac}")
    n = _bins(duration_s, bin_s)
    period = period_s if period_s is not None else duration_s
    t = (np.arange(n) + 0.5) * bin_s + phase_s
    # cos phase 0 at midday; shift so the trace starts at midday
    wave = 0.5 * (1.0 + np.cos(2.0 * np.pi * t / period))
    shape = night_frac + (1.0 - night_frac) * wave
    rng = np.random.default_rng(seed)
    rates = {}
    for svc in sorted(peak_rates):
        noise = rng.normal(1.0, jitter, size=n) if jitter > 0 else 1.0
        rates[svc] = np.maximum(peak_rates[svc] * shape * noise, 0.0)
    return Trace(bin_s, rates)


def poisson_burst_trace(
    base_rates: Mapping[str, float],
    duration_s: float,
    bin_s: float = 60.0,
    burst_mult: float = 3.0,
    burst_prob: float = 0.05,
    burst_len_bins: int = 3,
    seed: int = 0,
) -> Trace:
    """Background rate with seeded burst episodes: each bin opens a burst
    with probability ``burst_prob``; a burst multiplies the rate by
    ``burst_mult`` for ``burst_len_bins`` bins (bursts may overlap-extend)."""
    n = _bins(duration_s, bin_s)
    rng = np.random.default_rng(seed)
    rates = {}
    for svc in sorted(base_rates):
        mult = np.ones(n)
        starts = np.nonzero(rng.random(n) < burst_prob)[0]
        for s in starts:
            mult[s : s + burst_len_bins] = burst_mult
        rates[svc] = base_rates[svc] * mult
    return Trace(bin_s, rates)


def flash_crowd_trace(
    base_rates: Mapping[str, float],
    duration_s: float,
    at_s: float,
    bin_s: float = 60.0,
    mult: float = 5.0,
    ramp_s: float = 120.0,
    decay_s: float = 600.0,
) -> Trace:
    """A flash crowd arriving at ``at_s``: linear ramp to ``mult`` times the
    base over ``ramp_s``, then exponential decay back with scale ``decay_s``."""
    n = _bins(duration_s, bin_s)
    t = (np.arange(n) + 0.5) * bin_s
    shape = np.ones(n)
    ramping = (t >= at_s) & (t < at_s + ramp_s)
    shape[ramping] = 1.0 + (mult - 1.0) * (t[ramping] - at_s) / ramp_s
    after = t >= at_s + ramp_s
    shape[after] = 1.0 + (mult - 1.0) * np.exp(-(t[after] - at_s - ramp_s) / decay_s)
    return Trace(bin_s, {svc: base_rates[svc] * shape for svc in sorted(base_rates)})


def correlated_surge_trace(
    base_rates: Mapping[str, float],
    duration_s: float,
    bin_s: float = 60.0,
    surge_mult: float = 4.0,
    n_surges: int = 2,
    surge_len_bins: int = 10,
    ramp_bins: int = 2,
    correlation: float = 0.8,
    seed: int = 0,
) -> Trace:
    """Correlated multi-service surges: one shared seeded surge envelope hits
    every service *simultaneously* (a front-page event, a regional failover).

    The envelope is 0 outside surges and ramps linearly to 1 over
    ``ramp_bins`` at each surge's edges; service ``s`` follows it with
    coupling strength drawn uniformly from ``[correlation, 1]``, so

        rate_s(t) = base_s * (1 + (surge_mult - 1) * k_s * envelope(t)).

    Unlike :func:`poisson_burst_trace` (independent per-service episodes),
    the aggregate demand spike is what stresses a scheduler: every service
    needs capacity in the same bins, so there is no slack to steal.
    """
    if not 0.0 <= correlation <= 1.0:
        raise ValueError(f"correlation must be in [0, 1], got {correlation}")
    if surge_len_bins < 1 or n_surges < 1:
        raise ValueError(
            f"surge_len_bins and n_surges must be >= 1, got "
            f"{surge_len_bins} and {n_surges}"
        )
    n = _bins(duration_s, bin_s)
    rng = np.random.default_rng(seed)
    envelope = np.zeros(n)
    span = min(surge_len_bins, n)
    latest = max(n - span, 0)
    starts = sorted(
        int(s) for s in rng.integers(0, latest + 1, size=n_surges)
    )
    ramp = np.minimum(
        np.minimum(np.arange(1, span + 1), np.arange(span, 0, -1))
        / max(ramp_bins, 1),
        1.0,
    )
    for s in starts:
        seg = slice(s, s + span)
        envelope[seg] = np.maximum(envelope[seg], ramp[: n - s])
    rates = {}
    for svc in sorted(base_rates):
        k = correlation + (1.0 - correlation) * float(rng.random())
        rates[svc] = base_rates[svc] * (1.0 + (surge_mult - 1.0) * k * envelope)
    return Trace(bin_s, rates)


def replay_trace(
    rate_arrays: Mapping[str, "np.ndarray"], bin_s: float = 60.0
) -> Trace:
    """Replay externally recorded per-bin rate arrays (e.g. a production
    metrics export) as a trace."""
    return Trace(
        bin_s,
        {svc: np.asarray(arr, dtype=np.float64) for svc, arr in rate_arrays.items()},
    )
