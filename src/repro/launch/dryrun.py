import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# Placeholder host devices are a CPU-platform feature; pinning cpu (unless
# the caller overrides) also skips the TPU metadata probe, which stalls for
# 60s+ on TPU-less hosts.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# The lines above MUST run before any other import: jax locks the device
# count at first initialization, and the production meshes need 512
# placeholder host devices (16×16 single-pod uses the first 256).

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination and record the roofline inputs.

For each combo this driver:
  1. builds the production mesh (16×16, and 2×16×16 with ``--multi-pod``),
  2. builds the step (train/prefill/serve) with abstract ShapeDtypeStruct
     inputs — no allocation anywhere,
  3. ``jax.jit(fn, in_shardings, out_shardings).lower(...).compile()``,
  4. prints ``memory_analysis()`` / ``cost_analysis()`` and parses the
     post-SPMD HLO for collective bytes,
  5. writes ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` for the
     roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Failures here (sharding mismatch, unsupported collective) are bugs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, build_step
from repro.roofline.analysis import (
    RooflineReport,
    collective_bytes,
    hlo_cost,
    model_step_flops,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    seq_axis: Optional[str] = "model",
    zero1: bool = False,
    infer_shard_data: bool = False,
    act_tp: bool = False,
    donate_cache: bool = False,
    batch_all_axes: bool = False,
    kv_hint: bool = False,
    moe_shard_capacity: bool = False,
    moe_shard_map: bool = False,
    out_dir: str = OUT_DIR,
    tag: str = "",
    verbose: bool = True,
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    chips = mesh.size
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    t0 = time.monotonic()
    bundle = build_step(
        cfg, shape_name, mesh, seq_axis=seq_axis, zero1=zero1,
        infer_shard_data=infer_shard_data, act_tp=act_tp,
        batch_all_axes=batch_all_axes, kv_hint=kv_hint,
        moe_shard_capacity=moe_shard_capacity, moe_shard_map=moe_shard_map,
    )
    donate = (1,) if (donate_cache and shape.kind == "decode") else ()
    with mesh:
        lowered = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=donate,
        ).lower(*bundle.args)
        compiled = lowered.compile()
    t1 = time.monotonic()

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax: list of per-module dicts
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # trip-count-aware accounting: cost_analysis() visits while (scan)
    # bodies once, undercounting scanned models by the layer count
    parsed = hlo_cost(hlo)
    flops = float(parsed["flops"])
    bytes_accessed = float(parsed["bytes"])
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
        )
        out_bytes = float(getattr(mem, "output_size_in_bytes", 0))
    except Exception:
        mem, peak, out_bytes = None, None, None
    coll = collective_bytes(hlo)

    report = RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name + (f"+{tag}" if tag else ""),
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=coll,
        model_flops=model_step_flops(bundle.cfg, shape),
        peak_memory_per_device=peak,
        output_bytes_per_device=out_bytes,
    )
    d = report.to_dict()
    d["compile_seconds"] = t1 - t0
    d["raw_cost_analysis_flops"] = float(cost.get("flops", 0.0))
    d["raw_cost_analysis_bytes"] = float(cost.get("bytes accessed", 0.0))
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(d, f, indent=2)
    if verbose:
        print(
            f"[dryrun] {arch:18s} {shape_name:12s} mesh={mesh_name:8s} "
            f"compile={t1-t0:6.1f}s flops/dev={flops:.3e} bytes/dev={bytes_accessed:.3e} "
            f"coll/dev={sum(coll.values()):.3e} dominant={report.dominant}"
        )
        if mem is not None:
            print(f"         memory_analysis: peak/dev={peak:.3e}B out/dev={out_bytes:.3e}B")
    return d


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-axis", default="model")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--infer-shard-data", action="store_true")
    ap.add_argument("--act-tp", action="store_true")
    ap.add_argument("--donate-cache", action="store_true")
    ap.add_argument("--batch-all-axes", action="store_true")
    ap.add_argument("--kv-hint", action="store_true")
    ap.add_argument("--moe-shard-capacity", action="store_true")
    ap.add_argument("--moe-shard-map", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    combos = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape in combos:
        try:
            run_one(
                arch, shape, multi_pod=args.multi_pod,
                seq_axis=None if args.seq_axis == "none" else args.seq_axis,
                zero1=args.zero1, infer_shard_data=args.infer_shard_data,
                act_tp=args.act_tp, donate_cache=args.donate_cache,
                batch_all_axes=args.batch_all_axes, kv_hint=args.kv_hint,
                moe_shard_capacity=args.moe_shard_capacity,
                moe_shard_map=args.moe_shard_map,
                out_dir=args.out_dir, tag=args.tag,
            )
        except Exception as e:  # noqa: BLE001 — report all failures at the end
            failures.append((arch, shape, repr(e)))
            traceback.print_exc()
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(combos)} combos")


if __name__ == "__main__":
    main()
