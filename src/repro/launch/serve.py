"""Serving driver — the end-to-end example of the paper's kind.

Builds a model, wraps it in a serving :class:`Engine` (continuous batching),
fires a stream of batched requests, and reports throughput and latency.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 16 --batch 4 --new-tokens 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model
from repro.serving import Engine, Request, run_closed_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, batch=args.batch, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    stats = run_closed_loop(engine, reqs, seed=args.seed)
    lat = [r.finished_s - r.submitted_s for r in reqs]
    print(
        f"arch={cfg.name} served={stats.served} tokens={stats.tokens} "
        f"wall={stats.wall_s:.2f}s tput={stats.throughput:.2f} req/s "
        f"p50_lat={np.percentile(lat, 50)*1e3:.0f}ms p90_lat={np.percentile(lat, 90)*1e3:.0f}ms"
    )


if __name__ == "__main__":
    main()
