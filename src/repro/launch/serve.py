"""Serving driver — the end-to-end example of the paper's kind.

Builds a model, wraps it in a serving :class:`Engine` (ragged continuous
batching over a paged KV cache where the architecture supports it), fires a
stream of batched requests, and reports throughput and latency.  It then
closes the paper's §8.3 loop: the measured throughput is fed into a
:class:`~repro.core.online_profiles.MeasuredProfile` wrapped around the
roofline profile the optimizer consumes, and the resulting correction
factor is printed.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 16 --batch 4 --new-tokens 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.arch_bridge import tpu_arch_profiles
from repro.core.online_profiles import MeasuredProfile
from repro.models import Model
from repro.serving import Engine, Request, run_closed_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--backend", choices=["auto", "flat", "paged"], default="auto")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--size", type=int, default=16,
                    help="slice size credited in the §8.3 profile feedback")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stats-json", type=str, default=None, metavar="PATH",
                    help="write engine TTFT/TPOT stats as JSON in the same "
                         "metrics schema as the simulator's obs block "
                         "(docs/OBSERVABILITY.md)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(
        model, params, batch=args.batch, max_len=args.max_len,
        kv_backend=args.backend, page_size=args.page_size,
        temperature=args.temperature, top_k=args.top_k,
    )

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    measured = MeasuredProfile(tpu_arch_profiles([args.arch]))
    stats = run_closed_loop(
        engine, reqs, seed=args.seed,
        measured=measured, service=args.arch, size=args.size,
    )
    lat = [r.finished_s - r.submitted_s for r in reqs]
    print(
        f"arch={cfg.name} backend={engine.kv_backend} served={stats.served} "
        f"tokens={stats.tokens} preempted={stats.preempted} "
        f"wall={stats.wall_s:.2f}s tput={stats.throughput:.2f} req/s "
        f"p50_lat={np.percentile(lat, 50)*1e3:.0f}ms p90_lat={np.percentile(lat, 90)*1e3:.0f}ms"
    )
    if engine.pool is not None:
        print(
            f"pages={engine.pool.num_pages} free={engine.pool.free_pages} "
            f"page_size={engine.pool.page_size}"
        )
    print(
        f"§8.3 feedback: measured correction for ({args.arch}, size={args.size}) "
        f"= {measured.correction(args.arch, args.size):.4f}"
    )
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            json.dump(stats.summary(args.arch), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"stats written to {args.stats_json}")


if __name__ == "__main__":
    main()
