"""Training driver.

Runs real steps on CPU for smoke/100M-scale configs; the full production
configs are exercised through :mod:`repro.launch.dryrun` (no allocation).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 20 --batch 4 --seq 64
  PYTHONPATH=src python -m repro.launch.train --repro-100m --steps 10
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model
from repro.models.config import ModelConfig
from repro.training import adamw, checkpoint, data, make_train_step

# ~100M-parameter dense config for the end-to-end training example
REPRO_100M = ModelConfig(
    name="repro-100m",
    arch_type="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=8192,
    citation="in-repo 100M example config",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--repro-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.repro_100m:
        cfg = REPRO_100M
    elif args.arch:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    else:
        cfg = get_smoke_config("qwen3-8b")

    model = Model(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(1, args.steps // 10))
    opt_state = adamw.init(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    dcfg = data.DataConfig(batch=args.batch, seq_len=args.seq, seed=args.seed)

    t0 = time.monotonic()
    first = last = None
    for i, batch in enumerate(data.batches(cfg, dcfg, args.steps)):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f}")
    dt = time.monotonic() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s, loss {first:.3f} -> {last:.3f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, params)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
