"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_slice_mesh(rows: int, cols: int):
    """Mesh for one scheduled TPU slice (repro.core.tpu_slice geometry)."""
    return jax.make_mesh((rows, cols), ("data", "model"))
