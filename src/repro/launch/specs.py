"""Input shapes, argument structs and shardings for every launch step.

``input_specs(cfg, shape, mesh)`` produces weak-type-correct
ShapeDtypeStruct stand-ins for every model input — shardable, no device
allocation — plus the matching NamedShardings.  ``build_step`` returns the
jit-able step function and its in/out shardings for (arch × shape × mesh):

  train_4k     -> train_step   (params, opt_state, batch)
  prefill_32k  -> prefill      (params, tokens|embeds)
  decode_32k   -> serve_step   (params, cache, token, pos) — 1 new token
  long_500k    -> serve_step with a 524288-token context (ring cache /
                  SSM state; dense archs use the sliding-window variant)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import long_context_variant
from repro.models import Model
from repro.models.config import ModelConfig
from repro.training import adamw
from repro.training.train_loop import make_train_step


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    long_context: bool = False


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, long_context=True),
}


def _dp_axes(mesh: Mesh, batch: int) -> Tuple[str, ...]:
    """Data-parallel axes actually usable for this batch size."""
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return axes if axes and batch % size == 0 and batch >= size else ()


def _shard(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def shape_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    if shape.long_context and cfg.arch_type != "ssm":
        return long_context_variant(cfg)
    return cfg


@dataclasses.dataclass
class StepBundle:
    """Everything dryrun/launchers need for one (arch × shape × mesh)."""

    fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStructs (or real arrays for drivers)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    model: Model
    cfg: ModelConfig


def _batch_struct(cfg: ModelConfig, shape: ShapeSpec, dp) -> Tuple[Dict, Dict]:
    B, S = shape.global_batch, shape.seq_len
    structs: Dict[str, Any] = {
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    specs: Dict[str, Any] = {"labels": P(dp, None)}
    if cfg.modality == "text":
        structs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["tokens"] = P(dp, None)
    else:
        structs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        specs["embeds"] = P(dp, None, None)
    return structs, specs


def build_step(
    arch_cfg: ModelConfig,
    shape_name: str,
    mesh: Mesh,
    seq_axis: Optional[str] = "model",
    remat: bool = True,
    zero1: bool = False,
    infer_shard_data: bool = False,
    act_tp: bool = False,
    batch_all_axes: bool = False,
    kv_hint: bool = False,
    moe_shard_capacity: bool = False,
    moe_shard_map: bool = False,
) -> StepBundle:
    """§Perf knobs beyond the paper-faithful baseline:
      zero1            — shard optimizer moments over the data axis too
      infer_shard_data — inference weights sharded over data AND model axes
                         (serving has no gradient sync, so the data axis is
                         free real estate for weight shards)
      act_tp           — residual-stream feature dim constrained to "model"
                         (turns TP all-reduces into reduce-scatter pairs)
    """
    shape = SHAPES[shape_name]
    cfg = shape_config(arch_cfg, shape)
    dp = _dp_axes(mesh, shape.global_batch)
    if (
        batch_all_axes
        and shape.kind == "decode"
        and cfg.arch_type in ("dense", "vlm", "audio", "moe")
        and shape.global_batch % mesh.size == 0
    ):
        # decode batch over every mesh axis: attention becomes fully local
        # per chip (no cache resharding); weights are all-gathered instead.
        # (SSM/hybrid caches shard their head dim on "model" — skip those.)
        dp = tuple(mesh.axis_names)
        seq_axis = None
    model = Model(
        cfg,
        remat=remat and shape.kind == "train",
        mesh_axes=tuple(mesh.axis_names),
        act_tp=act_tp and shape.kind != "decode",
        kv_hint=P(dp, None, None, None) if kv_hint else None,
        moe_buf_spec=P("model", "data", None) if moe_shard_capacity else None,
        moe_shard_map_mesh=mesh if moe_shard_map else None,
    )
    # abstract params + specs (no allocation)
    params, pspecs = model.init(None, abstract=True)
    if infer_shard_data and shape.kind != "train":
        pspecs = _dual_axis_specs(pspecs, params, mesh)
    param_sh = _shard(mesh, pspecs)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt = jax.eval_shape(adamw.init, params)
        opt_specs = adamw.AdamWState(
            step=P(),
            mu=_zero1_specs(pspecs, opt.mu, mesh) if zero1 else pspecs,
            nu=_zero1_specs(pspecs, opt.nu, mesh) if zero1 else pspecs,
        )
        opt_sh = _shard(mesh, opt_specs)
        batch, bspecs = _batch_struct(cfg, shape, dp)
        batch_sh = _shard(mesh, bspecs)
        fn = make_train_step(model, opt_cfg)
        metrics_sh = None  # replicated scalars
        return StepBundle(
            fn=fn,
            args=(params, opt, batch),
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, metrics_sh),
            model=model,
            cfg=cfg,
        )

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        if cfg.modality == "text":
            inp = jax.ShapeDtypeStruct((B, S), jnp.int32)
            inp_spec = P(dp, None)
            fn = lambda p, tokens: model.prefill(p, tokens=tokens)
        else:
            inp = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
            inp_spec = P(dp, None, None)
            fn = lambda p, embeds: model.prefill(p, embeds=embeds)
        cache_specs = model.cache_specs(seq_axis=seq_axis)
        logits_spec = P(dp, None, "model")
        return StepBundle(
            fn=fn,
            args=(params, inp),
            in_shardings=(param_sh, NamedSharding(mesh, inp_spec)),
            out_shardings=(
                NamedSharding(mesh, logits_spec),
                _shard(mesh, cache_specs),
            ),
            model=model,
            cfg=cfg,
        )

    # decode
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    # rebind cache specs to the usable dp axes (batch=1 cannot shard)
    cache_specs = model.cache_specs(seq_axis=seq_axis, dp=dp)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    # per-slot ragged positions (continuous batching): one int32 per request
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    fn = lambda p, c, t, i: model.decode_step(p, c, t, i)
    return StepBundle(
        fn=fn,
        args=(params, cache, token, pos),
        in_shardings=(
            param_sh,
            _shard(mesh, cache_specs),
            NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P(dp) if dp else P()),
        ),
        out_shardings=(
            NamedSharding(mesh, P(dp, None, "model")),
            _shard(mesh, cache_specs),
        ),
        model=model,
        cfg=cfg,
    )


def input_specs(
    arch_cfg: ModelConfig, shape_name: str, mesh: Mesh, **kwargs
) -> Tuple[Tuple[Any, ...], Tuple[Any, ...]]:
    """ShapeDtypeStruct stand-ins + NamedShardings for every model input of
    one (arch × shape × mesh) step — weak-type-correct, shardable, no device
    allocation.  (Thin veneer over :func:`build_step` for callers that only
    need the argument specs.)"""
    bundle = build_step(arch_cfg, shape_name, mesh, **kwargs)
    return bundle.args, bundle.in_shardings


def _dp_axes_names(mesh: Mesh, dp: Tuple[str, ...]):
    """Mesh-axis tuple for a Model whose batch axes are restricted to dp."""
    return tuple(a for a in mesh.axis_names if a == "model" or a in dp)


def _dual_axis_specs(pspecs, params_like, mesh: Mesh):
    """Inference weight sharding over BOTH axes: keep the "model" dim and
    additionally shard the largest unsharded, divisible dim over "data"."""
    data = mesh.shape.get("data", 1)

    def upgrade(spec, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # choose the largest eligible dim for the data shard
        best, best_dim = None, 0
        for i, (p_, dim) in enumerate(zip(parts, leaf.shape)):
            if p_ is None and dim % data == 0 and dim >= data and dim > best_dim:
                best, best_dim = i, dim
        if best is not None and best_dim >= 1024:  # skip tiny tensors
            parts[best] = "data"
        return P(*parts)

    return jax.tree.map(
        upgrade, pspecs, params_like, is_leaf=lambda x: isinstance(x, P)
    )


def _zero1_specs(pspecs, opt_like, mesh: Mesh):
    """ZeRO-1: additionally shard optimizer moments over the data axis on the
    largest dimension that is unsharded and divisible (beyond-paper §Perf)."""
    data = mesh.shape.get("data", 1)

    def upgrade(spec, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (p_, dim) in enumerate(zip(parts, leaf.shape)):
            if p_ is None and dim % data == 0 and dim >= data:
                parts[i] = "data"
                return P(*parts)
        return P(*parts)

    return jax.tree.map(
        upgrade, pspecs, opt_like, is_leaf=lambda x: isinstance(x, P)
    )
