"""Paged KV-cache pool management (the host side of paged attention).

A :class:`PagePool` owns a fixed page inventory; requests allocate pages as
their context grows and release them on completion (one logical page id
addresses a slab across every attention layer).  The pool is the serving
engine's KV accounting: :class:`~repro.serving.engine.Engine` admits a
request's prompt into pages, grows it one token per decode step, and treats
:class:`OutOfPages` as its admission-refusal / preemption signal; ``tables``
produces the (page_tables, lengths) that ``repro.kernels.paged_attention``
and ``Model.decode_step_paged`` consume.

Allocation is **atomic**: a grow that cannot complete rolls back any pages
it grabbed, so a refused request leaves the pool byte-identical.

This is deliberately simple (free-list, no copy-on-write/prefix sharing);
the point is that MIG-Serving's slice scheduler and a paged engine compose:
a slice's HBM budget translates directly to ``num_pages`` (see
``repro.serving.engine.page_hbm_bytes``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


class OutOfPages(RuntimeError):
    pass


def page_bytes(
    page_size: int, kv_heads: int, head_dim: int, n_layers: int,
    dtype_bytes: int = 2,
) -> int:
    """HBM cost of ONE logical page: its k+v slabs across every attention
    layer.  The single source of truth for paged-KV capacity math — both
    :meth:`PagePool.hbm_bytes` and the engine's HBM-budget → ``num_pages``
    mapping derive from it."""
    return 2 * page_size * kv_heads * head_dim * n_layers * dtype_bytes


@dataclasses.dataclass
class RequestPages:
    rid: int
    page_ids: List[int]
    length: int = 0


class PagePool:
    def __init__(self, num_pages: int, page_size: int, max_pages_per_req: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_req = max_pages_per_req
        self._free: List[int] = list(range(num_pages))
        self._requests: Dict[int, RequestPages] = {}

    # -- lifecycle ---------------------------------------------------------------
    def admit(self, rid: int) -> RequestPages:
        if rid in self._requests:
            raise ValueError(f"request {rid} is already admitted to the pool")
        r = RequestPages(rid, [])
        self._requests[rid] = r
        return r

    def release(self, rid: int) -> bool:
        """Return ``rid``'s pages to the free list.  Releasing a request the
        pool no longer holds (a preempt racing a finish/drain, or a release
        after a crash replaced the pool) is a deterministic no-op returning
        False — never a double free-list insertion, which would let two
        requests share a page and corrupt both caches."""
        r = self._requests.pop(rid, None)
        if r is None:
            return False
        self._free.extend(r.page_ids)
        return True

    def abort(self, rid: int) -> None:
        """Undo a *fresh* admission whose pages came from one
        :meth:`append_tokens` grab — the engine's cleanup path when prefill
        fails after the reservation succeeded.  Pages go back in reverse
        grab order, so the free list (hence every later allocation) is
        byte-identical to the pre-admission state."""
        r = self._requests.pop(rid)
        self._free.extend(reversed(r.page_ids))

    def request(self, rid: int) -> RequestPages:
        """The live allocation record for ``rid`` (page ids + token length)."""
        return self._requests[rid]

    def append_tokens(self, rid: int, n: int = 1) -> None:
        """Grow a request's context by ``n`` tokens, allocating pages on
        boundary crossings.  Raises :class:`OutOfPages` when the pool (or the
        per-request table) is exhausted — the engine's admission/preemption
        signal.  **Atomic**: on failure any pages grabbed mid-loop are rolled
        back to the free list and the request's record is unchanged, so a
        refused grow leaves the pool exactly as it found it."""
        r = self._requests[rid]
        new_len = r.length + n
        needed = -(-new_len // self.page_size)  # ceil
        grabbed: List[int] = []
        try:
            while len(r.page_ids) + len(grabbed) < needed:
                if len(r.page_ids) + len(grabbed) >= self.max_pages_per_req:
                    raise OutOfPages(f"request {rid} exceeds max context")
                if not self._free:
                    raise OutOfPages("page pool exhausted")
                grabbed.append(self._free.pop())
        except OutOfPages:
            # roll back in reverse so the free list is byte-identical to the
            # pre-call state (allocation order stays deterministic)
            self._free.extend(reversed(grabbed))
            raise
        r.page_ids.extend(grabbed)
        r.length = new_len

    # -- kernel inputs --------------------------------------------------------------
    def tables(
        self, rids: List[Optional[int]]
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(page_tables (B, max_pages), lengths (B,)) for the given batch.
        ``None`` entries are idle slots; they (and unused table tail cells)
        point at page 0 — a legal dummy the kernel masks by length 0."""
        B = len(rids)
        pt = np.zeros((B, self.max_pages_per_req), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, rid in enumerate(rids):
            if rid is None:
                continue
            r = self._requests[rid]
            pt[i, : len(r.page_ids)] = r.page_ids
            lens[i] = r.length
        return pt, lens

    # -- accounting ---------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.num_pages

    def hbm_bytes(self, kv_heads: int, head_dim: int, n_layers: int,
                  dtype_bytes: int = 2) -> int:
        """Pool HBM footprint — what a slice's capacity check consumes."""
        return self.num_pages * page_bytes(
            self.page_size, kv_heads, head_dim, n_layers, dtype_bytes
        )
