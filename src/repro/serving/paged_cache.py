"""Paged KV-cache pool management (the host side of paged attention).

A :class:`PagePool` owns a fixed page inventory per layer; requests allocate
pages as their context grows and release them on completion.  The pool is
the serving-engine counterpart of ``repro.kernels.paged_attention`` — it
produces the (page_tables, lengths) the kernel consumes.

This is deliberately simple (free-list, no copy-on-write/prefix sharing);
the point is that MIG-Serving's slice scheduler and a paged engine compose:
a slice's HBM budget translates directly to ``num_pages``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class RequestPages:
    rid: int
    page_ids: List[int]
    length: int = 0


class PagePool:
    def __init__(self, num_pages: int, page_size: int, max_pages_per_req: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_req = max_pages_per_req
        self._free: List[int] = list(range(num_pages))
        self._requests: Dict[int, RequestPages] = {}

    # -- lifecycle ---------------------------------------------------------------
    def admit(self, rid: int) -> RequestPages:
        assert rid not in self._requests
        r = RequestPages(rid, [])
        self._requests[rid] = r
        return r

    def release(self, rid: int) -> None:
        r = self._requests.pop(rid)
        self._free.extend(r.page_ids)

    def append_tokens(self, rid: int, n: int = 1) -> None:
        """Grow a request's context by ``n`` tokens, allocating pages on
        boundary crossings.  Raises :class:`OutOfPages` when the pool (or the
        per-request table) is exhausted — the engine's admission signal."""
        r = self._requests[rid]
        new_len = r.length + n
        needed = -(-new_len // self.page_size)  # ceil
        while len(r.page_ids) < needed:
            if len(r.page_ids) >= self.max_pages_per_req:
                raise OutOfPages(f"request {rid} exceeds max context")
            if not self._free:
                raise OutOfPages("page pool exhausted")
            r.page_ids.append(self._free.pop())
        r.length = new_len

    # -- kernel inputs --------------------------------------------------------------
    def tables(self, rids: List[int]) -> Tuple[np.ndarray, np.ndarray]:
        """(page_tables (B, max_pages), lengths (B,)) for the given batch.
        Unused slots point at page 0 (a legal dummy; masked by length)."""
        B = len(rids)
        pt = np.zeros((B, self.max_pages_per_req), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, rid in enumerate(rids):
            r = self._requests[rid]
            pt[i, : len(r.page_ids)] = r.page_ids
            lens[i] = r.length
        return pt, lens

    # -- accounting ---------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.num_pages

    def hbm_bytes(self, kv_heads: int, head_dim: int, n_layers: int,
                  dtype_bytes: int = 2) -> int:
        """Pool HBM footprint — what a slice's capacity check consumes."""
        return (
            2 * self.num_pages * self.page_size * kv_heads * head_dim
            * n_layers * dtype_bytes
        )
