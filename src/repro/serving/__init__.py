"""Serving runtime: per-instance engines and the service-level router.

The engine pulls in jax and the model zoo; the router is plain Python.  The
engine names are exported lazily (PEP 562) so jax-free consumers — notably
the cluster simulator in :mod:`repro.sim` — can import the router without
paying (or requiring) the jax import.
"""

from repro.serving.paged_cache import OutOfPages, PagePool
from repro.serving.router import InstanceHandle, WeightedRouter

__all__ = [
    "Engine", "InstanceHandle", "OutOfPages", "PagePool", "Request",
    "ServeStats", "WeightedRouter", "page_hbm_bytes", "run_closed_loop",
]

_ENGINE_NAMES = ("Engine", "Request", "ServeStats", "page_hbm_bytes",
                 "run_closed_loop")


def __getattr__(name):
    if name in _ENGINE_NAMES:
        from repro.serving import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
