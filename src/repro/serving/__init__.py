"""Serving runtime: per-instance engines and the service-level router."""

from repro.serving.engine import Engine, Request, ServeStats, run_closed_loop
from repro.serving.router import InstanceHandle, WeightedRouter

__all__ = [
    "Engine", "InstanceHandle", "Request", "ServeStats", "WeightedRouter",
    "run_closed_loop",
]
